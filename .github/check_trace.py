#!/usr/bin/env python3
"""Validate a flight-recorder Chrome trace dump (--trace-out FILE).

Stdlib-only, mirrors compare_bench.py's role for the trace artifact:
the CI observability smoke runs a short serve with tracing enabled and
this script asserts the dump is a loadable trace with the lifecycle
stages the recorder promises. Checks:

  * the file parses as one JSON array of event objects;
  * instant events (ph "i") cover the core request lifecycle stages;
  * derived hop spans (ph "X") exist and carry non-negative durations;
  * timestamps are non-negative integers (one shared time axis);
  * per-request instants are monotone in stage order is the recorder's
    own invariant (tested in-process) — here we only re-check span
    durations, since stage names round-tripped through JSON.

Usage: check_trace.py <trace.json>
"""

import json
import sys

# Stages a short in-process serve run must tap. Wire stages
# (wire_cand_tx / wire_grant_rx) appear only on remote-rank runs and
# are not required here.
REQUIRED_STAGES = {
    "submit",
    "ingest_bin",
    "worker_recv",
    "cand_reg",
    "rank_grant",
    "grant_recv",
    "dispatch",
    "complete",
}


def fail(msg):
    print(f"::error title=trace check::{msg}")
    return 1


def main():
    if len(sys.argv) != 2:
        print("usage: check_trace.py <trace.json>")
        return 2
    path = sys.argv[1]
    try:
        with open(path) as f:
            events = json.load(f)
    except OSError as e:
        return fail(f"cannot read {path}: {e}")
    except ValueError as e:
        return fail(f"{path} is not valid JSON: {e}")
    if not isinstance(events, list) or not events:
        return fail(f"{path} must be a non-empty JSON array of trace events")

    instants = [e for e in events if e.get("ph") == "i"]
    spans = [e for e in events if e.get("ph") == "X"]
    if not instants:
        return fail("no instant (ph 'i') events — the recorder captured nothing")
    if not spans:
        return fail("no hop span (ph 'X') events — per-request chains never formed")

    for e in events:
        ts = e.get("ts")
        if not isinstance(ts, int) or ts < 0:
            return fail(f"event with non-integer/negative ts: {e}")
    for e in spans:
        dur = e.get("dur")
        if not isinstance(dur, int) or dur < 0:
            return fail(f"hop span with bad duration: {e}")

    seen = {e.get("name") for e in instants}
    missing = sorted(REQUIRED_STAGES - seen)
    if missing:
        return fail(
            f"lifecycle stages missing from the trace: {', '.join(missing)} "
            f"(saw: {', '.join(sorted(s for s in seen if s))})"
        )

    shed = next(
        (e for e in events if e.get("ph") == "C" and e.get("name") == "trace_shed"),
        None,
    )
    shed_n = (shed or {}).get("args", {}).get("shed", "?")
    print(
        f"trace ok: {len(events)} events ({len(instants)} instants, "
        f"{len(spans)} hop spans), {len(seen)} stages, shed={shed_n}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
