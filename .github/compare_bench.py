#!/usr/bin/env python3
"""Compare two bench JSON files (BENCH_hotpath.json, BENCH_frontend.json,
...) and FAIL (exit nonzero, `::error` annotations) when a `*_per_sec`
metric regresses more than 30% against a non-empty checked-in baseline.
Usage: compare_bench.py <baseline.json> <new.json>.

An empty or missing baseline is announced explicitly and stays
informational (the trajectory is being seeded by this run); metrics
present in the new results but absent from the baseline — a freshly
added bench — are reported as informational rather than silently
skipped."""

import json
import os
import sys

REGRESSION_FRACTION = 0.30


def load(path):
    try:
        with open(path) as f:
            return json.load(f).get("results", {}) or {}
    except (OSError, ValueError):
        return {}


def main():
    if len(sys.argv) != 3:
        print("usage: compare_bench.py <baseline.json> <new.json>")
        return 0
    base, new = load(sys.argv[1]), load(sys.argv[2])
    name = os.path.basename(sys.argv[2])
    if not base:
        print(
            f"no baseline for {name} — seeding: this run's "
            f"{len(new)} metrics become the comparison base once committed"
        )
        return 0
    checked = regressed = 0
    for key, old in sorted(base.items()):
        if not key.endswith("_per_sec") or not isinstance(old, (int, float)) or old <= 0:
            continue
        cur = new.get(key)
        if not isinstance(cur, (int, float)):
            continue
        checked += 1
        if cur < (1.0 - REGRESSION_FRACTION) * old:
            regressed += 1
            drop = 100.0 * (1.0 - cur / old)
            print(
                f"::error title={name} regression::"
                f"{key}: {old:.0f} -> {cur:.0f} events/sec (-{drop:.0f}%)"
            )
    fresh = sorted(k for k in new if k.endswith("_per_sec") and k not in base)
    if fresh:
        shown = ", ".join(fresh[:8]) + (", ..." if len(fresh) > 8 else "")
        print(
            f"{len(fresh)} metrics not in the baseline (informational, "
            f"no comparison until committed): {shown}"
        )
    print(f"bench comparison ({name}): {checked} metrics checked, {regressed} regressed >30%")
    # A populated baseline is a contract: regressing past the threshold
    # fails the job (seeding runs above return 0 before reaching here).
    return 1 if regressed else 0


if __name__ == "__main__":
    sys.exit(main())
