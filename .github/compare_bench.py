#!/usr/bin/env python3
"""Compare two BENCH_hotpath.json files and emit GitHub warnings (never
fail) when a `*_per_sec` metric regresses more than 30% against the
checked-in baseline. Usage: compare_bench.py <baseline.json> <new.json>.
Missing or empty baselines are skipped silently (the trajectory starts
with the first committed run)."""

import json
import sys

REGRESSION_FRACTION = 0.30


def load(path):
    try:
        with open(path) as f:
            return json.load(f).get("results", {}) or {}
    except (OSError, ValueError):
        return {}


def main():
    if len(sys.argv) != 3:
        print("usage: compare_bench.py <baseline.json> <new.json>")
        return 0
    base, new = load(sys.argv[1]), load(sys.argv[2])
    if not base:
        print("no baseline bench results; skipping comparison")
        return 0
    checked = regressed = 0
    for key, old in sorted(base.items()):
        if not key.endswith("_per_sec") or not isinstance(old, (int, float)) or old <= 0:
            continue
        cur = new.get(key)
        if not isinstance(cur, (int, float)):
            continue
        checked += 1
        if cur < (1.0 - REGRESSION_FRACTION) * old:
            regressed += 1
            drop = 100.0 * (1.0 - cur / old)
            print(
                f"::warning title=bench_hotpath regression::"
                f"{key}: {old:.0f} -> {cur:.0f} events/sec (-{drop:.0f}%)"
            )
    print(f"bench comparison: {checked} metrics checked, {regressed} regressed >30%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
