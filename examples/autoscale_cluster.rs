//! Fig 15-style demo: a changing workload (diurnal + bursts) on a large
//! emulated cluster with the §3.5 autoscaling controller in the loop.
//! Prints the time series of offered load, active GPUs, bad rate, and
//! scaling actions — Symphony's load-proportional GPU usage in action.
//!
//! ```bash
//! cargo run --release --example autoscale_cluster -- [secs] [gpus]
//! ```

use symphony::harness::experiments;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let secs: f64 = args.first().and_then(|v| v.parse().ok()).unwrap_or(240.0);
    let gpus: usize = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(128);

    println!("changing workload on a {gpus}-GPU cluster for {secs} simulated seconds");
    let table = experiments::fig15_autoscale(secs, gpus);
    print!("{}", table.render());
    println!(
        "\nExpect: active_gpus tracks offered_rps (load-proportional), bad_rate\n\
         stays near zero except transiently after bursts (flat-top, §3.5)."
    );
}
