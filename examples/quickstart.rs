//! Quickstart: simulate Symphony's deferred batch scheduler against
//! eager scheduling on an 8-GPU cluster serving ResNet50 under a 25 ms
//! SLO, and print goodput + batch statistics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use symphony::core::model_zoo;
use symphony::harness::{GoodputExperiment, SystemKind};

fn main() {
    // 1. Pick a model from the paper's zoo (Table 2 profile).
    let resnet50 = model_zoo::resnet50_table2();
    println!(
        "model {}: l(b) = {:.3}b + {:.3} ms, SLO {}",
        resnet50.name, resnet50.profile.alpha_ms, resnet50.profile.beta_ms, resnet50.slo
    );

    // 2. Define the experiment: 8 emulated GPUs, Poisson arrivals.
    let exp = GoodputExperiment::new(vec![resnet50], 8).sim_secs(8.0);

    // 3. Binary-search the goodput of each system (§2.1's definition:
    //    max rate with p99 latency within SLO).
    for sys in [
        SystemKind::Symphony,
        SystemKind::Eager,
        SystemKind::Clockwork,
        SystemKind::Nexus { frontends: 1 },
        SystemKind::Shepherd,
    ] {
        let res = exp.goodput(|e| {
            sys.build(&e.models, e.num_gpus, symphony::Micros::ZERO)
        });
        let hist = res.metrics.batch_hist_all();
        println!(
            "{:<10} goodput {:>6.0} r/s   median batch {:>2}   p95 batch {:>2}",
            sys.label(),
            res.goodput,
            hist.median(),
            hist.quantile(0.95),
        );
    }
    println!("\n(expect symphony to lead with ~2x the eager median batch — Fig 1 / Table 2)");
}
