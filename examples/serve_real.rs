//! **End-to-end validation driver**: serve the real AOT-compiled TinyCNN
//! (JAX + Pallas → HLO text → PJRT CPU) behind the ModelThread/RankThread
//! coordinator under a live Poisson workload, and report latency,
//! goodput, and batch statistics. Python is not involved at runtime.
//!
//! ```bash
//! make artifacts          # once: lowers the model per batch size
//! cargo run --release --example serve_real -- [rate] [secs] [gpus]
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::time::Duration;

use symphony::core::profile::ModelSpec;
use symphony::runtime::{default_artifacts_dir, ModelRuntime};
use symphony::serve::{serve, BackendKind, ServeConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rate: f64 = args.first().and_then(|v| v.parse().ok()).unwrap_or(400.0);
    let secs: f64 = args.get(1).and_then(|v| v.parse().ok()).unwrap_or(5.0);
    let gpus: usize = args.get(2).and_then(|v| v.parse().ok()).unwrap_or(2);

    let Some(dir) = default_artifacts_dir() else {
        eprintln!("artifacts/ not found — run `make artifacts` first");
        std::process::exit(1);
    };

    // Load once up front to report the compiled inventory and measured
    // profile (the serving path reloads inside its executor thread
    // because PJRT handles are not Send).
    println!("loading artifacts from {}", dir.display());
    let rt = ModelRuntime::load(&dir).expect("load artifacts");
    println!(
        "platform: {}   batch sizes: {:?}",
        rt.platform(),
        rt.batch_sizes()
    );
    let profile = rt
        .profile
        .as_ref()
        .map(|p| p.fitted)
        .unwrap_or_else(|| symphony::core::profile::LatencyProfile::new(0.05, 0.2));
    println!(
        "measured profile: l(b) = {:.3}b + {:.3} ms",
        profile.alpha_ms, profile.beta_ms
    );

    // Two "services" share the TinyCNN with a 50 ms SLO; the scheduler
    // plans with the measured CPU ℓ(b).
    let model = |name: &str| {
        let mut m = ModelSpec::new(name, profile.alpha_ms.max(0.02), profile.beta_ms.max(0.05), 50.0);
        m.profile = symphony::core::profile::LatencyProfile::new(
            profile.alpha_ms.max(0.02),
            profile.beta_ms.max(0.05),
        );
        m
    };
    let models = vec![model("tinycnn-a"), model("tinycnn-b")];

    println!(
        "\nserving {} models on {gpus} emulated GPUs at {rate} r/s for {secs}s ...",
        models.len()
    );
    let report = serve(ServeConfig {
        models,
        num_gpus: gpus,
        initial_gpus: None,
        rank_shards: 1,
        ingest_shards: 1,
        model_workers: None,
        remote_ranks: Vec::new(),
        total_rate: rate,
        rate_phases: Vec::new(),
        duration: Duration::from_secs_f64(secs),
        backend: BackendKind::Pjrt {
            artifacts_dir: dir,
        },
        autoscale: None,
        busy_poll: false,
        pin_cores: false,
        seed: 42,
    })
    .expect("serving run");

    println!("\n================ serve_real report ================");
    println!("submitted          {}", report.submitted);
    println!("completed          {}", report.completed);
    println!("dropped            {}", report.dropped);
    println!("SLO violations     {}", report.violations);
    println!("goodput            {:.1} req/s", report.goodput);
    println!("p50 latency        {:.2} ms", report.p50_latency_ms);
    println!("p99 latency        {:.2} ms", report.p99_latency_ms);
    println!("median batch size  {}", report.median_batch);
    println!("mean batch size    {:.2}", report.mean_batch);
    println!("batches executed   {}", report.batches);
    println!("bad fraction       {:.4}", report.bad_fraction());
    println!("===================================================");

    if report.bad_fraction() > 0.05 {
        eprintln!("warning: >5% SLO violations — lower the rate for this host");
    }
}
