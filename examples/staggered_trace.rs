//! Renders the paper's worked examples as ASCII timelines:
//! Figure 4 (staggered execution) and Figure 5 (reaction to three
//! missing requests) — deferred vs eager.
//!
//! ```bash
//! cargo run --release --example staggered_trace
//! ```

use symphony::core::time::Micros;
use symphony::harness::experiments::{render_trace, worked_example_workload};
use symphony::harness::SystemKind;
use symphony::sim::{Engine, SimConfig};

fn run(title: &str, sys: SystemKind, skip: bool) {
    let (models, workload) = worked_example_workload(72, skip);
    let cfg = SimConfig::new(3, Micros::from_secs_f64(0.1)).trace(true);
    let res = Engine::new(workload, sys.build(&models, 3, Micros::ZERO), cfg).run();
    println!("\n=== {title} ===");
    print!("{}", render_trace(&res.trace, 3, 55.0));
    println!(
        "good={} dropped={} median_batch={}",
        res.metrics.per_model[0].good,
        res.metrics.per_model[0].dropped,
        res.metrics.per_model[0].median_batch()
    );
}

fn main() {
    println!("Worked example (§3.3): l(b) = b + 5 ms, SLO 12 ms, 3 GPUs,");
    println!("arrivals every 0.75 ms. Digits are batch sizes, 1 column = 1 ms.");

    run("Figure 4: deferred batch scheduling (staggered)", SystemKind::Symphony, false);
    run("Figure 5a: eager, R13-R15 missing (degrades)", SystemKind::Eager, true);
    run("Figure 5b: deferred, R13-R15 missing (recovers)", SystemKind::Symphony, true);
}
