"""AOT entry point: lower TinyCNN to HLO text, one artifact per batch size.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (all under ``artifacts/``):
  model_b{B}.hlo.txt   — lowered forward pass at batch size B
  manifest.tsv         — batch_size -> artifact path + I/O shapes
  profile.tsv          — measured CPU ℓ(b) per batch size, plus the fitted
                         α/β the serving examples use for SLO math

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_lib

BATCH_SIZES = [1, 2, 4, 8, 16, 32]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    `print_large_constants=True` is load-bearing: the default printer
    elides big literals as ``constant({...})``, which the Rust side's
    HLO-text parser silently reads back as *zeros* — the baked-in model
    weights would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def measure_latency_ms(fn, args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-clock latency of the jitted fn on this host (ms)."""
    jfn = jax.jit(fn)
    out = jfn(*args)
    jax.block_until_ready(out)
    for _ in range(warmup):
        jax.block_until_ready(jfn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jfn(*args))
        samples.append((time.perf_counter() - t0) * 1e3)
    samples.sort()
    return samples[len(samples) // 2]


def fit_affine(batch_sizes, lat_ms):
    """Least-squares fit ℓ(b) = αb + β."""
    b = np.asarray(batch_sizes, dtype=np.float64)
    y = np.asarray(lat_ms, dtype=np.float64)
    a = np.vstack([b, np.ones_like(b)]).T
    (alpha, beta), *_ = np.linalg.lstsq(a, y, rcond=None)
    return float(alpha), float(beta)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--batch-sizes",
        default=",".join(map(str, BATCH_SIZES)),
        help="comma-separated batch sizes to lower",
    )
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument(
        "--skip-profile", action="store_true", help="skip ℓ(b) measurement"
    )
    args = parser.parse_args()

    batch_sizes = [int(s) for s in args.batch_sizes.split(",") if s]
    os.makedirs(args.out_dir, exist_ok=True)
    params = model_lib.init_params(args.seed)

    manifest_rows = []
    profile_rows = []
    for b in batch_sizes:
        fn, specs = model_lib.batched_entry(params, b)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        name = f"model_b{b}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        in_shape = "x".join(map(str, specs[0].shape))
        out_shape = f"{b}x{model_lib.NUM_CLASSES}"
        manifest_rows.append((b, name, in_shape, out_shape))
        print(f"lowered b={b:<3d} -> {path} ({len(text)} chars)")

        if not args.skip_profile:
            x = np.zeros(specs[0].shape, np.float32)
            ms = measure_latency_ms(fn, (jnp.asarray(x),))
            profile_rows.append((b, ms))
            print(f"  measured latency: {ms:.3f} ms")

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("batch_size\tartifact\tinput_shape\toutput_shape\n")
        for row in manifest_rows:
            f.write("\t".join(map(str, row)) + "\n")

    if profile_rows:
        alpha, beta = fit_affine(*zip(*profile_rows))
        with open(os.path.join(args.out_dir, "profile.tsv"), "w") as f:
            f.write(f"# fitted alpha_ms={alpha:.6f} beta_ms={beta:.6f}\n")
            f.write("batch_size\tlatency_ms\n")
            for b, ms in profile_rows:
                f.write(f"{b}\t{ms:.6f}\n")
        print(f"fitted profile: l(b) = {alpha:.3f}*b + {beta:.3f} ms")

    print(f"wrote {len(manifest_rows)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
