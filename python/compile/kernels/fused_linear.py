"""Layer-1 Pallas kernel: fused tiled ``matmul + bias + activation``.

This is the dense hot path of the served model (the batched-GEMM that
dominates DNN inference work and that batching amortizes, per Symphony
§2.1). The kernel is written TPU-style even though we validate it under
``interpret=True`` on CPU:

* the grid tiles the output into ``(bm, bn)`` blocks (MXU-shaped,
  multiples of 128 when the problem is large enough);
* the K axis is the innermost grid dimension, accumulating partial
  products into the resident output tile in f32 (the MXU accumulation
  dtype) — the Pallas revisit-the-same-block idiom, equivalent to a VMEM
  accumulator;
* ``BlockSpec`` expresses the HBM->VMEM schedule that a CUDA
  implementation would express with threadblocks + shared memory
  (DESIGN.md §Hardware-Adaptation).

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, n_k: int, activation: str):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero_acc():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-style: f32 accumulation regardless of input dtype.
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...].astype(jnp.float32)
        if activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        elif activation == "gelu":
            acc = jax.nn.gelu(acc)
        elif activation != "none":
            raise ValueError(f"unknown activation {activation!r}")
        o_ref[...] = acc


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (prefers MXU multiples)."""
    if dim <= target:
        return dim
    for cand in (target, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= target and dim % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bn", "bk"))
def fused_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: str = "relu",
    bm: Optional[int] = None,
    bn: Optional[int] = None,
    bk: Optional[int] = None,
) -> jax.Array:
    """``activation(x @ w + b)`` as a Pallas kernel.

    Args:
      x: ``[M, K]`` input activations.
      w: ``[K, N]`` weights.
      b: ``[N]`` bias.
      activation: ``"relu" | "gelu" | "none"``.
      bm/bn/bk: tile sizes; defaults pick MXU-friendly divisors (<=128).

    Returns:
      ``[M, N]`` float32 output.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"shape mismatch: x[{m},{k}] @ w[{k2},{n}]")
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")

    bm = bm or _pick_block(m, 128)
    bn = bn or _pick_block(n, 128)
    bk = bk or _pick_block(k, 128)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"tiles ({bm},{bn},{bk}) must divide ({m},{n},{k})")
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_linear_kernel, n_k=n_k, activation=activation),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(x, w, b)


def vmem_footprint_bytes(
    bm: int, bn: int, bk: int, dtype_bytes: int = 4, double_buffer: bool = True
) -> int:
    """Structural VMEM estimate for one grid step (DESIGN.md §Perf).

    x-tile + w-tile + bias-tile (double-buffered for the HBM->VMEM
    pipeline) + resident f32 output/accumulator tile.
    """
    streams = bm * bk * dtype_bytes + bk * bn * dtype_bytes + bn * dtype_bytes
    if double_buffer:
        streams *= 2
    return streams + bm * bn * 4


def mxu_utilization_estimate(bm: int, bn: int, bk: int) -> float:
    """Fraction of 128x128x128 MXU lanes busy per issue, from tile padding.

    Tiles that are not multiples of 128 waste systolic-array lanes; this is
    the padding-efficiency upper bound used in EXPERIMENTS.md §Perf.
    """

    def eff(blk: int) -> float:
        padded = ((blk + 127) // 128) * 128
        return blk / padded

    return eff(bm) * eff(bn) * eff(bk)
