"""Pure-jnp correctness oracles for the Pallas kernels and the model.

These are the ground truth against which ``pytest python/tests`` checks
every kernel (hypothesis sweeps shapes/dtypes) and the full forward pass.
No Pallas, no tiling — just the textbook math.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_linear_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, activation: str = "relu"
) -> jax.Array:
    """``activation(x @ w + b)`` in plain jnp (f32 accumulation)."""
    out = (
        jnp.dot(
            x.astype(jnp.float32),
            w.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        + b.astype(jnp.float32)
    )
    if activation == "relu":
        return jnp.maximum(out, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(out)
    if activation == "none":
        return out
    raise ValueError(f"unknown activation {activation!r}")


def softmax_ref(x: jax.Array) -> jax.Array:
    """Row-wise stable softmax in plain jnp."""
    x = x.astype(jnp.float32)
    x_max = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - x_max)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def tiny_cnn_ref(params, images: jax.Array) -> jax.Array:
    """Reference forward pass of the served model (see model.py).

    Mirrors model.tiny_cnn_forward but with jnp-only dense layers +
    softmax instead of the Pallas kernels.
    """
    x = images.astype(jnp.float32)
    for conv in params["convs"]:
        x = jax.lax.conv_general_dilated(
            x,
            conv["w"],
            window_strides=(2, 2),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        x = jnp.maximum(x + conv["b"], 0.0)
    x = jnp.mean(x, axis=(1, 2))  # global average pool -> [B, C]
    x = fused_linear_ref(x, params["fc1"]["w"], params["fc1"]["b"], activation="relu")
    logits = fused_linear_ref(
        x, params["fc2"]["w"], params["fc2"]["b"], activation="none"
    )
    return softmax_ref(logits)
