"""Layer-1 Pallas kernel: row-wise numerically-stable softmax.

The classifier head of the served model. One grid step owns a block of
rows; the full feature axis stays resident in VMEM (class counts are
small for the serving workloads Symphony targets), so the max/sum
reductions are single-pass — the TPU analogue of a warp-level softmax.

``interpret=True`` for the same reason as ``fused_linear``: the CPU PJRT
plugin cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    x_max = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - x_max)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def _pick_rows(m: int, target: int = 128) -> int:
    if m <= target:
        return m
    for cand in (target, 64, 32, 16, 8, 4, 2, 1):
        if m % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("block_rows",))
def softmax(x: jax.Array, *, block_rows: Optional[int] = None) -> jax.Array:
    """Row-wise softmax over the last axis of a 2-D array.

    Args:
      x: ``[M, N]`` logits.
      block_rows: rows per grid step (default: divisor of M, <=128).

    Returns:
      ``[M, N]`` float32 probabilities summing to 1 along the last axis.
    """
    if x.ndim != 2:
        raise ValueError(f"softmax expects 2-D input, got {x.shape}")
    m, n = x.shape
    bm = block_rows or _pick_rows(m)
    if m % bm:
        raise ValueError(f"block_rows {bm} must divide {m}")

    return pl.pallas_call(
        _softmax_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x)
