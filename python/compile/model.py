"""Layer-2 JAX model: ``TinyCNN``, the real DNN served end-to-end.

A small ResNet-style image classifier (strided convs + global average
pool + Pallas dense head + Pallas softmax). It is deliberately modest —
the point of this repo is the *scheduler*, and the model exists so the
runtime executes a genuine compiled DNN per batch rather than a sleep.
Its latency profile still has the affine ℓ(b) = αb + β shape that
Symphony's deferred batch scheduling exploits (aot.py measures it into
``artifacts/profile.tsv``).

Build-time only: ``aot.py`` lowers `tiny_cnn_forward` once per batch size
to HLO text. Python never runs on the request path.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from .kernels.fused_linear import fused_linear
from .kernels.softmax import softmax

# Architecture constants (kept MXU/VMEM-friendly: dense dims multiple of 64).
IMAGE_SIZE = 32
IN_CHANNELS = 3
CONV_CHANNELS: List[int] = [16, 32, 64]
HIDDEN = 128
NUM_CLASSES = 64


def init_params(seed: int = 0) -> Dict:
    """He-initialized parameters for TinyCNN."""
    key = jax.random.PRNGKey(seed)
    params: Dict = {"convs": []}
    cin = IN_CHANNELS
    for cout in CONV_CHANNELS:
        key, kw, kb = jax.random.split(key, 3)
        fan_in = 3 * 3 * cin
        params["convs"].append(
            {
                "w": jax.random.normal(kw, (3, 3, cin, cout), jnp.float32)
                * jnp.sqrt(2.0 / fan_in),
                "b": jnp.zeros((cout,), jnp.float32),
            }
        )
        cin = cout
    key, k1, k2 = jax.random.split(key, 3)
    params["fc1"] = {
        "w": jax.random.normal(k1, (cin, HIDDEN), jnp.float32) * jnp.sqrt(2.0 / cin),
        "b": jnp.zeros((HIDDEN,), jnp.float32),
    }
    params["fc2"] = {
        "w": jax.random.normal(k2, (HIDDEN, NUM_CLASSES), jnp.float32)
        * jnp.sqrt(2.0 / HIDDEN),
        "b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }
    return params


def tiny_cnn_forward(params: Dict, images: jax.Array) -> jax.Array:
    """Forward pass: ``[B, 32, 32, 3]`` images -> ``[B, NUM_CLASSES]`` probs.

    Convs/pool are plain XLA ops (they fuse well already); the dense head
    and softmax go through the Layer-1 Pallas kernels so the whole stack —
    Pallas -> JAX -> HLO -> Rust/PJRT — is exercised by every batch.
    """
    x = images.astype(jnp.float32)
    for conv in params["convs"]:
        x = jax.lax.conv_general_dilated(
            x,
            conv["w"],
            window_strides=(2, 2),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        x = jnp.maximum(x + conv["b"], 0.0)
    x = jnp.mean(x, axis=(1, 2))  # global average pool -> [B, C]
    x = fused_linear(x, params["fc1"]["w"], params["fc1"]["b"], activation="relu")
    logits = fused_linear(
        x, params["fc2"]["w"], params["fc2"]["b"], activation="none"
    )
    return softmax(logits)


def batched_entry(params: Dict, batch_size: int):
    """Returns (fn, example_args) for AOT lowering at a fixed batch size.

    Weights are closed over (baked into the HLO as constants) so the Rust
    runtime feeds only the input batch — matching a serving deployment
    where weights live on the accelerator.
    """
    spec = jax.ShapeDtypeStruct(
        (batch_size, IMAGE_SIZE, IMAGE_SIZE, IN_CHANNELS), jnp.float32
    )

    def fn(images):
        return (tiny_cnn_forward(params, images),)

    return fn, (spec,)
