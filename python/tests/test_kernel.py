"""Pallas kernels vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes/dtypes/tilings; every case asserts allclose
against ref.py. These run at build time (`make test`); nothing here is on
the serving path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_linear import (
    fused_linear,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import fused_linear_ref, softmax_ref
from compile.kernels.softmax import softmax

jax.config.update("jax_platform_name", "cpu")

DIMS = st.sampled_from([1, 2, 3, 4, 5, 8, 16, 24, 32, 64, 96, 128])
ACTIVATIONS = st.sampled_from(["relu", "gelu", "none"])
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestFusedLinear:
    @settings(max_examples=40, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, act=ACTIVATIONS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, k, n, act, dtype, seed):
        k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x, w = _rand(k0, (m, k), dtype), _rand(k1, (k, n), dtype)
        b = _rand(k2, (n,), dtype)
        out = fused_linear(x, w, b, activation=act)
        ref = fused_linear_ref(x, w, b, activation=act)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)
        assert out.dtype == jnp.float32

    @settings(max_examples=15, deadline=None)
    @given(
        bm=st.sampled_from([1, 2, 4, 8]),
        bn=st.sampled_from([2, 4, 8, 16]),
        bk=st.sampled_from([2, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_explicit_tilings(self, bm, bn, bk, seed):
        """Any tiling that divides the problem gives identical results."""
        m, k, n = 8, 16, 16
        k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x, w = _rand(k0, (m, k), jnp.float32), _rand(k1, (k, n), jnp.float32)
        b = _rand(k2, (n,), jnp.float32)
        out = fused_linear(x, w, b, bm=bm, bn=bn, bk=bk)
        ref = fused_linear_ref(x, w, b)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_relu_clamps(self):
        x = -jnp.ones((4, 4), jnp.float32)
        w = jnp.eye(4, dtype=jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        out = fused_linear(x, w, b, activation="relu")
        assert np.all(np.asarray(out) == 0.0)

    def test_none_activation_passes_negatives(self):
        x = -jnp.ones((4, 4), jnp.float32)
        w = jnp.eye(4, dtype=jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        out = fused_linear(x, w, b, activation="none")
        np.testing.assert_allclose(out, np.asarray(x), rtol=1e-6)

    def test_shape_mismatch_raises(self):
        x = jnp.zeros((2, 3))
        w = jnp.zeros((4, 5))
        b = jnp.zeros((5,))
        with pytest.raises(ValueError, match="shape mismatch"):
            fused_linear(x, w, b)

    def test_bad_tile_raises(self):
        x = jnp.zeros((4, 4), jnp.float32)
        w = jnp.zeros((4, 4), jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        with pytest.raises(ValueError, match="must divide"):
            fused_linear(x, w, b, bm=3)

    def test_vmem_estimate_monotone(self):
        small = vmem_footprint_bytes(128, 128, 128)
        big = vmem_footprint_bytes(256, 256, 256)
        assert small < big
        # Default serving tiles fit the 16 MiB VMEM budget comfortably.
        assert small < 16 * 1024 * 1024

    def test_mxu_estimate_bounds(self):
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert 0.0 < mxu_utilization_estimate(32, 64, 128) < 1.0


class TestSoftmax:
    @settings(max_examples=40, deadline=None)
    @given(m=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, n, dtype, seed):
        x = _rand(jax.random.PRNGKey(seed), (m, n), dtype)
        out = softmax(x)
        np.testing.assert_allclose(out, softmax_ref(x), rtol=1e-5, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_rows_sum_to_one(self, m, n, seed):
        x = _rand(jax.random.PRNGKey(seed), (m, n), jnp.float32)
        out = np.asarray(softmax(x))
        np.testing.assert_allclose(out.sum(-1), np.ones(m), rtol=1e-5)
        assert np.all(out >= 0.0)

    def test_stability_large_logits(self):
        """No overflow for logits around +-1e4 (the stable-max trick)."""
        x = jnp.array([[1e4, 1e4 - 1.0], [-1e4, -1e4 + 1.0]], jnp.float32)
        out = np.asarray(softmax(x))
        assert np.all(np.isfinite(out))
        np.testing.assert_allclose(out.sum(-1), [1.0, 1.0], rtol=1e-5)

    def test_block_rows_tiling(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 8), jnp.float32)
        full = softmax(x)
        tiled = softmax(x, block_rows=4)
        np.testing.assert_allclose(full, tiled, rtol=1e-6)

    def test_rejects_non_2d(self):
        with pytest.raises(ValueError, match="2-D"):
            softmax(jnp.zeros((2, 2, 2)))
