"""Layer-2 model tests: shapes, numerics vs ref, AOT round-trip."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_lib
from compile.aot import fit_affine, to_hlo_text
from compile.kernels.ref import tiny_cnn_ref

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def params():
    return model_lib.init_params(seed=0)


class TestTinyCNN:
    @pytest.mark.parametrize("batch", [1, 2, 4, 8])
    def test_output_shape_and_distribution(self, params, batch):
        x = jax.random.normal(
            jax.random.PRNGKey(batch),
            (batch, model_lib.IMAGE_SIZE, model_lib.IMAGE_SIZE, model_lib.IN_CHANNELS),
        )
        out = np.asarray(model_lib.tiny_cnn_forward(params, x))
        assert out.shape == (batch, model_lib.NUM_CLASSES)
        assert np.all(np.isfinite(out))
        np.testing.assert_allclose(out.sum(-1), np.ones(batch), rtol=1e-5)

    @pytest.mark.parametrize("batch", [1, 3, 8])
    def test_matches_pure_jnp_ref(self, params, batch):
        """Pallas head == jnp head through the full network."""
        x = jax.random.normal(
            jax.random.PRNGKey(17 + batch),
            (batch, model_lib.IMAGE_SIZE, model_lib.IMAGE_SIZE, model_lib.IN_CHANNELS),
        )
        out = model_lib.tiny_cnn_forward(params, x)
        ref = tiny_cnn_ref(params, x)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)

    def test_batch_consistency(self, params):
        """Row i of a batched forward == forward of row i alone."""
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 32, 32, 3))
        batched = np.asarray(model_lib.tiny_cnn_forward(params, x))
        for i in range(4):
            single = np.asarray(model_lib.tiny_cnn_forward(params, x[i : i + 1]))
            np.testing.assert_allclose(batched[i], single[0], rtol=1e-4, atol=1e-5)

    def test_deterministic_params(self):
        p1 = model_lib.init_params(seed=42)
        p2 = model_lib.init_params(seed=42)
        np.testing.assert_array_equal(p1["fc1"]["w"], p2["fc1"]["w"])


class TestAot:
    def test_lower_to_hlo_text(self, params):
        fn, specs = model_lib.batched_entry(params, 2)
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        assert "HloModule" in text
        # Weights are baked in: entry takes exactly one arg (the images).
        assert "entry_computation_layout={(f32[2,32,32,3]" in text
        assert "parameter(0)" in text

    def test_entry_runs(self, params):
        fn, specs = model_lib.batched_entry(params, 2)
        x = jnp.zeros(specs[0].shape, jnp.float32)
        (out,) = jax.jit(fn)(x)
        assert out.shape == (2, model_lib.NUM_CLASSES)

    def test_fit_affine_recovers_profile(self):
        alpha, beta = fit_affine([1, 2, 4, 8], [1.5 * b + 3.0 for b in [1, 2, 4, 8]])
        assert abs(alpha - 1.5) < 1e-9
        assert abs(beta - 3.0) < 1e-9

    def test_manifest_written(self, params, tmp_path=None):
        """End-to-end aot.main() on a tiny batch list writes all outputs."""
        import sys
        from compile import aot

        with tempfile.TemporaryDirectory() as d:
            argv = sys.argv
            sys.argv = ["aot", "--out-dir", d, "--batch-sizes", "1,2", "--skip-profile"]
            try:
                aot.main()
            finally:
                sys.argv = argv
            assert os.path.exists(os.path.join(d, "model_b1.hlo.txt"))
            assert os.path.exists(os.path.join(d, "model_b2.hlo.txt"))
            manifest = open(os.path.join(d, "manifest.tsv")).read()
            assert "model_b1.hlo.txt" in manifest
            assert manifest.startswith("batch_size\t")
