//! `cargo bench --bench bench_ablations` — ablations of the design
//! choices DESIGN.md calls out:
//!
//! 1. overload shedding (drop-head batch gathering) on/off — the
//!    flat-top property (§3.5);
//! 2. the network-delay budget Symphony subtracts from its windows
//!    (§5.6) — too small violates SLOs under jitter, too large wastes
//!    batch headroom;
//! 3. Shepherd with and without 3× preemption (§2.2);
//! 4. batch-size caps on the deferred scheduler.

use symphony::core::model_zoo::{self, GpuKind};
use symphony::core::time::Micros;
use symphony::harness::{GoodputExperiment, SystemKind};
use symphony::scheduler::deferred::{DeferredConfig, DeferredScheduler};
use symphony::scheduler::shepherd::ShepherdScheduler;
use symphony::sim::NetworkModel;
use symphony::util::table::{banner, f1, pct, Table};

fn main() {
    banner("Ablation 1: overload shedding (flat-top, §3.5)");
    {
        let models = model_zoo::resnet_like_variants(10, 100.0, GpuKind::Gtx1080Ti);
        let exp = GoodputExperiment::new(models, 24).sim_secs(5.0);
        let mut t = Table::new(vec!["shed", "offered_rps", "goodput", "bad_rate"]);
        for shed in [true, false] {
            for load in [9_000.0, 15_000.0, 24_000.0] {
                let m = exp.run_at(load, &|e: &GoodputExperiment| {
                    DeferredScheduler::new(
                        e.models.iter().map(|mm| mm.profile).collect(),
                        e.num_gpus,
                        DeferredConfig {
                            shed,
                            ..Default::default()
                        },
                    )
                });
                t.row(vec![
                    shed.to_string(),
                    f1(load),
                    f1(m.goodput()),
                    pct(m.bad_fraction()),
                ]);
            }
        }
        t.emit("ablation_shedding");
    }

    banner("Ablation 2: network-delay budget vs actual jitter (§5.6)");
    {
        let models = model_zoo::resnet_like_variants(10, 25.0, GpuKind::Gtx1080Ti);
        let mut t = Table::new(vec!["budget_us", "network", "goodput"]);
        for (net, label) in [
            (NetworkModel::Rdma, "rdma"),
            (
                NetworkModel::Constant {
                    latency: Micros(2_000),
                },
                "const2ms",
            ),
        ] {
            for budget_us in [0u64, 33, 500, 2_000, 5_000] {
                let exp = GoodputExperiment::new(models.clone(), 16)
                    .network(net)
                    .sim_secs(4.0);
                let g = exp
                    .goodput(|e| {
                        let cfg = DeferredConfig {
                            net_bound: Micros(budget_us),
                            ..Default::default()
                        };
                        DeferredScheduler::new(
                            e.models.iter().map(|mm| mm.profile).collect(),
                            e.num_gpus,
                            cfg,
                        )
                    })
                    .goodput;
                t.row(vec![budget_us.to_string(), label.to_string(), f1(g)]);
            }
        }
        t.emit("ablation_netbudget");
    }

    banner("Ablation 3: Shepherd preemption on/off (§2.2)");
    {
        let models = model_zoo::resnet_like_variants(8, 25.0, GpuKind::Gtx1080Ti);
        let mut t = Table::new(vec!["preemption", "goodput", "wasted_batches"]);
        for pre in [true, false] {
            let exp = GoodputExperiment::new(models.clone(), 16)
                .gamma_shape(0.2)
                .sim_secs(5.0);
            let res = exp.goodput(|e| {
                let mut s = ShepherdScheduler::new(
                    e.models.iter().map(|mm| mm.profile).collect(),
                    e.num_gpus,
                );
                s.preemption = pre;
                s
            });
            t.row(vec![
                pre.to_string(),
                f1(res.goodput),
                res.metrics.preempted_batches.to_string(),
            ]);
        }
        t.emit("ablation_preemption");
    }

    banner("Ablation 4: deferred batch-size cap");
    {
        let model = model_zoo::resnet50_table2();
        let mut t = Table::new(vec!["max_batch", "goodput"]);
        for cap in [0u32, 4, 8, 16, 32] {
            let exp = GoodputExperiment::new(vec![model.clone()], 8).sim_secs(5.0);
            let g = exp
                .goodput(|e| {
                    DeferredScheduler::new(
                        e.models.iter().map(|mm| mm.profile).collect(),
                        e.num_gpus,
                        DeferredConfig {
                            max_batch: cap,
                            ..Default::default()
                        },
                    )
                })
                .goodput;
            t.row(vec![
                if cap == 0 { "none".into() } else { cap.to_string() },
                f1(g),
            ]);
        }
        t.emit("ablation_batchcap");
    }
}
