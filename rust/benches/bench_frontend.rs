//! `cargo bench --bench bench_frontend` — end-to-end frontend ingest
//! throughput (§4.2 step ②, the Fig 13-left request-rate claim): how
//! fast requests travel submit → ingest shard → model worker → rank
//! shard → dispatch, swept over model count × producer threads × burst
//! size, with an in-bench before/after probe comparing the seed's
//! per-request `Coordinator::submit` path against the batched
//! `IngestHandle::submit_batch` path.
//!
//! Two numbers per run:
//! * `submit_per_sec` — producer-side ingest rate (how fast the
//!   frontend tier *accepts* work; the number the sharded ingest +
//!   worker-pool rebuild targets);
//! * `e2e_per_sec` — submit → fully-accounted rate (every request
//!   dispatched to a backend sink or dropped by the scheduler). This
//!   includes the deferred-scheduling dwell (~SLO), so it is a floor,
//!   not a scheduler ceiling.
//!
//! Results print as a table, mirror to `results/bench_frontend.tsv`,
//! and are written machine-readable to `BENCH_frontend.json` at the
//! repo root — consumed by CI's regression check
//! (`.github/compare_bench.py`) next to `BENCH_hotpath.json`.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use symphony::coordinator::{Completion, Coordinator, CoordinatorConfig, ToBackend};
use symphony::core::profile::LatencyProfile;
use symphony::core::time::Micros;
use symphony::core::types::{ModelId, Request, RequestId};
use symphony::util::table::{banner, Table};

/// Submission mode for one run.
#[derive(Clone, Copy)]
enum Mode {
    /// The seed path: one `Coordinator::submit` per request.
    PerRequest,
    /// The batched path: `IngestHandle::submit_batch` every `B`
    /// requests.
    Batched(usize),
}

impl Mode {
    fn label(&self) -> String {
        match self {
            Mode::PerRequest => "per-request".to_string(),
            Mode::Batched(b) => format!("batch{b}"),
        }
    }

    fn key(&self) -> String {
        match self {
            Mode::PerRequest => "perreq".to_string(),
            Mode::Batched(b) => format!("b{b}"),
        }
    }
}

struct RunOut {
    submit_per_sec: f64,
    e2e_per_sec: f64,
}

/// One frontend run: `producers` threads push `n_total` requests
/// (round-robin over `n_models`) into a live coordinator backed by
/// counting sinks; done when every request is dispatched or dropped.
fn frontend_run(n_models: usize, producers: usize, mode: Mode, n_total: u64) -> RunOut {
    let num_gpus = 32usize;
    // Tiny ℓ(b) so execution windows never bottleneck the frontend.
    let profile = LatencyProfile::new(0.02, 0.05);
    let slo = Micros::from_millis_f64(25.0);

    // Backend sinks: count dispatched requests, discard the batches.
    let accounted = Arc::new(AtomicU64::new(0));
    let mut backend_txs = Vec::new();
    let mut sink_handles = Vec::new();
    for _ in 0..num_gpus {
        let (tx, rx) = channel::<ToBackend>();
        backend_txs.push(tx);
        let acc = accounted.clone();
        sink_handles.push(std::thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    ToBackend::Execute { requests, .. } => {
                        acc.fetch_add(requests.len() as u64, Ordering::Relaxed);
                    }
                    ToBackend::Shutdown => break,
                }
            }
        }));
    }
    // Drops also account (scheduler-shed requests are "done" too).
    let (comp_tx, comp_rx) = channel::<Completion>();
    let comp_handle = {
        let acc = accounted.clone();
        std::thread::spawn(move || {
            while let Ok(c) = comp_rx.recv() {
                if let Completion::Dropped(rs) = c {
                    acc.fetch_add(rs.len() as u64, Ordering::Relaxed);
                }
            }
        })
    };

    let coord = Coordinator::spawn(
        CoordinatorConfig {
            profiles: vec![profile; n_models],
            num_gpus,
            initial_gpus: None,
            rank_shards: 4,
            ingest_shards: producers.clamp(1, 8),
            model_workers: None,
            net_bound: Micros::ZERO,
            exec_margin: Micros::ZERO,
            remote_ranks: Vec::new(),
            // CI's second smoke pass sets SYMPHONY_BUSY_POLL=1 to run
            // the same sweep with spinning ring consumers (the
            // `--busy-poll` serve flag); default is the parking drain.
            busy_poll: std::env::var_os("SYMPHONY_BUSY_POLL").is_some(),
            pin_cores: std::env::var_os("SYMPHONY_PIN_CORES").is_some(),
            reconnect: symphony::net::client::ReconnectPolicy::default(),
            fault_plan: symphony::net::faults::FaultPlan::none(),
        },
        backend_txs.clone(),
        comp_tx,
    );
    let clock = coord.clock;
    let coord = Arc::new(coord);

    // Producers: each submits its share as fast as the channels accept.
    let per = n_total / producers as u64;
    let t0 = Instant::now();
    let mut feeders = Vec::new();
    for p in 0..producers as u64 {
        let coord = coord.clone();
        let handle = coord.ingest_handle();
        feeders.push(std::thread::spawn(move || {
            let mut buf: Vec<Request> = Vec::new();
            for k in 0..per {
                let i = p * per + k;
                let now = clock.now();
                let r = Request {
                    id: RequestId(i),
                    model: ModelId((i % n_models as u64) as u32),
                    arrival: now,
                    deadline: now + slo,
                };
                match mode {
                    Mode::PerRequest => coord.submit(r),
                    Mode::Batched(b) => {
                        buf.push(r);
                        if buf.len() >= b {
                            handle.submit_batch(&buf);
                            buf.clear();
                        }
                    }
                }
            }
            if !buf.is_empty() {
                handle.submit_batch(&buf);
            }
        }));
    }
    for f in feeders {
        let _ = f.join();
    }
    let submitted = per * producers as u64;
    let submit_secs = t0.elapsed().as_secs_f64().max(1e-9);

    // Wait until every submitted request is dispatched, dropped by the
    // scheduler, or shed at a full ingest ring (`dropped_submits`, the
    // bounded rings' documented full-queue policy for request traffic).
    let deadline = Instant::now() + Duration::from_secs(30);
    while accounted.load(Ordering::Relaxed) + coord.dropped_submits() < submitted
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(1));
    }
    let e2e_secs = t0.elapsed().as_secs_f64().max(1e-9);
    let got = accounted.load(Ordering::Relaxed) + coord.dropped_submits();
    if got < submitted {
        eprintln!(
            "warn: only {got}/{submitted} requests accounted before timeout \
             (m={n_models} p={producers} {})",
            mode.label()
        );
    }

    let coord = Arc::try_unwrap(coord).ok().expect("sole owner");
    coord.shutdown();
    for tx in &backend_txs {
        let _ = tx.send(ToBackend::Shutdown);
    }
    for h in sink_handles {
        let _ = h.join();
    }
    let _ = comp_handle.join();

    RunOut {
        submit_per_sec: submitted as f64 / submit_secs,
        e2e_per_sec: got as f64 / e2e_secs,
    }
}

fn main() {
    banner("Frontend ingest throughput (submit → dispatch, §4.2)");
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(8);
    println!("(host has {cores} cores; 32 in-process GPU sinks, 4 rank shards)");

    let n_total = 32_768u64;
    let mut table = Table::new(vec![
        "models",
        "producers",
        "mode",
        "submit_per_sec",
        "e2e_per_sec",
        "speedup_vs_perreq",
    ]);
    let mut json: Vec<(String, f64)> = Vec::new();
    for &n_models in &[1usize, 16, 256] {
        for &producers in &[1usize, 4, 16] {
            // The seed's per-request path is the probe baseline for
            // this (models × producers) point.
            let base = frontend_run(n_models, producers, Mode::PerRequest, n_total);
            let mut emit = |mode: Mode, out: &RunOut, base_submit: f64| {
                let name = format!("frontend_m{n_models}_p{producers}_{}", mode.key());
                table.row(vec![
                    n_models.to_string(),
                    producers.to_string(),
                    mode.label(),
                    format!("{:.0}", out.submit_per_sec),
                    format!("{:.0}", out.e2e_per_sec),
                    format!("{:.2}x", out.submit_per_sec / base_submit.max(1.0)),
                ]);
                json.push((format!("{name}_submit_per_sec"), out.submit_per_sec));
                json.push((format!("{name}_e2e_per_sec"), out.e2e_per_sec));
            };
            emit(Mode::PerRequest, &base, base.submit_per_sec);
            let mut best = 0.0f64;
            for &b in &[1usize, 8, 64] {
                let out = frontend_run(n_models, producers, Mode::Batched(b), n_total);
                best = best.max(out.submit_per_sec);
                emit(Mode::Batched(b), &out, base.submit_per_sec);
            }
            // The before/after probe: best batched ingest rate over the
            // seed's per-request rate at the same sweep point.
            json.push((
                format!("frontend_m{n_models}_p{producers}_probe_speedup"),
                best / base.submit_per_sec.max(1.0),
            ));
        }
    }

    table.emit("bench_frontend");
    write_json(&json);
}

/// Hand-rolled JSON (zero registry deps): `{"bench": ..., "results":
/// {name: value, ...}}` at the repo root, consumed by the CI regression
/// check (`.github/compare_bench.py`).
fn write_json(rows: &[(String, f64)]) {
    let mut s = String::from("{\n  \"bench\": \"bench_frontend\",\n  \"schema\": 1,\n  \"results\": {\n");
    for (i, (k, v)) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(s, "    \"{k}\": {v:.1}{sep}");
    }
    s.push_str("  }\n}\n");
    match std::fs::write("BENCH_frontend.json", &s) {
        Ok(()) => println!("wrote BENCH_frontend.json"),
        Err(e) => eprintln!("warn: could not write BENCH_frontend.json: {e}"),
    }
}
