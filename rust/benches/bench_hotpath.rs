//! `cargo bench --bench bench_hotpath` — microbenchmarks of the hot
//! paths (§Perf): discrete-event engine event rate, deferred-scheduler
//! operation cost, candidate-window math, and the RNG. These are the
//! numbers the EXPERIMENTS.md §Perf iteration log tracks.

use std::time::Instant;

use symphony::core::model_zoo;
use symphony::core::time::Micros;
use symphony::harness::{GoodputExperiment, SystemKind};
use symphony::util::rng::Rng;
use symphony::util::table::{banner, Table};

fn time_it<F: FnMut()>(mut f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

fn main() {
    banner("Hot-path microbenchmarks (§Perf)");
    let mut table = Table::new(vec!["bench", "metric", "value"]);

    // 1. Simulation event rate: 1 model, 8 GPUs, heavy load.
    {
        let model = model_zoo::resnet50_table2();
        let exp = GoodputExperiment::new(vec![model], 8).sim_secs(20.0);
        let mut events = 0u64;
        let secs = time_it(|| {
            let spec = symphony::workload::WorkloadSpec::new(exp.models.clone(), 5_000.0)
                .seed(3);
            let cfg = symphony::sim::SimConfig::new(8, Micros::from_secs_f64(20.0))
                .samples(false);
            let engine = symphony::sim::Engine::new(
                spec.build(),
                SystemKind::Symphony.build(&exp.models, 8, Micros::ZERO),
                cfg,
            );
            let res = engine.run();
            events = res.events_processed
                + res.metrics.total_finished();
        });
        table.row(vec![
            "sim_engine".to_string(),
            "events_per_sec".to_string(),
            format!("{:.0}", events as f64 / secs),
        ]);
        table.row(vec![
            "sim_engine".to_string(),
            "sim_seconds_per_wall_second".to_string(),
            format!("{:.1}", 20.0 / secs),
        ]);
    }

    // 2. Scheduler ops: requests through the deferred scheduler alone
    //    (no engine), measuring per-request handler cost.
    {
        use symphony::scheduler::deferred::{DeferredConfig, DeferredScheduler};
        use symphony::scheduler::Scheduler;
        let profile = symphony::core::profile::LatencyProfile::new(1.0, 5.0);
        let mut sched = DeferredScheduler::new(vec![profile; 16], 64, DeferredConfig::default());
        let n = 2_000_000u64;
        let mut out = Vec::new();
        let secs = time_it(|| {
            for i in 0..n {
                let t = Micros(i * 3);
                out.clear();
                sched.on_request(
                    symphony::core::types::Request {
                        id: symphony::core::types::RequestId(i),
                        model: symphony::core::types::ModelId((i % 16) as u32),
                        arrival: t,
                        deadline: t + Micros(100_000),
                    },
                    t,
                    &mut out,
                );
                // Periodically free a GPU so queues drain.
                if i % 16 == 0 {
                    out.clear();
                    sched.on_gpu_free(
                        symphony::core::types::GpuId((i / 16 % 64) as u32),
                        t,
                        &mut out,
                    );
                }
            }
        });
        table.row(vec![
            "deferred_scheduler".to_string(),
            "on_request_per_sec".to_string(),
            format!("{:.0}", n as f64 / secs),
        ]);
    }

    // 3. Window math: ℓ(b), max_batch_within.
    {
        let p = symphony::core::profile::LatencyProfile::new(1.053, 5.072);
        let n = 10_000_000u64;
        let mut acc = 0u64;
        let secs = time_it(|| {
            for i in 0..n {
                acc = acc.wrapping_add(
                    p.max_batch_within(Micros(10_000 + (i % 50_000))) as u64
                );
            }
        });
        assert!(acc > 0);
        table.row(vec![
            "profile_math".to_string(),
            "max_batch_within_per_sec".to_string(),
            format!("{:.0}", n as f64 / secs),
        ]);
    }

    // 4. RNG throughput (workload generation feeds every sweep).
    {
        let mut rng = Rng::new(1);
        let n = 20_000_000u64;
        let mut acc = 0.0f64;
        let secs = time_it(|| {
            for _ in 0..n {
                acc += rng.exp1();
            }
        });
        assert!(acc > 0.0);
        table.row(vec![
            "rng".to_string(),
            "exp_samples_per_sec".to_string(),
            format!("{:.0}", n as f64 / secs),
        ]);
    }

    table.emit("bench_hotpath");
}
