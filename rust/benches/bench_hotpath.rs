//! `cargo bench --bench bench_hotpath` — microbenchmarks of the hot
//! paths (§Perf): a scheduler-only throughput sweep (models × arrival
//! gaps), discrete-event engine event rate, integer vs seed-float
//! candidate-window math, the RNG, and a `ring_vs_mpsc` inter-thread
//! hop probe (the lock-free fabric's before/after). Results print as a
//! table, mirror
//! to `results/bench_hotpath.tsv`, and are written machine-readable to
//! `BENCH_hotpath.json` at the repo root — the perf trajectory the
//! EXPERIMENTS.md §Perf iteration log and the CI regression check track.

use std::fmt::Write as _;
use std::time::Instant;

use symphony::core::model_zoo;
use symphony::core::profile::{reference, LatencyProfile};
use symphony::core::time::Micros;
use symphony::core::types::{GpuId, ModelId, Request, RequestId};
use symphony::harness::{GoodputExperiment, SystemKind};
use symphony::obs::trace::{self, Stage};
use symphony::scheduler::deferred::{DeferredConfig, DeferredScheduler};
use symphony::scheduler::Scheduler;
use symphony::util::ring::ring;
use symphony::util::rng::Rng;
use symphony::util::table::{banner, Table};

fn time_it<F: FnMut()>(mut f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// Scheduler-only request pump: `n` arrivals spaced `gap_us` apart,
/// round-robin over `n_models`, freeing a GPU every 16th event so the
/// queues drain. Returns events/second through `on_request`.
fn sched_ops(n_models: usize, gap_us: u64, n: u64) -> f64 {
    let gpus = (n_models * 2).clamp(8, 512);
    let profile = LatencyProfile::new(1.0, 5.0);
    let mut sched =
        DeferredScheduler::new(vec![profile; n_models], gpus, DeferredConfig::default());
    let mut out = Vec::with_capacity(64);
    let secs = time_it(|| {
        for i in 0..n {
            let t = Micros(i * gap_us);
            out.clear();
            sched.on_request(
                Request {
                    id: RequestId(i),
                    model: ModelId((i % n_models as u64) as u32),
                    arrival: t,
                    deadline: t + Micros(100_000),
                },
                t,
                &mut out,
            );
            if i % 16 == 0 {
                out.clear();
                sched.on_gpu_free(GpuId((i / 16 % gpus as u64) as u32), t, &mut out);
            }
        }
    });
    n as f64 / secs
}

fn main() {
    banner("Hot-path microbenchmarks (§Perf)");
    let mut table = Table::new(vec!["bench", "metric", "value"]);
    let mut json: Vec<(String, f64)> = Vec::new();

    // 1. Scheduler-only throughput sweep: models × inter-arrival gap
    //    (1 µs ≈ hard overload, 3 µs ≈ saturation, 10 µs ≈ heavy load).
    //    This is the number the paper's "millions of requests per
    //    second" claim (Fig 13) rests on.
    for &n_models in &[1usize, 16, 256] {
        for &gap_us in &[1u64, 3, 10] {
            let n = 1_000_000u64;
            let ops = sched_ops(n_models, gap_us, n);
            let name = format!("sched_m{n_models}_gap{gap_us}");
            table.row(vec![
                name.clone(),
                "requests_per_sec".to_string(),
                format!("{ops:.0}"),
            ]);
            table.row(vec![
                name.clone(),
                "ns_per_op".to_string(),
                format!("{:.1}", 1e9 / ops),
            ]);
            json.push((format!("{name}_per_sec"), ops));
            json.push((format!("{name}_ns_per_op"), 1e9 / ops));
        }
    }

    // 2. Simulation event rate: 1 model, 8 GPUs, heavy load.
    {
        let model = model_zoo::resnet50_table2();
        let exp = GoodputExperiment::new(vec![model], 8).sim_secs(20.0);
        let mut events = 0u64;
        let secs = time_it(|| {
            let spec = symphony::workload::WorkloadSpec::new(exp.models.clone(), 5_000.0)
                .seed(3);
            let cfg = symphony::sim::SimConfig::new(8, Micros::from_secs_f64(20.0))
                .samples(false);
            let engine = symphony::sim::Engine::new(
                spec.build(),
                SystemKind::Symphony.build(&exp.models, 8, Micros::ZERO),
                cfg,
            );
            let res = engine.run();
            events = res.events_processed + res.metrics.total_finished();
        });
        let eps = events as f64 / secs;
        table.row(vec![
            "sim_engine".to_string(),
            "events_per_sec".to_string(),
            format!("{eps:.0}"),
        ]);
        table.row(vec![
            "sim_engine".to_string(),
            "sim_seconds_per_wall_second".to_string(),
            format!("{:.1}", 20.0 / secs),
        ]);
        json.push(("sim_engine_events_per_sec".to_string(), eps));
    }

    // 3. Window math: integer closed form vs the seed float reference —
    //    the same-host before/after proxy recorded with every run.
    {
        let p = LatencyProfile::new(1.053, 5.072);
        let n = 10_000_000u64;
        let mut acc = 0u64;
        let secs_int = time_it(|| {
            for i in 0..n {
                acc = acc
                    .wrapping_add(p.max_batch_within(Micros(10_000 + (i % 50_000))) as u64);
            }
        });
        let secs_flt = time_it(|| {
            for i in 0..n {
                acc = acc.wrapping_add(reference::max_batch_within(
                    1.053,
                    5.072,
                    Micros(10_000 + (i % 50_000)),
                ) as u64);
            }
        });
        assert!(acc > 0);
        let int_ops = n as f64 / secs_int;
        let flt_ops = n as f64 / secs_flt;
        table.row(vec![
            "profile_math_int".to_string(),
            "max_batch_within_per_sec".to_string(),
            format!("{int_ops:.0}"),
        ]);
        table.row(vec![
            "profile_math_float_ref".to_string(),
            "max_batch_within_per_sec".to_string(),
            format!("{flt_ops:.0}"),
        ]);
        table.row(vec![
            "profile_math".to_string(),
            "int_over_float_speedup".to_string(),
            format!("{:.2}", int_ops / flt_ops),
        ]);
        json.push(("profile_math_int_per_sec".to_string(), int_ops));
        json.push(("profile_math_float_ref_per_sec".to_string(), flt_ops));
        json.push(("profile_math_speedup".to_string(), int_ops / flt_ops));
    }

    // 4. RNG throughput (workload generation feeds every sweep).
    {
        let mut rng = Rng::new(1);
        let n = 20_000_000u64;
        let mut acc = 0.0f64;
        let secs = time_it(|| {
            for _ in 0..n {
                acc += rng.exp1();
            }
        });
        assert!(acc > 0.0);
        let ops = n as f64 / secs;
        table.row(vec![
            "rng".to_string(),
            "exp_samples_per_sec".to_string(),
            format!("{ops:.0}"),
        ]);
        json.push(("rng_exp_samples_per_sec".to_string(), ops));
    }

    // 5. Inter-thread hop rate — the `ring_vs_mpsc` probe: one producer
    //    thread pushing u64s through the seed's `std::sync::mpsc`
    //    channel vs the bounded lock-free ring (parking drain, then
    //    busy-polling). This is the per-hop cost every submit → grant
    //    message pays on the fabric, recorded with every run as the
    //    tentpole's before/after evidence.
    {
        let n = 4_000_000u64;
        let hop_mpsc = {
            let (tx, rx) = std::sync::mpsc::channel::<u64>();
            hop_run(n, move |i| tx.send(i).is_ok(), move || rx.recv().ok())
        };
        let hop_ring = |busy_poll: bool| {
            let (tx, rx) = ring::<u64>(4096);
            rx.set_busy_poll(busy_poll);
            hop_run(n, move |i| tx.send(i).is_ok(), move || rx.recv().ok())
        };
        let hop_park = hop_ring(false);
        let hop_spin = hop_ring(true);
        let speedup = hop_park.max(hop_spin) / hop_mpsc.max(1.0);
        for (name, v) in [
            ("hop_mpsc", hop_mpsc),
            ("hop_ring_park", hop_park),
            ("hop_ring_spin", hop_spin),
        ] {
            table.row(vec![
                name.to_string(),
                "msgs_per_sec".to_string(),
                format!("{v:.0}"),
            ]);
            json.push((format!("{name}_per_sec"), v));
        }
        table.row(vec![
            "ring_vs_mpsc".to_string(),
            "speedup".to_string(),
            format!("{speedup:.2}"),
        ]);
        json.push(("ring_vs_mpsc_speedup".to_string(), speedup));
    }

    // 6. Flight-recorder tap cost — the observability tentpole's
    //    overhead evidence. Untraced arm: the recorder is disabled, so
    //    each tap is one relaxed load of the sampling word and a
    //    predictable branch (the cost every production run pays at
    //    every lifecycle hop). Traced arm: a live 1-in-64 session —
    //    sampled taps clone a thread-cached ring sender and `try_send`
    //    into the bounded span ring, shedding on overflow.
    {
        let n = 50_000_000u64;
        assert!(!trace::enabled(), "bench process must start untraced");
        let secs_off = time_it(|| {
            for i in 0..n {
                trace::req_event(Stage::Submit, RequestId(std::hint::black_box(i)));
            }
        });
        let session = trace::install(64).expect("recorder free in a fresh bench process");
        assert!(trace::enabled(), "sampled arm must actually trace");
        let secs_on = time_it(|| {
            for i in 0..n {
                trace::req_event(Stage::Submit, RequestId(std::hint::black_box(i)));
            }
        });
        let dump = session.finish();
        assert!(
            dump.events.len() as u64 + dump.shed > 0,
            "sampled arm recorded nothing"
        );
        let off_ops = n as f64 / secs_off;
        let on_ops = n as f64 / secs_on;
        for (name, v) in [
            ("trace_disabled", off_ops),
            ("trace_sampled_1in64", on_ops),
        ] {
            table.row(vec![
                name.to_string(),
                "events_per_sec".to_string(),
                format!("{v:.0}"),
            ]);
            table.row(vec![
                name.to_string(),
                "ns_per_event".to_string(),
                format!("{:.2}", 1e9 / v),
            ]);
        }
        table.row(vec![
            "trace_tap".to_string(),
            "sampled_over_disabled_cost".to_string(),
            format!("{:.2}", off_ops / on_ops.max(1.0)),
        ]);
        json.push(("trace_disabled_events_per_sec".to_string(), off_ops));
        json.push(("trace_sampled_events_per_sec".to_string(), on_ops));
    }

    table.emit("bench_hotpath");
    write_json(&json);
}

/// One producer thread pushing `0..n` through `send` while this thread
/// drains with `recv`; returns messages/second over the whole hop.
fn hop_run(
    n: u64,
    send: impl Fn(u64) -> bool + Send + 'static,
    recv: impl FnMut() -> Option<u64>,
) -> f64 {
    let t0 = Instant::now();
    let producer = std::thread::spawn(move || {
        for i in 0..n {
            if !send(i) {
                break;
            }
        }
    });
    let mut recv = recv;
    let mut acc = 0u64;
    for _ in 0..n {
        match recv() {
            Some(v) => acc = acc.wrapping_add(v),
            None => break,
        }
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    producer.join().expect("hop producer");
    assert!(acc > 0, "hop bench must move data");
    n as f64 / secs
}

/// Hand-rolled JSON (zero registry deps): `{"bench": ..., "results":
/// {name: value, ...}}` at the repo root, consumed by the CI regression
/// check (`.github/compare_bench.py`).
fn write_json(rows: &[(String, f64)]) {
    let mut s = String::from("{\n  \"bench\": \"bench_hotpath\",\n  \"schema\": 1,\n  \"results\": {\n");
    for (i, (k, v)) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(s, "    \"{k}\": {v:.1}{sep}");
    }
    s.push_str("  }\n}\n");
    match std::fs::write("BENCH_hotpath.json", &s) {
        Ok(()) => println!("wrote BENCH_hotpath.json"),
        Err(e) => eprintln!("warn: could not write BENCH_hotpath.json: {e}"),
    }
}
