//! `cargo bench --bench bench_wire` — wire-rate microbenchmarks for
//! the `net/` rank-coordination tier, the numbers behind the "scalable,
//! low-latency, fine-grained coordination" claim once the rank tier
//! leaves the process:
//!
//! * `wire_codec_roundtrips_per_sec` — pure encode→decode of a
//!   `GpuBusyUntil` up-frame (no socket): the codec's ceiling.
//! * `wire_frames_per_sec` / `wire_frames_per_write` — loopback framed
//!   TCP throughput through the coalescing writer: how many control
//!   frames per second one connection moves, and how many frames each
//!   `write` syscall carried (the `InboxBatch` analogue on the wire).
//! * `wire_rtt_*` — loopback submit→grant round trip against a real
//!   `rank-server` session: candidate registration frame up, `Granted`
//!   frame down, measured at the client. p50/p99 in µs plus a
//!   round-trips/sec rate for the CI regression check (which only
//!   compares `*_per_sec` metrics).
//!
//! Results print as a table and land machine-readable in
//! `BENCH_wire.json` at the repo root (consumed by
//! `.github/compare_bench.py`, artifact-uploaded by CI). Loopback
//! numbers are the lower bound on wire cost; the EXPERIMENTS.md §Wire
//! coordination table adds host-pair rows once run on real hardware.

use std::fmt::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use symphony::coordinator::messages::{CandWindow, ToModel};
use symphony::coordinator::{Clock, ShardLiveness};
use symphony::core::time::Micros;
use symphony::core::types::{GpuId, ModelId};
use symphony::net::client::{DisconnectCounts, ReconnectPolicy, RemoteRank};
use symphony::net::codec::{self, WireToRank};
use symphony::net::faults::FaultPlan;
use symphony::net::server::{RankServer, RankServerConfig};
use symphony::net::transport::{spawn_writer, FrameReader};
use symphony::util::ring::ring;
use symphony::util::stats::percentile;
use symphony::util::table::{banner, Table};

/// Pure codec throughput: encode + decode round trips per second.
fn bench_codec(iters: u64) -> f64 {
    let msg = WireToRank::GpuBusyUntil {
        gpu: GpuId(7),
        free_at: Micros(123_456_789),
    };
    let mut buf = Vec::with_capacity(32);
    let t0 = Instant::now();
    let mut sink = 0u64;
    for i in 0..iters {
        buf.clear();
        codec::encode_up((i % 8) as u16, &msg, &mut buf);
        let (shard, decoded) = codec::decode_up(&buf).expect("roundtrip");
        if let WireToRank::GpuBusyUntil { gpu, .. } = decoded {
            sink = sink.wrapping_add(shard as u64 + gpu.0 as u64);
        }
    }
    assert!(sink > 0, "keep the loop alive");
    iters as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

/// Loopback frames/s through the coalescing writer, plus the observed
/// frames-per-syscall coalescing factor.
fn bench_frames(n: u64) -> (f64, f64) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let reader_h = std::thread::spawn(move || {
        let (stream, _) = listener.accept().expect("accept");
        stream.set_nodelay(true).unwrap();
        let mut reader = FrameReader::new(stream);
        let mut got = 0u64;
        while let Ok(Some(frame)) = reader.next_frame() {
            // Decode to keep the measurement honest end to end.
            let _ = codec::decode_up(frame).expect("valid frame");
            got += 1;
        }
        got
    });
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_nodelay(true).unwrap();
    let (tx, writer_h) = spawn_writer(stream).expect("spawn writer");
    let msg = WireToRank::GpuBusyUntil {
        gpu: GpuId(3),
        free_at: Micros(1),
    };
    let t0 = Instant::now();
    let mut buf = Vec::with_capacity(32);
    for i in 0..n {
        buf.clear();
        codec::encode_up((i % 4) as u16, &msg, &mut buf);
        tx.send(buf.clone()).expect("enqueue frame");
    }
    drop(tx);
    let stats = writer_h.join().unwrap().expect("writer io");
    let got = reader_h.join().unwrap();
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    assert_eq!(got, n, "every frame must arrive");
    let per_write = stats.frames as f64 / stats.writes.max(1) as f64;
    (n as f64 / secs, per_write)
}

/// Submit→grant round trips against a real rank-server session: one
/// immediately-grantable candidate registration up, one `Granted`
/// frame down, then a `GpuBusyUntil(now)` to free the GPU again.
fn bench_rtt(rounds: usize) -> (f64, f64, f64) {
    let server = RankServer::bind(RankServerConfig {
        listen: "127.0.0.1:0".into(),
        shards: 1,
        gpus: 0..1,
        max_sessions: Some(1),
        busy_poll: std::env::var_os("SYMPHONY_BUSY_POLL").is_some(),
        pin_cores: false,
        fault_plan: FaultPlan::none(),
        metrics_listen: None,
    })
    .expect("bind rank server");
    let addr = server.local_addr().to_string();
    let server_h = std::thread::spawn(move || server.run().expect("server run"));

    let clock = Clock::new();
    let conn = Arc::new(
        RemoteRank::connect(
            &addr,
            1,
            clock,
            Duration::from_secs(5),
            ReconnectPolicy::disabled(),
            FaultPlan::none(),
        )
        .expect("connect"),
    );
    let (model_tx, model_rx) = ring::<ToModel>(1024);
    conn.start_reader(
        vec![model_tx],
        0,
        Arc::new(DisconnectCounts::default()),
        ShardLiveness::all_live(1),
    );

    let mut rtts_us: Vec<f64> = Vec::with_capacity(rounds);
    for seq in 0..rounds as u64 {
        let far = clock.now() + Micros::from_millis_f64(5_000.0);
        let t0 = Instant::now();
        conn.send(
            0,
            &WireToRank::Candidate {
                model: ModelId(0),
                cand: Some(CandWindow {
                    exec: Micros(0),
                    latest: far,
                    size: 1,
                }),
                seq,
                hops: 0,
            },
        )
        .expect("send candidate");
        match model_rx.recv_timeout(Duration::from_secs(5)) {
            Ok(ToModel::Granted { gpu, .. }) => {
                rtts_us.push(t0.elapsed().as_secs_f64() * 1e6);
                // Free the GPU for the next round (free_at in the past
                // puts it straight back in the free set).
                conn.send(
                    0,
                    &WireToRank::GpuBusyUntil {
                        gpu,
                        free_at: clock.now(),
                    },
                )
                .expect("send busy-until");
            }
            other => panic!("expected a grant, got {other:?}"),
        }
    }
    conn.close();
    conn.join();
    let _ = server_h.join();
    let total_s: f64 = rtts_us.iter().sum::<f64>() / 1e6;
    (
        percentile(&rtts_us, 50.0),
        percentile(&rtts_us, 99.0),
        rounds as f64 / total_s.max(1e-9),
    )
}

fn main() {
    banner("Wire coordination microbench (net/: codec, transport, rank-server RTT)");
    let mut table = Table::new(vec!["metric", "value"]);
    let mut json: Vec<(String, f64)> = Vec::new();

    let codec_rate = bench_codec(1_000_000);
    table.row(vec!["codec roundtrips/s".into(), format!("{codec_rate:.0}")]);
    json.push(("wire_codec_roundtrips_per_sec".into(), codec_rate));

    let (frames_rate, per_write) = bench_frames(200_000);
    table.row(vec!["frames/s (loopback)".into(), format!("{frames_rate:.0}")]);
    table.row(vec!["frames per write syscall".into(), format!("{per_write:.1}")]);
    json.push(("wire_frames_per_sec".into(), frames_rate));
    json.push(("wire_frames_per_write".into(), per_write));

    let (p50, p99, rtt_rate) = bench_rtt(2_000);
    table.row(vec!["submit→grant RTT p50 (µs)".into(), format!("{p50:.0}")]);
    table.row(vec!["submit→grant RTT p99 (µs)".into(), format!("{p99:.0}")]);
    table.row(vec!["submit→grant round trips/s".into(), format!("{rtt_rate:.0}")]);
    json.push(("wire_rtt_p50_us".into(), p50));
    json.push(("wire_rtt_p99_us".into(), p99));
    json.push(("wire_rtt_round_trips_per_sec".into(), rtt_rate));

    table.emit("bench_wire");
    write_json(&json);
}

/// Hand-rolled JSON (zero registry deps), same shape as
/// `BENCH_hotpath.json` / `BENCH_frontend.json`.
fn write_json(rows: &[(String, f64)]) {
    let mut s =
        String::from("{\n  \"bench\": \"bench_wire\",\n  \"schema\": 1,\n  \"results\": {\n");
    for (i, (k, v)) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        let _ = writeln!(s, "    \"{k}\": {v:.1}{sep}");
    }
    s.push_str("  }\n}\n");
    match std::fs::write("BENCH_wire.json", &s) {
        Ok(()) => println!("wrote BENCH_wire.json"),
        Err(e) => eprintln!("warn: could not write BENCH_wire.json: {e}"),
    }
}
