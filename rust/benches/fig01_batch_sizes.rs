//! `cargo bench --bench fig01_batch_sizes` — regenerates the paper's
//! Figure 1: batch size distribution.
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 1: batch size distribution");
    let t0 = std::time::Instant::now();
    experiments::fig01_batch_sizes().emit("fig01_batch_sizes");
    println!("[{}s]", t0.elapsed().as_secs());
}
