//! `cargo bench --bench fig02_flattop` — regenerates the paper's
//! Figure 2: goodput stability + load-proportional GPU usage.
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 2: goodput stability + load-proportional GPU usage");
    let t0 = std::time::Instant::now();
    experiments::fig02_flattop().emit("fig02_flattop");
    println!("[{}s]", t0.elapsed().as_secs());
}
