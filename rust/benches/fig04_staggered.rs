//! `cargo bench --bench fig04_staggered` — reproduces Figure 4: the
//! staggered execution pattern formed by deferred batch scheduling on
//! the §3.3 worked example (3 GPUs, ℓ(b) = b + 5, SLO 12, uniform
//! arrivals every 0.75 time units).

use symphony::core::time::Micros;
use symphony::harness::experiments::{render_trace, worked_example_workload};
use symphony::harness::SystemKind;
use symphony::sim::{Engine, SimConfig};
use symphony::util::table::{banner, Table};

fn main() {
    banner("Figure 4: staggered execution under deferred batch scheduling");
    let (models, workload) = worked_example_workload(48, false);
    let cfg = SimConfig::new(3, Micros::from_secs_f64(0.1)).trace(true);
    let res = Engine::new(
        workload,
        SystemKind::Symphony.build(&models, 3, Micros::ZERO),
        cfg,
    )
    .run();
    println!("(digits = batch size; 1 column = 1 ms)\n");
    print!("{}", render_trace(&res.trace, 3, 45.0));
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["batches".to_string(), res.trace.len().to_string()]);
    t.row(vec![
        "steady_batch_size".to_string(),
        res.trace.last().map(|x| x.size).unwrap_or(0).to_string(),
    ]);
    t.row(vec![
        "good".to_string(),
        res.metrics.per_model[0].good.to_string(),
    ]);
    t.row(vec![
        "dropped".to_string(),
        res.metrics.per_model[0].dropped.to_string(),
    ]);
    t.emit("fig04_staggered");
}
