//! `cargo bench --bench fig05_missing` — reproduces Figure 5: reaction
//! to three missing requests (R13–R15). Eager scheduling degrades into
//! small batches and drops; deferred scheduling idles briefly and
//! regains the staggered pattern.

use symphony::core::time::Micros;
use symphony::harness::experiments::{render_trace, worked_example_workload};
use symphony::harness::SystemKind;
use symphony::sim::{Engine, SimConfig};
use symphony::util::table::{banner, Table};

fn main() {
    banner("Figure 5: reaction to three missing requests");
    let mut table = Table::new(vec![
        "system", "batches", "good", "dropped", "median_batch",
    ]);
    for sys in [SystemKind::Eager, SystemKind::Symphony] {
        let (models, workload) = worked_example_workload(72, true);
        let cfg = SimConfig::new(3, Micros::from_secs_f64(0.1)).trace(true);
        let res = Engine::new(workload, sys.build(&models, 3, Micros::ZERO), cfg).run();
        println!("\n--- {} ---", sys.label());
        print!("{}", render_trace(&res.trace, 3, 55.0));
        table.row(vec![
            sys.label(),
            res.trace.len().to_string(),
            res.metrics.per_model[0].good.to_string(),
            res.metrics.per_model[0].dropped.to_string(),
            res.metrics.per_model[0].median_batch().to_string(),
        ]);
    }
    println!();
    table.emit("fig05_missing");
}
