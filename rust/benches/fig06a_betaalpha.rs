//! `cargo bench --bench fig06a_betaalpha` — regenerates the paper's
//! Figure 6a: eager vs deferred across batching-effect strength.
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 6a: eager vs deferred across batching-effect strength");
    let t0 = std::time::Instant::now();
    experiments::fig06a_betaalpha().emit("fig06a_betaalpha");
    println!("[{}s]", t0.elapsed().as_secs());
}
