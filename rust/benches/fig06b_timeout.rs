//! `cargo bench --bench fig06b_timeout` — regenerates the paper's
//! Figure 6b: timeout-based batch scheduling comparison.
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 6b: timeout-based batch scheduling comparison");
    let t0 = std::time::Instant::now();
    experiments::fig06b_timeout().emit("fig06b_timeout");
    println!("[{}s]", t0.elapsed().as_secs());
}
