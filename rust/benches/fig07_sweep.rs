//! `cargo bench --bench fig07_sweep` — regenerates the paper's
//! Figure 7: synthetic-workload sweep (SYMPHONY_FULL_SWEEP=1 for the full 5880-config grid).
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 7: synthetic-workload sweep (SYMPHONY_FULL_SWEEP=1 for the full 5880-config grid)");
    let t0 = std::time::Instant::now();
    experiments::fig07_sweep().emit("fig07_sweep");
    println!("[{}s]", t0.elapsed().as_secs());
}
