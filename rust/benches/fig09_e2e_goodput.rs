//! `cargo bench --bench fig09_e2e_goodput` — regenerates the paper's
//! Figure 9: end-to-end goodput on the model zoo (64 GPUs).
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 9: end-to-end goodput on the model zoo (64 GPUs)");
    let t0 = std::time::Instant::now();
    experiments::fig09_e2e_goodput().emit("fig09_e2e_goodput");
    println!("[{}s]", t0.elapsed().as_secs());
}
