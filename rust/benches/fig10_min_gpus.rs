//! `cargo bench --bench fig10_min_gpus` — regenerates the paper's
//! Figure 10: minimum GPUs for 15k RPS.
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 10: minimum GPUs for 15k RPS");
    let t0 = std::time::Instant::now();
    experiments::fig10_min_gpus().emit("fig10_min_gpus");
    println!("[{}s]", t0.elapsed().as_secs());
}
