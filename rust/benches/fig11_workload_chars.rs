//! `cargo bench --bench fig11_workload_chars` — regenerates the paper's
//! Figure 11: SLO x popularity x arrival-process grid.
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 11: SLO x popularity x arrival-process grid");
    let t0 = std::time::Instant::now();
    experiments::fig11_workload_chars().emit("fig11_workload_chars");
    println!("[{}s]", t0.elapsed().as_secs());
}
