//! `cargo bench --bench fig12_queueing` — regenerates the paper's
//! Figure 12: queueing delay quantiles.
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 12: queueing delay quantiles");
    let t0 = std::time::Instant::now();
    experiments::fig12_queueing().emit("fig12_queueing");
    println!("[{}s]", t0.elapsed().as_secs());
}
