//! `cargo bench --bench fig13_scalability` — Figure 13 (left): the
//! multithreaded coordinator's request throughput vs the number of
//! ModelThreads, with the RankThread shared (the §5.5 scheduler-only
//! benchmark: no network messages, no real GPUs — requests and GPUs are
//! in-process objects). Also runs the Figure 13 (right) goodput-vs-GPUs
//! simulation.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use symphony::coordinator::{Completion, Coordinator, CoordinatorConfig, ToBackend};
use symphony::core::profile::LatencyProfile;
use symphony::core::time::Micros;
use symphony::core::types::{ModelId, Request, RequestId};
use symphony::harness::experiments;
use symphony::util::table::{banner, Table};

/// Drive `n_models` ModelThreads at line rate for `dur`; return req/s.
fn coordinator_throughput(n_models: usize, num_gpus: usize, dur: Duration) -> f64 {
    let profile = LatencyProfile::new(1.0, 5.0);
    // Backend sinks: a drain thread per GPU channel (batches discarded).
    let mut backend_txs = Vec::new();
    let mut drains = Vec::new();
    for _ in 0..num_gpus {
        let (tx, rx) = channel::<ToBackend>();
        backend_txs.push(tx);
        drains.push(std::thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                if matches!(msg, ToBackend::Shutdown) {
                    break;
                }
            }
        }));
    }
    let (comp_tx, comp_rx) = channel::<Completion>();
    let comp_drain = std::thread::spawn(move || while comp_rx.recv().is_ok() {});

    let coord = Coordinator::spawn(
        CoordinatorConfig {
            profiles: vec![profile; n_models],
            num_gpus,
            net_bound: Micros::ZERO,
            exec_margin: Micros::ZERO,
        },
        backend_txs.clone(),
        comp_tx,
    );

    // Load generators: one feeder thread per ModelThread, submitting as
    // fast as the channel accepts (line rate), SLO 100 ms.
    let stop = Arc::new(AtomicBool::new(false));
    let clock = coord.clock;
    let coord = Arc::new(coord);
    let mut feeders = Vec::new();
    for m in 0..n_models {
        let stop = stop.clone();
        let coord = coord.clone();
        feeders.push(std::thread::spawn(move || {
            let slo = Micros::from_millis_f64(100.0);
            let mut sent = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let now = clock.now();
                coord.submit(Request {
                    id: RequestId((m as u64) << 40 | sent),
                    model: ModelId(m as u32),
                    arrival: now,
                    deadline: now + slo,
                });
                sent += 1;
            }
            sent
        }));
    }
    std::thread::sleep(dur);
    stop.store(true, Ordering::Relaxed);
    let submitted: u64 = feeders.into_iter().map(|f| f.join().unwrap()).sum();
    let coord = Arc::try_unwrap(coord).ok().expect("sole owner");
    let (processed, _grants) = coord.shutdown();
    for tx in &backend_txs {
        let _ = tx.send(ToBackend::Shutdown);
    }
    for d in drains {
        let _ = d.join();
    }
    drop(comp_drain);
    let _ = submitted;
    processed as f64 / dur.as_secs_f64()
}

fn main() {
    banner("Figure 13 (left): scheduler multicore scalability");
    let dur = Duration::from_millis(800);
    let mut table = Table::new(vec![
        "model_threads", "gpus", "requests_per_sec", "speedup_vs_1",
    ]);
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(8);
    let mut base = 0.0;
    let mut counts = vec![1usize, 2, 4, 8, 16];
    counts.retain(|&c| c <= cores.max(4));
    for &n in &counts {
        for &gpus in &[64usize, 1024] {
            let tput = coordinator_throughput(n, gpus, dur);
            if n == 1 && gpus == 64 {
                base = tput;
            }
            table.row(vec![
                n.to_string(),
                gpus.to_string(),
                format!("{tput:.0}"),
                format!("{:.2}x", tput / base.max(1.0)),
            ]);
        }
    }
    table.emit("fig13_scalability");

    banner("Figure 13 (right): goodput vs number of GPUs");
    let t0 = Instant::now();
    experiments::fig13_goodput_vs_gpus().emit("fig13_gpus");
    println!("[{}s]", t0.elapsed().as_secs());
}
