//! `cargo bench --bench fig13_scalability` — Figure 13 (left): the
//! multithreaded coordinator's scheduler-only throughput as the rank
//! tier is sharded (§5.5: no network messages, no real GPUs — requests
//! and GPUs are in-process objects, backends are drain threads).
//!
//! The sweep runs 1/2/4/8 rank shards × offered request rate and
//! reports requests/s through the model-worker pool, grants/s out of the
//! rank tier, and the p99 grant latency (µs a candidate's window was
//! open before a GPU was granted). On a multi-core host grants/s
//! scales with the shard count once a single rank thread saturates;
//! `speedup` is relative to 1 shard at the same offered rate. Also
//! runs the Figure 13 (right) goodput-vs-GPUs simulation.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use symphony::coordinator::{Completion, Coordinator, CoordinatorConfig, ToBackend};
use symphony::core::profile::LatencyProfile;
use symphony::core::time::Micros;
use symphony::core::types::{ModelId, Request, RequestId};
use symphony::harness::experiments;
use symphony::util::table::{banner, Table};

struct SweepPoint {
    processed_per_sec: f64,
    grants_per_sec: f64,
    p99_grant_latency_us: usize,
    /// Overflow-routed candidates that found no free GPU (stale
    /// steering hints) — the ROADMAP's mis-steer rate, per grant.
    missteer_per_kgrant: f64,
}

/// Drive `n_models` models (on the worker pool) for `dur` against a
/// sharded rank tier.
/// `rate` is the offered aggregate rate in requests/second; `None`
/// submits at line rate (as fast as the channels accept).
fn coordinator_sweep(
    n_models: usize,
    num_gpus: usize,
    rank_shards: usize,
    rate: Option<f64>,
    dur: Duration,
) -> SweepPoint {
    let profile = LatencyProfile::new(1.0, 5.0);
    // Backend sinks: a drain thread per GPU channel (batches discarded).
    let mut backend_txs = Vec::new();
    let mut drains = Vec::new();
    for _ in 0..num_gpus {
        let (tx, rx) = channel::<ToBackend>();
        backend_txs.push(tx);
        drains.push(std::thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                if matches!(msg, ToBackend::Shutdown) {
                    break;
                }
            }
        }));
    }
    let (comp_tx, comp_rx) = channel::<Completion>();
    let comp_drain = std::thread::spawn(move || while comp_rx.recv().is_ok() {});

    let coord = Coordinator::spawn(
        CoordinatorConfig {
            profiles: vec![profile; n_models],
            num_gpus,
            initial_gpus: None,
            rank_shards,
            ingest_shards: 1,
            model_workers: None,
            net_bound: Micros::ZERO,
            exec_margin: Micros::ZERO,
            remote_ranks: Vec::new(),
            busy_poll: std::env::var_os("SYMPHONY_BUSY_POLL").is_some(),
            pin_cores: std::env::var_os("SYMPHONY_PIN_CORES").is_some(),
            reconnect: symphony::net::client::ReconnectPolicy::default(),
            fault_plan: symphony::net::faults::FaultPlan::none(),
        },
        backend_txs.clone(),
        comp_tx,
    );

    // Load generators: one feeder thread per ModelThread, SLO 100 ms.
    // Paced feeders submit the deficit vs the target rate in small
    // chunks; line-rate feeders submit as fast as the channel accepts.
    let stop = Arc::new(AtomicBool::new(false));
    let clock = coord.clock;
    let coord = Arc::new(coord);
    let per_model_rate = rate.map(|r| r / n_models as f64);
    let mut feeders = Vec::new();
    for m in 0..n_models {
        let stop = stop.clone();
        let coord = coord.clone();
        feeders.push(std::thread::spawn(move || {
            let slo = Micros::from_millis_f64(100.0);
            let t0 = clock.now();
            let mut sent = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let now = clock.now();
                let quota = match per_model_rate {
                    Some(r) => {
                        let elapsed = (now.saturating_sub(t0)).as_secs_f64();
                        let due = (elapsed * r) as u64;
                        if due <= sent {
                            std::thread::sleep(Duration::from_micros(200));
                            continue;
                        }
                        (due - sent).min(256)
                    }
                    None => 1,
                };
                for _ in 0..quota {
                    coord.submit(Request {
                        id: RequestId((m as u64) << 40 | sent),
                        model: ModelId(m as u32),
                        arrival: now,
                        deadline: now + slo,
                    });
                    sent += 1;
                }
            }
            sent
        }));
    }
    std::thread::sleep(dur);
    stop.store(true, Ordering::Relaxed);
    let submitted: u64 = feeders.into_iter().map(|f| f.join().unwrap()).sum();
    let coord = Arc::try_unwrap(coord).ok().expect("sole owner");
    let (front, stats) = coord.shutdown_stats();
    let processed = front.processed;
    for tx in &backend_txs {
        let _ = tx.send(ToBackend::Shutdown);
    }
    for d in drains {
        let _ = d.join();
    }
    drop(comp_drain);
    let _ = submitted;
    let secs = dur.as_secs_f64();
    SweepPoint {
        processed_per_sec: processed as f64 / secs,
        grants_per_sec: stats.grants as f64 / secs,
        p99_grant_latency_us: stats.p99_grant_latency_us(),
        missteer_per_kgrant: stats.mis_steers as f64 / (stats.grants as f64 / 1e3).max(1e-9),
    }
}

fn main() {
    banner("Figure 13 (left): rank-shard scalability (scheduler-only)");
    let dur = Duration::from_millis(800);
    let num_gpus = 64usize;
    let n_models = 16usize;
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(8);
    println!("(host has {cores} cores; {n_models} models, {num_gpus} in-process GPUs)");

    let mut table = Table::new(vec![
        "rank_shards",
        "offered_rps",
        "requests_per_sec",
        "grants_per_sec",
        "p99_grant_lat_us",
        "missteer_per_kgrant",
        "speedup_vs_1shard",
    ]);
    // Offered rates: two paced points plus line rate (0 = line rate).
    let rates: [Option<f64>; 3] = [Some(50_000.0), Some(200_000.0), None];
    let shard_counts = [1usize, 2, 4, 8];
    let mut base: Vec<f64> = vec![0.0; rates.len()];
    for &shards in &shard_counts {
        for (ri, &rate) in rates.iter().enumerate() {
            let pt = coordinator_sweep(n_models, num_gpus, shards, rate, dur);
            if shards == 1 {
                base[ri] = pt.grants_per_sec;
            }
            table.row(vec![
                shards.to_string(),
                rate.map_or("line".to_string(), |r| format!("{r:.0}")),
                format!("{:.0}", pt.processed_per_sec),
                format!("{:.0}", pt.grants_per_sec),
                pt.p99_grant_latency_us.to_string(),
                format!("{:.2}", pt.missteer_per_kgrant),
                format!("{:.2}x", pt.grants_per_sec / base[ri].max(1.0)),
            ]);
        }
    }
    table.emit("fig13_scalability");

    banner("Figure 13 (right): goodput vs number of GPUs");
    let t0 = Instant::now();
    experiments::fig13_goodput_vs_gpus().emit("fig13_gpus");
    println!("[{}s]", t0.elapsed().as_secs());
}
