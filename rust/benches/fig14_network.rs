//! `cargo bench --bench fig14_network` — regenerates the paper's
//! Figure 14: network latency sensitivity.
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 14: network latency sensitivity");
    let t0 = std::time::Instant::now();
    experiments::fig14_network().emit("fig14_network");
    println!("[{}s]", t0.elapsed().as_secs());
}
