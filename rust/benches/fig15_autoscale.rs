//! `cargo bench --bench fig15_autoscale` — Figure 15: a changing
//! workload (24 models, synthetic diurnal+burst rate traces) on a
//! 512-GPU emulated cluster with the §3.5 autoscaling controller in the
//! loop: offered load, active GPUs, bad rate, and scaling advice over
//! time.

use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 15: changing workload on a 512-GPU cluster");
    let t0 = std::time::Instant::now();
    let secs = if std::env::var("SYMPHONY_FULL_SWEEP").is_ok() {
        1200.0
    } else {
        180.0
    };
    experiments::fig15_autoscale(secs, 512).emit("fig15_autoscale");
    println!("[{}s]", t0.elapsed().as_secs());
}
