//! `cargo bench --bench fig16_partition` — regenerates the paper's
//! Figure 16: MILP-style partitioning vs random search.
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 16: MILP-style partitioning vs random search");
    let t0 = std::time::Instant::now();
    experiments::fig16_partition(20, 300).emit("fig16_partition");
    println!("[{}s]", t0.elapsed().as_secs());
}
