//! `cargo bench --bench fig17_incast` — regenerates the paper's
//! Figure 17: RDMA vs TCP incast latency distributions.
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Figure 17: RDMA vs TCP incast latency distributions");
    let t0 = std::time::Instant::now();
    experiments::fig17_incast(200_000).emit("fig17_incast");
    println!("[{}s]", t0.elapsed().as_secs());
}
