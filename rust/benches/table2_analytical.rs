//! `cargo bench --bench table2_analytical` — regenerates the paper's
//! Table 2: analytical batching model vs measured goodput.
use symphony::harness::experiments;
use symphony::util::table::banner;

fn main() {
    banner("Table 2: analytical batching model vs measured goodput");
    let t0 = std::time::Instant::now();
    experiments::table2_analytical().emit("table2_analytical");
    println!("[{}s]", t0.elapsed().as_secs());
}
