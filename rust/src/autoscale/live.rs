//! Live autoscaling (§3.5 wired to the real coordinator): the
//! [`LiveAutoscaler`] consumes per-epoch [`WindowStats`] measured from
//! the completion stream and acts on the running cluster through a
//! [`ClusterCtl`] — attaching detached GPUs on `Allocate` advice and
//! draining attached ones on `Deallocate`.
//!
//! Retirement order is always **highest id first** (the highest shard's
//! highest `GpuId`s): Symphony's min-id dispatch rule and the
//! shard-0-first overflow steering keep exactly those GPUs idle, so
//! they drain fastest and the active set stays a contiguous low-id
//! prefix — the consolidation invariant the whole stack preserves.
//! Attach order is symmetric: lowest detached id first.
//!
//! A drained GPU is not forgotten at the moment the `Drain` is issued:
//! it sits in `Draining` until the owning shard acks that its in-flight
//! batch finished (LazyBatching's lesson — act on measured windows, and
//! retire only provably-idle accelerators). Only acked GPUs return to
//! the attachable pool.
//!
//! The autoscaler is transport-agnostic: `ClusterCtl` routes through
//! [`crate::coordinator::RankPort`]s, so against `serve
//! --remote-ranks` the same `Drain` becomes a wire frame to the
//! owning `rank-server` and the ack returns as a `DrainAck` frame —
//! this actor neither knows nor cares which side of the process
//! boundary the shard lives on.
//!
//! It is, however, **failover-aware**: each step starts by reconciling
//! against the cluster's shard-liveness map. GPUs on a server that has
//! been unreachable past `ReconnectPolicy::dead_after` become
//! [`GpuState::Lost`] — no longer counted active, never drained or
//! attached — which drops the measured capacity and lets the ordinary
//! `Allocate` path **re-tile the lost range onto survivors** (lowest
//! detached live ids first, the same consolidation order as any other
//! attach). When the server reconnects, its `Lost` GPUs are re-adopted:
//! an idempotent `Attach` re-asserts intent against the fresh session
//! (which spawned fully attached anyway) and the slot returns to
//! `Attached`.

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::autoscale::{Advice, AutoscaleController, WindowStats};
use crate::coordinator::ClusterCtl;
use crate::core::types::GpuId;

/// Where one GPU slot is in the attach/drain lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GpuState {
    /// Registered with its shard; grantable.
    Attached,
    /// Drain issued; waiting for the shard's idle ack.
    Draining,
    /// Retired (or never attached); available to attach.
    Detached,
    /// Its shard's server has been unreachable past the reconnect
    /// deadline: the capacity is gone until the server returns. Not
    /// active, not attachable, not drainable — a pending drain's ack
    /// died with the session and will never arrive.
    Lost,
}

/// The actor that applies [`AutoscaleController`] advice to a live
/// coordinator. Single-writer: exactly one `LiveAutoscaler` may manage
/// a cluster (it assumes nobody else attaches or drains GPUs).
pub struct LiveAutoscaler {
    pub ctl: AutoscaleController,
    cluster: ClusterCtl,
    state: Vec<GpuState>,
    ack_tx: Sender<GpuId>,
    ack_rx: Receiver<GpuId>,
}

impl LiveAutoscaler {
    /// `initial_gpus` must match the coordinator's
    /// `CoordinatorConfig::initial_gpus` (the attached low-id prefix).
    pub fn new(ctl: AutoscaleController, cluster: ClusterCtl, initial_gpus: usize) -> Self {
        let (ack_tx, ack_rx) = channel();
        let state = (0..cluster.num_gpus())
            .map(|g| {
                if g < initial_gpus {
                    GpuState::Attached
                } else {
                    GpuState::Detached
                }
            })
            .collect();
        LiveAutoscaler {
            ctl,
            cluster,
            state,
            ack_tx,
            ack_rx,
        }
    }

    /// GPUs currently attached (grantable). Draining GPUs no longer
    /// count: they take no new work.
    pub fn active_gpus(&self) -> usize {
        self.state.iter().filter(|s| **s == GpuState::Attached).count()
    }

    /// GPUs whose drain ack is still outstanding.
    pub fn draining_gpus(&self) -> usize {
        self.state.iter().filter(|s| **s == GpuState::Draining).count()
    }

    /// Per-GPU lifecycle states, indexed by `GpuId` (callers diff this
    /// across [`Self::step`] to run attach-time side effects like
    /// spawning a backend worker).
    pub fn gpu_states(&self) -> &[GpuState] {
        &self.state
    }

    /// Absorb shard acks: a `Draining` GPU whose shard confirmed it is
    /// idle becomes `Detached` (re-attachable capacity).
    pub fn reap_acks(&mut self) {
        while let Ok(gpu) = self.ack_rx.try_recv() {
            let s = &mut self.state[gpu.0 as usize];
            // A `Lost` slot can still see its ack land if the shard
            // acked just before the session died; the loss verdict
            // stands (the GPU is unreachable either way).
            if *s == GpuState::Draining {
                *s = GpuState::Detached;
            }
        }
    }

    /// Reconcile against shard liveness: GPUs on dead servers become
    /// `Lost` (dropping out of the active count, making room for the
    /// `Allocate` path to re-tile onto survivors); `Lost` GPUs whose
    /// server returned are re-adopted with an idempotent `Attach`.
    /// Returns `(lost, revived)` this pass.
    pub fn reconcile_liveness(&mut self) -> (usize, usize) {
        let mut lost = 0;
        let mut revived = 0;
        for g in 0..self.state.len() {
            let gpu = GpuId(g as u32);
            let live = self.cluster.gpu_is_live(gpu);
            match self.state[g] {
                GpuState::Attached | GpuState::Draining if !live => {
                    self.state[g] = GpuState::Lost;
                    lost += 1;
                }
                GpuState::Lost if live => {
                    // The reconnected session spawned fully attached;
                    // the explicit attach is an idempotent re-assert
                    // (and catches a replayed drain racing this slot).
                    if self.cluster.attach(gpu).is_ok() {
                        self.state[g] = GpuState::Attached;
                        revived += 1;
                    }
                }
                _ => {}
            }
        }
        if lost > 0 {
            eprintln!(
                "autoscaler: {lost} GPU(s) lost to a dead rank server; \
                 re-tiling onto survivors"
            );
        }
        if revived > 0 {
            eprintln!("autoscaler: {revived} lost GPU(s) re-adopted after reconnect");
        }
        (lost, revived)
    }

    /// One epoch: feed the window through the controller and act on the
    /// advice. Returns the net delta (GPUs attached minus drains
    /// issued) actually applied.
    pub fn step(&mut self, w: &WindowStats) -> i64 {
        self.reap_acks();
        self.reconcile_liveness();
        match self.ctl.advise(w) {
            Advice::Hold => 0,
            Advice::Allocate(n) => {
                // Lowest detached ids first: the active set stays a
                // contiguous prefix (modulo drains still in flight).
                let mut added = 0i64;
                for g in 0..self.state.len() {
                    if added == n as i64 {
                        break;
                    }
                    // Live shards only: a detached GPU on a dead server
                    // is not capacity — skipping it is what re-tiles a
                    // lost range onto the surviving servers' headroom.
                    if self.state[g] == GpuState::Detached
                        && self.cluster.gpu_is_live(GpuId(g as u32))
                        && self.cluster.attach(GpuId(g as u32)).is_ok()
                    {
                        self.state[g] = GpuState::Attached;
                        added += 1;
                    }
                }
                added
            }
            Advice::Deallocate(n) => {
                // Highest attached ids first — the consolidation order.
                // Never drain below the controller's floor even if the
                // advice and the attached count disagree transiently
                // (drains from the previous epoch may still be in
                // flight and uncounted by `w.active_gpus`).
                let room = self.active_gpus().saturating_sub(self.ctl.cfg.min_gpus);
                let n = n.min(room);
                let mut drained = 0i64;
                for g in (0..self.state.len()).rev() {
                    if drained == n as i64 {
                        break;
                    }
                    if self.state[g] == GpuState::Attached
                        && self.cluster.drain(GpuId(g as u32), self.ack_tx.clone()).is_ok()
                    {
                        self.state[g] = GpuState::Draining;
                        drained += 1;
                    }
                }
                -drained
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autoscale::AutoscaleConfig;
    use crate::coordinator::{Completion, Coordinator, CoordinatorConfig, ToBackend};
    use crate::core::profile::LatencyProfile;
    use crate::core::time::Micros;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    fn overloaded() -> WindowStats {
        WindowStats {
            good: 10,
            bad: 90,
            busy_fraction: 1.0,
            active_gpus: 0, // filled per test
            queue_depth: 0,
        }
    }

    fn idle() -> WindowStats {
        WindowStats {
            good: 100,
            bad: 0,
            busy_fraction: 0.02,
            active_gpus: 0,
            queue_depth: 0,
        }
    }

    /// End-to-end against a real (idle) coordinator: allocate attaches
    /// the lowest detached ids, deallocate drains the highest attached
    /// ones and their acks return them to the pool.
    #[test]
    fn live_autoscaler_attach_and_drain_order() {
        let profile = LatencyProfile::new(0.5, 2.0);
        let num_gpus = 6;
        let mut backend_txs = Vec::new();
        let mut _backend_rxs = Vec::new();
        for _ in 0..num_gpus {
            let (tx, rx) = channel::<ToBackend>();
            backend_txs.push(tx);
            _backend_rxs.push(rx);
        }
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let coord = Coordinator::spawn(
            CoordinatorConfig {
                profiles: vec![profile],
                num_gpus,
                initial_gpus: Some(2),
                rank_shards: 2,
                ingest_shards: 1,
                model_workers: None,
                net_bound: Micros::ZERO,
                exec_margin: Micros::ZERO,
                remote_ranks: Vec::new(),
                busy_poll: false,
                pin_cores: false,
                reconnect: crate::net::client::ReconnectPolicy::default(),
                fault_plan: crate::net::faults::FaultPlan::none(),
            },
            backend_txs,
            comp_tx,
        );
        let ctl = AutoscaleController::new(AutoscaleConfig {
            min_gpus: 1,
            max_gpus: num_gpus,
            ..Default::default()
        });
        let mut scaler = LiveAutoscaler::new(ctl, coord.cluster_ctl(), 2);
        assert_eq!(scaler.active_gpus(), 2);

        // Overload: 2 GPUs, 90% bad → allocate (bounded by capacity).
        let mut w = overloaded();
        w.active_gpus = scaler.active_gpus();
        let delta = scaler.step(&w);
        assert!(delta > 0, "overload must allocate, got {delta}");
        let grown = scaler.active_gpus();
        assert!(grown > 2 && grown <= num_gpus);
        assert_eq!(
            scaler.state[..grown],
            vec![GpuState::Attached; grown][..],
            "attached set must be the low-id prefix: {:?}",
            scaler.state
        );

        // Idle: drain back down; acks arrive from the shards (the GPUs
        // are idle, so immediately) and free the slots.
        let mut w = idle();
        w.active_gpus = scaler.active_gpus();
        let delta = scaler.step(&w);
        assert!(delta < 0, "idle must deallocate, got {delta}");
        assert!(scaler.active_gpus() >= 1, "floor respected");
        // Draining GPUs are the *highest* ids.
        let first_draining = scaler
            .state
            .iter()
            .position(|s| *s == GpuState::Draining)
            .expect("something draining");
        assert!(
            scaler.state[first_draining..].iter().all(|s| *s != GpuState::Attached),
            "drains must come from the top: {:?}",
            scaler.state
        );
        // Idle GPUs ack fast.
        std::thread::sleep(Duration::from_millis(150));
        scaler.reap_acks();
        assert_eq!(scaler.draining_gpus(), 0, "{:?}", scaler.state);
        coord.shutdown();
    }

    /// Failover re-tiling: a dead shard's GPUs become `Lost` (not
    /// active, not attachable), overload allocation skips the dead
    /// range and grows onto surviving shards' headroom, and revival
    /// re-adopts the lost slots as `Attached`.
    #[test]
    fn live_autoscaler_retiles_around_dead_shard() {
        let profile = LatencyProfile::new(0.5, 2.0);
        // 3 shards over 6 GPUs: shard 0 owns 0..2, shard 1 owns 2..4,
        // shard 2 owns 4..6. Start with 0..4 attached.
        let num_gpus = 6;
        let mut backend_txs = Vec::new();
        let mut _backend_rxs = Vec::new();
        for _ in 0..num_gpus {
            let (tx, rx) = channel::<ToBackend>();
            backend_txs.push(tx);
            _backend_rxs.push(rx);
        }
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let coord = Coordinator::spawn(
            CoordinatorConfig {
                profiles: vec![profile],
                num_gpus,
                initial_gpus: Some(4),
                rank_shards: 3,
                ingest_shards: 1,
                model_workers: None,
                net_bound: Micros::ZERO,
                exec_margin: Micros::ZERO,
                remote_ranks: Vec::new(),
                busy_poll: false,
                pin_cores: false,
                reconnect: crate::net::client::ReconnectPolicy::default(),
                fault_plan: crate::net::faults::FaultPlan::none(),
            },
            backend_txs,
            comp_tx,
        );
        let liveness = coord.shard_liveness();
        let ctl = AutoscaleController::new(AutoscaleConfig {
            min_gpus: 1,
            max_gpus: num_gpus,
            ..Default::default()
        });
        let mut scaler = LiveAutoscaler::new(ctl, coord.cluster_ctl(), 4);
        assert_eq!(scaler.active_gpus(), 4);

        // Shard 1's server goes dark past the deadline.
        liveness.set_live(1, false);
        let (lost, revived) = scaler.reconcile_liveness();
        assert_eq!((lost, revived), (2, 0), "{:?}", scaler.gpu_states());
        assert_eq!(scaler.gpu_states()[2], GpuState::Lost);
        assert_eq!(scaler.gpu_states()[3], GpuState::Lost);
        assert_eq!(scaler.active_gpus(), 2, "lost GPUs are not active");

        // Overload: the grow path must skip the dead range and attach
        // shard 2's headroom instead — the re-tile.
        let mut w = overloaded();
        w.active_gpus = scaler.active_gpus();
        let delta = scaler.step(&w);
        assert!(delta > 0, "overload must still allocate, got {delta}");
        assert_eq!(
            scaler.gpu_states()[4],
            GpuState::Attached,
            "lowest live detached id attaches first: {:?}",
            scaler.gpu_states()
        );
        assert_eq!(scaler.gpu_states()[2], GpuState::Lost, "dead range untouched");

        // The server returns: lost slots are re-adopted.
        liveness.set_live(1, true);
        let (lost, revived) = scaler.reconcile_liveness();
        assert_eq!((lost, revived), (0, 2), "{:?}", scaler.gpu_states());
        assert_eq!(scaler.gpu_states()[2], GpuState::Attached);
        assert_eq!(scaler.gpu_states()[3], GpuState::Attached);
        coord.shutdown();
    }

    /// An empty window must not scale (the controller regression,
    /// exercised through the live actor).
    #[test]
    fn live_autoscaler_holds_on_empty_window() {
        let profile = LatencyProfile::new(0.5, 2.0);
        let (backend_tx, _backend_rx) = channel::<ToBackend>();
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let coord = Coordinator::spawn(
            CoordinatorConfig {
                profiles: vec![profile],
                num_gpus: 1,
                initial_gpus: None,
                rank_shards: 1,
                ingest_shards: 1,
                model_workers: None,
                net_bound: Micros::ZERO,
                exec_margin: Micros::ZERO,
                remote_ranks: Vec::new(),
                busy_poll: false,
                pin_cores: false,
                reconnect: crate::net::client::ReconnectPolicy::default(),
                fault_plan: crate::net::faults::FaultPlan::none(),
            },
            vec![backend_tx],
            comp_tx,
        );
        let ctl = AutoscaleController::new(AutoscaleConfig::default());
        let mut scaler = LiveAutoscaler::new(ctl, coord.cluster_ctl(), 1);
        let w = WindowStats {
            active_gpus: 1,
            ..Default::default()
        };
        assert_eq!(scaler.step(&w), 0);
        assert_eq!(scaler.active_gpus(), 1);
        coord.shutdown();
    }
}
