//! Autoscaling support (§3.5, §5.4, Fig 15).
//!
//! Symphony's *flat-top* behavior makes two signals trustworthy:
//! * **bad rate** `r` under overload ⇒ allocate `N·r/(1−r)` GPUs;
//! * **GPU idle fraction** `f` under underload ⇒ deallocate `N·f` GPUs.
//!
//! The [`AutoscaleController`] turns windowed measurements of those two
//! signals into advice; the Fig 15 driver applies the advice to the
//! emulated cluster (removing only idle, highest-id GPUs — which
//! Symphony's min-id dispatch rule keeps idle on purpose).

pub mod live;

use crate::core::time::Micros;

/// Windowed measurements the controller consumes.
#[derive(Clone, Copy, Debug, Default)]
pub struct WindowStats {
    pub good: u64,
    pub bad: u64,
    /// Mean busy fraction across active GPUs in the window, 0..1.
    pub busy_fraction: f64,
    pub active_gpus: usize,
    /// Requests sitting in the model workers' queues at the end of the
    /// window (the live wiring reads
    /// [`crate::coordinator::QueueDepthProbe`]; sim-side producers
    /// leave it 0). Completion counts alone can read a *stalling*
    /// epoch — few completions, low measured busy — as an idle one;
    /// the backlog disambiguates, vetoing deallocation when work is
    /// piling up (the ROADMAP's "feed shard-level queue depth into
    /// `WindowStats`" item).
    pub queue_depth: u64,
}

impl WindowStats {
    /// Did any request finish (well or badly) this window? An empty
    /// window carries no signal: `busy_fraction` is left at its 0.0
    /// default by most producers, which would otherwise read as a fully
    /// idle cluster and trigger a mass deallocation.
    pub fn is_empty(&self) -> bool {
        self.good + self.bad == 0
    }

    pub fn bad_rate(&self) -> f64 {
        let t = self.good + self.bad;
        if t == 0 {
            0.0
        } else {
            self.bad as f64 / t as f64
        }
    }

    pub fn idle_fraction(&self) -> f64 {
        (1.0 - self.busy_fraction).clamp(0.0, 1.0)
    }
}

/// The controller's advice for the next epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Advice {
    /// Cluster is sized right.
    Hold,
    /// Add this many GPUs.
    Allocate(usize),
    /// Remove this many (idle) GPUs.
    Deallocate(usize),
}

/// Controller configuration.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    /// Bad-rate threshold that triggers allocation (§3.5: "if the bad
    /// rate r is above a threshold").
    pub bad_rate_threshold: f64,
    /// Idle-fraction threshold that triggers deallocation.
    pub idle_threshold: f64,
    /// Never shrink below this many GPUs.
    pub min_gpus: usize,
    /// Never grow beyond this many GPUs.
    pub max_gpus: usize,
    /// Decision epoch.
    pub epoch: Micros,
    /// Deallocation veto threshold: an idle-looking window with more
    /// than `backlog_per_gpu × active_gpus` requests still queued holds
    /// instead of shrinking (the backlog will surface as bad rate
    /// within an epoch; shrinking first would whipsaw).
    pub backlog_per_gpu: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            bad_rate_threshold: 0.01,
            idle_threshold: 0.10,
            min_gpus: 1,
            max_gpus: 4096,
            epoch: Micros::from_secs_f64(10.0),
            backlog_per_gpu: 4.0,
        }
    }
}

/// The §3.5 controller.
#[derive(Clone, Debug)]
pub struct AutoscaleController {
    pub cfg: AutoscaleConfig,
}

impl AutoscaleController {
    pub fn new(cfg: AutoscaleConfig) -> Self {
        AutoscaleController { cfg }
    }

    /// Clamp on the bad rate fed to the `N·r/(1−r)` allocation formula:
    /// at `r = 1` the formula divides by zero (`want` becomes `inf`,
    /// which a saturating cast turns into `usize::MAX`), and near 1 it
    /// explodes. Full overload carries no proportional signal — the bad
    /// rate says "everything missed", not "by how much" — so saturation
    /// becomes a bounded multiplicative step (`0.95/0.05 = 19×`,
    /// still capped by `max_gpus`) applied once per epoch.
    const MAX_BAD_RATE: f64 = 0.95;

    /// Advice from this window's stats.
    pub fn advise(&self, w: &WindowStats) -> Advice {
        // No completions this window: nothing to react to. Scaling on
        // the defaulted busy_fraction would deallocate an idle-looking
        // cluster down to `min_gpus` on every quiet epoch. Tradeoff: a
        // cluster whose traffic stops entirely holds at its current
        // size until requests resume (revisit with an explicit
        // has-measurement flag if full-idle decay is ever needed —
        // production clusters at this scale are never request-silent).
        if w.is_empty() {
            return Advice::Hold;
        }
        let n = w.active_gpus;
        let r = w.bad_rate();
        if r > self.cfg.bad_rate_threshold {
            // Allocate N·r/(1−r), at least 1, capped.
            let r = r.min(Self::MAX_BAD_RATE);
            let want = ((n as f64 * r / (1.0 - r)).ceil() as usize).max(1);
            let room = self.cfg.max_gpus.saturating_sub(n);
            let add = want.min(room);
            return if add == 0 { Advice::Hold } else { Advice::Allocate(add) };
        }
        let f = w.idle_fraction();
        if f > self.cfg.idle_threshold {
            // Deep-backlog veto: completions and busy time are
            // *trailing* signals — an epoch in which the queues exploded
            // can finish few requests and measure low busy exactly
            // because everything is still waiting. Such an epoch must
            // not read as scale-down.
            if w.queue_depth as f64 > self.cfg.backlog_per_gpu * n.max(1) as f64 {
                return Advice::Hold;
            }
            // Deallocate N·f, keeping min_gpus.
            let want = (n as f64 * f).floor() as usize;
            let room = n.saturating_sub(self.cfg.min_gpus);
            let del = want.min(room);
            return if del == 0 {
                Advice::Hold
            } else {
                Advice::Deallocate(del)
            };
        }
        Advice::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> AutoscaleController {
        AutoscaleController::new(AutoscaleConfig::default())
    }

    #[test]
    fn overload_allocates_proportionally() {
        // 10% bad on 24 GPUs: N·r/(1−r) = 24·0.1/0.9 ≈ 2.67 → 3.
        let w = WindowStats {
            good: 900,
            bad: 100,
            busy_fraction: 1.0,
            active_gpus: 24,
            queue_depth: 0,
        };
        assert_eq!(ctl().advise(&w), Advice::Allocate(3));
    }

    #[test]
    fn underload_deallocates_idle_share() {
        // 50% idle on 24 GPUs → remove 12.
        let w = WindowStats {
            good: 1000,
            bad: 0,
            busy_fraction: 0.5,
            active_gpus: 24,
            queue_depth: 0,
        };
        assert_eq!(ctl().advise(&w), Advice::Deallocate(12));
    }

    #[test]
    fn balanced_holds() {
        let w = WindowStats {
            good: 1000,
            bad: 2,
            busy_fraction: 0.95,
            active_gpus: 24,
            queue_depth: 0,
        };
        assert_eq!(ctl().advise(&w), Advice::Hold);
    }

    #[test]
    fn respects_min_and_max() {
        let c = AutoscaleController::new(AutoscaleConfig {
            min_gpus: 4,
            max_gpus: 8,
            ..Default::default()
        });
        let idle = WindowStats {
            good: 100,
            bad: 0,
            busy_fraction: 0.0,
            active_gpus: 4,
            queue_depth: 0,
        };
        assert_eq!(c.advise(&idle), Advice::Hold, "won't shrink below min");
        let over = WindowStats {
            good: 100,
            bad: 100,
            busy_fraction: 1.0,
            active_gpus: 8,
            queue_depth: 0,
        };
        assert_eq!(c.advise(&over), Advice::Hold, "won't grow past max");
    }

    /// Regression: a zero-traffic epoch (all-default `WindowStats`, the
    /// exact shape a live wiring produces on an idle epoch) must not
    /// read the defaulted `busy_fraction == 0.0` as a fully idle
    /// cluster and advise mass deallocation.
    #[test]
    fn empty_window_holds() {
        let w = WindowStats {
            active_gpus: 8,
            ..Default::default()
        };
        assert_eq!(ctl().advise(&w), Advice::Hold, "no signal, no action");
        // The fully-default window (active_gpus = 0 too) also holds.
        assert_eq!(ctl().advise(&WindowStats::default()), Advice::Hold);
    }

    /// Regression: `bad_rate == 1.0` used to divide by zero in
    /// `N·r/(1−r)` (`want = inf → usize::MAX` via saturating cast). A
    /// saturated window must advise a *bounded* allocation.
    #[test]
    fn saturated_bad_rate_allocates_bounded() {
        let c = AutoscaleController::new(AutoscaleConfig {
            max_gpus: 100_000,
            ..Default::default()
        });
        let w = WindowStats {
            good: 0,
            bad: 500,
            busy_fraction: 1.0,
            active_gpus: 8,
            queue_depth: 0,
        };
        // r clamps to 0.95: 8·0.95/0.05 = 152.
        assert_eq!(c.advise(&w), Advice::Allocate(152));
        // r just below 1.0 (999/1000) clamps the same way instead of
        // exploding toward 8·999 = 7992.
        let w = WindowStats {
            good: 1,
            bad: 999,
            busy_fraction: 1.0,
            active_gpus: 8,
            queue_depth: 0,
        };
        assert_eq!(c.advise(&w), Advice::Allocate(152));
        // Unclamped rates keep the exact proportional formula.
        let w = WindowStats {
            good: 500,
            bad: 500,
            busy_fraction: 1.0,
            active_gpus: 8,
            queue_depth: 0,
        };
        assert_eq!(c.advise(&w), Advice::Allocate(8));
    }

    /// The queue-depth satellite: an epoch whose completions look idle
    /// but whose worker queues are deep must hold, not shrink — the
    /// backlog is load the trailing completion counters haven't seen
    /// yet. A genuinely idle epoch (same counters, empty queues) still
    /// deallocates.
    #[test]
    fn deep_backlog_vetoes_deallocation() {
        let c = ctl(); // backlog_per_gpu = 4.0
        let stalled = WindowStats {
            good: 50,
            bad: 0,
            busy_fraction: 0.05,
            active_gpus: 8,
            queue_depth: 1_000, // ≫ 4 × 8
        };
        assert_eq!(c.advise(&stalled), Advice::Hold, "backlog vetoes shrink");
        let idle = WindowStats {
            queue_depth: 0,
            ..stalled
        };
        assert!(
            matches!(c.advise(&idle), Advice::Deallocate(_)),
            "{:?}",
            c.advise(&idle)
        );
        // The veto scales with the cluster: the same backlog on enough
        // GPUs is just normal queueing, not a stall.
        let shallow = WindowStats {
            queue_depth: 30, // < 4 × 8
            ..stalled
        };
        assert!(matches!(c.advise(&shallow), Advice::Deallocate(_)));
        // The veto never blocks the overload path: bad rate still
        // allocates regardless of depth.
        let over = WindowStats {
            good: 10,
            bad: 90,
            busy_fraction: 1.0,
            active_gpus: 8,
            queue_depth: 1_000,
        };
        assert!(matches!(c.advise(&over), Advice::Allocate(_)));
    }
}
