//! Schedule exploration: bounded-preemption DFS with state-fingerprint
//! pruning, plus a seeded random-walk mode for budgets beyond the
//! exhaustive bound.
//!
//! Exploration is stateless-replay: each run spawns fresh model
//! threads and replays a recorded choice prefix, then explores new
//! choices depth-first (always picking index 0 and backtracking the
//! deepest point that still has an unexplored sibling). Fingerprints
//! are consulted only *beyond* the replay prefix — states on the
//! prefix were necessarily seen by earlier runs and must not prune
//! their own replay.

use std::collections::HashSet;
use std::panic;
use std::sync::{Mutex, Once, PoisonError};
use std::time::Instant;

use super::sched::{self, CheckAbort, Sched};
use crate::util::rng::Rng;

/// Serializes explorations across `cargo test` threads: the scheduler
/// slot (`sched::CURRENT`) and the virtual memory are process-global.
static RUN_LOCK: Mutex<()> = Mutex::new(());

/// Silence the panic reports of `CheckAbort` unwinds (they are control
/// flow, thousands per exploration). Installed once, wraps whatever
/// hook was active, delegates everything else — so real model
/// assertion failures still print their diagnostics.
fn install_panic_filter() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            if !info.payload().is::<CheckAbort>() {
                prev(info);
            }
        }));
    });
}

#[derive(Clone, Copy, Debug)]
pub struct ExploreConfig {
    /// Preemption budget per run (CHESS-style): context switches away
    /// from a still-runnable thread. Most real concurrency bugs
    /// manifest within 2.
    pub preempt: u32,
    /// Hard cap on runs for the exhaustive mode; hitting it reports
    /// `exhausted: false` (CI keeps bounds that never hit this).
    pub max_schedules: usize,
    /// `Some((n, seed))`: run `n` uniformly random schedules instead
    /// of DFS (nightly deep sweeps).
    pub random: Option<(usize, u64)>,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        ExploreConfig {
            preempt: 2,
            max_schedules: 200_000,
            random: None,
        }
    }
}

#[derive(Debug)]
pub struct ExploreReport {
    /// Runs executed (including pruned ones).
    pub schedules: usize,
    /// Runs cut short because their state fingerprint was already
    /// explored.
    pub pruned: usize,
    /// First failure found, if any — an assertion, a detected data
    /// race / uninitialized read, or a deadlock.
    pub failure: Option<String>,
    /// DFS exhausted the tree (false = `max_schedules` cap hit;
    /// always false in random mode).
    pub exhausted: bool,
    pub millis: u128,
}

enum RunOutcome {
    Complete,
    Failure(String),
    Pruned,
}

/// One scheduled run of the model: replay `replay`, then continue
/// depth-first (or randomly). Returns the choice trace as
/// `(enabled_count, chosen_index)` pairs.
fn run_once(
    run: fn(),
    budget: u32,
    replay: &[(u32, u32)],
    mut seen: Option<&mut HashSet<u64>>,
    mut rng: Option<&mut Rng>,
) -> (Vec<(u32, u32)>, RunOutcome) {
    let sched = Sched::new(budget);
    sched::install(&sched);
    sched.spawn_root(run);
    let mut trace: Vec<(u32, u32)> = Vec::new();
    let outcome = loop {
        let mut g = sched.wait_quiescent();
        if let Some(msg) = g.failure.clone() {
            drop(g);
            break RunOutcome::Failure(msg);
        }
        let acts = g.enabled_actions();
        if acts.is_empty() {
            if g.all_finished() {
                drop(g);
                break RunOutcome::Complete;
            }
            let msg = g.describe_stuck();
            drop(g);
            break RunOutcome::Failure(msg);
        }
        let d = trace.len();
        let idx = if d < replay.len() {
            debug_assert_eq!(
                replay[d].0 as usize,
                acts.len(),
                "nondeterministic model: replay diverged at depth {d}"
            );
            (replay[d].1 as usize).min(acts.len() - 1)
        } else if let Some(r) = rng.as_deref_mut() {
            (r.next_u64() % acts.len() as u64) as usize
        } else {
            if let Some(s) = seen.as_deref_mut() {
                if !s.insert(g.fingerprint()) {
                    drop(g);
                    break RunOutcome::Pruned;
                }
            }
            0
        };
        trace.push((acts.len() as u32, idx as u32));
        g.apply_action(acts[idx]);
        drop(g);
        sched.notify();
    };
    // Abandon whatever is still alive (no-op when all finished), wait
    // for the real threads, clear the scheduler slot.
    sched.abort();
    sched.join_all();
    sched::uninstall();
    (trace, outcome)
}

/// Explore `run` under `cfg`. Takes the process-wide run lock; safe to
/// call from concurrent tests.
pub fn explore(run: fn(), cfg: ExploreConfig) -> ExploreReport {
    let _guard = RUN_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    install_panic_filter();
    let t0 = Instant::now();
    let mut report = ExploreReport {
        schedules: 0,
        pruned: 0,
        failure: None,
        exhausted: false,
        millis: 0,
    };

    if let Some((n, seed)) = cfg.random {
        let mut rng = Rng::new(seed);
        for _ in 0..n {
            let (_, outcome) = run_once(run, cfg.preempt, &[], None, Some(&mut rng));
            report.schedules += 1;
            if let RunOutcome::Failure(msg) = outcome {
                report.failure = Some(msg);
                break;
            }
        }
        report.millis = t0.elapsed().as_millis();
        return report;
    }

    let mut seen: HashSet<u64> = HashSet::new();
    let mut prefix: Vec<(u32, u32)> = Vec::new();
    loop {
        let (trace, outcome) = run_once(run, cfg.preempt, &prefix, Some(&mut seen), None);
        report.schedules += 1;
        match outcome {
            RunOutcome::Failure(msg) => {
                report.failure = Some(msg);
                break;
            }
            RunOutcome::Pruned => report.pruned += 1,
            RunOutcome::Complete => {}
        }
        if report.schedules >= cfg.max_schedules {
            break; // cap hit: exhausted stays false
        }
        // Backtrack: deepest choice point with an unexplored sibling.
        let Some(i) = (0..trace.len()).rfind(|&i| trace[i].1 + 1 < trace[i].0) else {
            report.exhausted = true;
            break;
        };
        prefix.clear();
        prefix.extend_from_slice(&trace[..i]);
        prefix.push((trace[i].0, trace[i].1 + 1));
    }
    report.millis = t0.elapsed().as_millis();
    report
}
