//! `symphony check` — a CHESS/loom-style deterministic concurrency
//! model checker for the lock-free fabric (offline registry: no loom,
//! no syn; std-only, like `util/error.rs` and the lint tokenizer).
//!
//! PR 7 hand-rolled the fabric — the Vyukov MPSC ring, the Dekker
//! `Parker`, `FreeHints` merge-publish — and its wake-not-lost and
//! exactly-once invariants were desk-checked prose plus whatever
//! schedules nightly TSan happened to sample. This subsystem makes
//! them machine-checked: the protocol code (generic over
//! `util::shim::Fabric`) is instantiated on a virtual fabric whose
//! every atomic/fence/blocking edge traps into a cooperative
//! scheduler, and a DFS explorer enumerates every distinct
//! interleaving up to a preemption bound, under a TSO memory model
//! with store buffers and vector-clock race detection.
//!
//! Layout: [`sched`] (scheduler + virtual memory), [`virt`] (the
//! instrumented `Fabric`), [`explore`] (DFS + pruning + random walk),
//! [`models`] (the closed model set, incl. two seeded bugs that the
//! checker must fail). CLI: `symphony check --all`, gated in CI; the
//! tier-1 mirror is `rust/tests/check_explorer.rs`.

pub mod explore;
pub mod models;
pub mod sched;
pub mod virt;

pub use explore::{explore, ExploreConfig, ExploreReport};
pub use models::{all_models, find_model, Model};
pub use sched::vspawn;

/// Verdict for one model under one exploration config.
pub struct ModelReport {
    pub name: &'static str,
    pub expect_fail: bool,
    pub report: ExploreReport,
    /// Passed its contract: failure-free for real models, at least
    /// one failing schedule found for seeded (`expect_fail`) ones.
    pub ok: bool,
}

/// Explore one model and judge it against its contract.
pub fn check_model(m: &Model, cfg: ExploreConfig) -> ModelReport {
    let report = explore(m.run, cfg);
    let ok = if m.expect_fail {
        report.failure.is_some()
    } else {
        report.failure.is_none()
    };
    ModelReport {
        name: m.name,
        expect_fail: m.expect_fail,
        report,
        ok,
    }
}

/// Explore every registered model. Returns the per-model reports and
/// whether all met their contracts.
pub fn check_all(cfg: ExploreConfig) -> (Vec<ModelReport>, bool) {
    let reports: Vec<ModelReport> = all_models().iter().map(|m| check_model(m, cfg)).collect();
    let all_ok = reports.iter().all(|r| r.ok);
    (reports, all_ok)
}
