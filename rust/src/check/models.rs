//! The closed model set `symphony check` explores: small, terminating
//! concurrent programs built from the *production* fabric code
//! instantiated at [`VirtFabric`], plus two deliberately broken
//! replicas (`expect_fail`) that prove the checker actually detects
//! the bug classes it exists for.
//!
//! Model-authoring rules (the explorer depends on them):
//!
//! * Deterministic apart from scheduling: no clocks, no OS entropy —
//!   `recv()`/`try_send` only (never `send`/`recv_timeout`, which read
//!   `Instant::now`), no unbounded retry loops (every loop must be
//!   bounded by a delivery the schedule guarantees).
//! * All shared objects created in the single-threaded setup section,
//!   so scheduler ids — and therefore state fingerprints — are
//!   schedule-independent.
//! * At most [`crate::check::sched::MAX_THREADS`] threads, spawned via
//!   [`vspawn`], all joined or provably finished at model exit.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::sched::vspawn;
use super::virt::{VirtAtomic, VirtBlocker, VirtCellToken, VirtFabric};
use crate::coordinator::router::GenericFreeHints;
use crate::util::ring::{ring_in, GenericParker};
use crate::util::shim::{Fabric, ShimAtomic, ShimBlocker};

/// One checkable model. `expect_fail` inverts the verdict: the
/// explorer must find at least one failing schedule (these are the
/// seeded-bug meta-models that keep the checker honest).
pub struct Model {
    pub name: &'static str,
    pub about: &'static str,
    pub expect_fail: bool,
    pub run: fn(),
}

pub fn all_models() -> &'static [Model] {
    &MODELS
}

pub fn find_model(name: &str) -> Option<&'static Model> {
    MODELS.iter().find(|m| m.name == name)
}

static MODELS: [Model; 9] = [
    Model {
        name: "parker-wake",
        about: "Dekker wake-not-lost: a parking consumer never misses the producer's wake",
        expect_fail: false,
        run: parker_wake,
    },
    Model {
        name: "parker-cancel",
        about: "prepare/cancel racing a wake leaves the parker reusable",
        expect_fail: false,
        run: parker_cancel,
    },
    Model {
        name: "ring-spsc-wrap",
        about: "capacity-2 ring: FIFO exactly-once through two full wrap laps",
        expect_fail: false,
        run: ring_spsc_wrap,
    },
    Model {
        name: "ring-mpsc",
        about: "two producers, one consumer: exactly-once delivery as a multiset",
        expect_fail: false,
        run: ring_mpsc,
    },
    Model {
        name: "ring-disconnect",
        about: "sender-drop disconnect wakes a blocked receiver; buffered values survive",
        expect_fail: false,
        run: ring_disconnect,
    },
    Model {
        name: "hints-reserve",
        about: "one advertised slot, two racing steerers: exactly one reservation wins",
        expect_fail: false,
        run: hints_reserve,
    },
    Model {
        name: "hints-republish",
        about: "owner republish racing reserve+redeem never resurrects a claimed slot",
        expect_fail: false,
        run: hints_republish,
    },
    Model {
        name: "seeded-parker-nofence",
        about: "SEEDED BUG (must fail): Dekker fence removed from the parker — lost wake",
        expect_fail: true,
        run: seeded_parker_nofence,
    },
    Model {
        name: "seeded-ring-relaxed-publish",
        about: "SEEDED BUG (must fail): slot publish downgraded to Relaxed — data race",
        expect_fail: true,
        run: seeded_ring_relaxed_publish,
    },
];

// ---------------------------------------------------------------- parker

/// The production wake-not-lost protocol, verbatim
/// (`GenericParker<VirtFabric>` *is* `util::ring::Parker`'s code): a
/// consumer that announces PARKED and re-checks must either see the
/// producer's flag or be notified — every schedule, even with both
/// sides' stores sitting in TSO buffers.
fn parker_wake() {
    let p = Arc::new(GenericParker::<VirtFabric>::new());
    let flag = Arc::new(VirtFabric::atomic(0));
    let (p2, f2) = (p.clone(), flag.clone());
    let producer = vspawn(move || {
        f2.store(1, Ordering::Release);
        p2.wake();
    });
    loop {
        if flag.load(Ordering::Acquire) == 1 {
            break;
        }
        p.prepare();
        if flag.load(Ordering::Acquire) == 1 {
            p.cancel();
            break;
        }
        // A lost wake deadlocks right here — the explorer reports it.
        p.park(None);
    }
    producer.join();
}

/// The cancel path: a consumer that withdraws its park announcement
/// (re-check found the flag) must leave the parker in a state where a
/// later prepare/cancel cycle still terminates, even when the
/// withdrawal raced the producer's CAS to NOTIFIED.
fn parker_cancel() {
    let p = Arc::new(GenericParker::<VirtFabric>::new());
    let flag = Arc::new(VirtFabric::atomic(0));
    let (p2, f2) = (p.clone(), flag.clone());
    let producer = vspawn(move || {
        f2.store(1, Ordering::Release);
        p2.wake();
    });
    p.prepare();
    if flag.load(Ordering::Acquire) == 1 {
        p.cancel();
    } else {
        p.park(None);
    }
    producer.join();
    assert_eq!(flag.load(Ordering::Acquire), 1, "join orders the flag store");
    // Reusability after a possibly-raced cancel: the state machine
    // must not wedge a later cycle (a leaked NOTIFIED is consumed by
    // park's swap; a leaked PARKED would hang the next wake-less
    // cancel — which this exercises).
    p.prepare();
    p.cancel();
}

// ------------------------------------------------------------------ ring

/// SPSC through the smallest ring: two concurrent sends into a
/// capacity-2 ring (never full by construction), consumed blocking;
/// then a sequential lap crossing the wrap boundary twice, exercising
/// the Vyukov `seq == pos + capacity` recycle arithmetic.
fn ring_spsc_wrap() {
    let (tx, rx) = ring_in::<usize, VirtFabric>(2);
    let producer = vspawn(move || {
        tx.try_send(1).expect("cap-2 ring holds a 1st value");
        tx.try_send(2).expect("cap-2 ring holds a 2nd value");
        tx
    });
    let a = rx.recv().expect("producer alive");
    let b = rx.recv().expect("producer alive");
    assert_eq!((a, b), (1, 2), "FIFO exactly-once");
    let tx = producer.join();
    for lap in 3..7usize {
        tx.try_send(lap).expect("empty ring accepts");
        assert_eq!(rx.recv(), Ok(lap), "wrap lap delivers in order");
    }
    drop(tx);
    assert!(rx.recv().is_err(), "last sender gone: disconnect, not hang");
}

/// MPSC exactly-once: two producers race their tail-CAS claims; the
/// consumer must see each value exactly once, in some order, and then
/// a clean disconnect once both sender handles dropped.
fn ring_mpsc() {
    let (tx, rx) = ring_in::<usize, VirtFabric>(4);
    let t1 = tx.clone();
    let p1 = vspawn(move || t1.try_send(10).expect("cap 4, 2 sends total"));
    let p2 = vspawn(move || tx.try_send(20).expect("cap 4, 2 sends total"));
    let a = rx.recv().expect("senders alive");
    let b = rx.recv().expect("senders alive");
    assert!(
        (a == 10 && b == 20) || (a == 20 && b == 10),
        "exactly-once multiset, got ({a}, {b})"
    );
    p1.join();
    p2.join();
    assert!(rx.recv().is_err(), "both senders dropped: disconnect");
}

/// The sender-drop disconnect edge: the last sender's drop must wake a
/// receiver that parked between the send and the drop, and buffered
/// values must survive the disconnect.
fn ring_disconnect() {
    let (tx, rx) = ring_in::<usize, VirtFabric>(2);
    let producer = vspawn(move || {
        tx.try_send(7).expect("empty ring accepts");
        // tx drops here: senders hits 0, the drop wakes the receiver.
    });
    let mut got = Vec::new();
    loop {
        match rx.recv() {
            Ok(v) => got.push(v),
            Err(_) => break,
        }
    }
    assert_eq!(got, vec![7], "value delivered once, then disconnect");
    producer.join();
}

// ----------------------------------------------------------------- hints

/// The PR-6 invariant, now schedule-exhaustive: one advertised slot,
/// two racing `reserve` calls — exactly one may claim it.
fn hints_reserve() {
    let h = GenericFreeHints::<VirtFabric>::new(1);
    h.publish(0, 1);
    let (h1, h2) = (h.clone(), h.clone());
    let a = vspawn(move || h1.reserve(0));
    let b = vspawn(move || h2.reserve(0));
    let (ra, rb) = (a.join(), b.join());
    assert!(ra != rb, "exactly one steerer claims the single slot");
    assert_eq!(h.free_of(0), 0, "the advertisement is spent");
    assert!(!h.reserve(0), "an empty hint is never claimable");
}

/// Merge-publish racing a reserve+redeem: wherever the owner's
/// republish lands in the steerer's sequence, the claim is discounted
/// at most once and at least the un-redeemed window — the advertised
/// count ends in [1, 2], never 0 (lost slot) or 3 (resurrected claim).
fn hints_republish() {
    let h = GenericFreeHints::<VirtFabric>::new(1);
    h.publish(0, 2);
    let h1 = h.clone();
    let steerer = vspawn(move || {
        let got = h1.reserve(0);
        if got {
            h1.redeem(0);
        }
        got
    });
    h.publish(0, 2); // the racing republish (owner still sees 2 free)
    assert!(steerer.join(), "two advertised slots: reserve cannot fail");
    let free = h.free_of(0);
    assert!(
        (1..=2).contains(&free),
        "republish must neither lose nor resurrect the claim: free = {free}"
    );
}

// ---------------------------------------------------------- seeded bugs

/// A Parker replica with the Dekker edge removed: `prepare` publishes
/// PARKED with a plain Release store (no SeqCst, no fence) and `wake`
/// drops its fence. On TSO both announcements can sit in store
/// buffers while both re-checks read stale memory — the classic
/// store-buffering litmus — and the consumer parks forever. The
/// explorer MUST report the deadlock (within 1 preemption).
fn seeded_parker_nofence() {
    const EMPTY: usize = 0;
    const PARKED: usize = 1;
    const NOTIFIED: usize = 2;
    struct NoFenceParker {
        state: VirtAtomic,
        blocker: VirtBlocker,
    }
    impl NoFenceParker {
        fn prepare(&self) {
            // SEEDED BUG: should be a SeqCst store + SeqCst fence.
            self.state.store(PARKED, Ordering::Release);
        }
        fn cancel(&self) {
            self.state.store(EMPTY, Ordering::SeqCst);
        }
        fn park(&self) {
            self.blocker
                .block_while(&mut || self.state.load(Ordering::SeqCst) == PARKED, None);
            let _ = self.state.swap(EMPTY, Ordering::SeqCst) == NOTIFIED;
        }
        fn wake(&self) {
            // SEEDED BUG: the SeqCst fence before this load is removed.
            if self.state.load(Ordering::Acquire) == PARKED {
                self.blocker.update_and_notify(&mut || {
                    self.state
                        .compare_exchange(PARKED, NOTIFIED, Ordering::SeqCst, Ordering::SeqCst)
                        .is_ok()
                });
            }
        }
    }
    let p = Arc::new(NoFenceParker {
        state: VirtFabric::atomic(EMPTY),
        blocker: VirtFabric::blocker(),
    });
    let flag = Arc::new(VirtFabric::atomic(0));
    let (p2, f2) = (p.clone(), flag.clone());
    let producer = vspawn(move || {
        f2.store(1, Ordering::Release);
        p2.wake();
    });
    loop {
        if flag.load(Ordering::Acquire) == 1 {
            break;
        }
        p.prepare();
        if flag.load(Ordering::Acquire) == 1 {
            p.cancel();
            break;
        }
        p.park();
    }
    producer.join();
}

/// A single-slot ring replica with the publish downgraded from
/// Release to Relaxed. The consumer's Acquire load can see the
/// sequence flip without acquiring a happens-before edge to the
/// payload write (a Relaxed store drains with an empty clock), so the
/// payload read races the write. The explorer MUST report the race.
fn seeded_ring_relaxed_publish() {
    struct BrokenSlot {
        seq: VirtAtomic,
        val: UnsafeCell<MaybeUninit<u64>>,
        tok: VirtCellToken,
    }
    // SAFETY: the payload cell is handed between exactly two threads
    // under the seq protocol this model exists to break; the checker's
    // cell race detector (keyed by `tok`) is the real guard — a
    // schedule where the handoff is unsound is *reported*, not relied
    // on to be absent.
    unsafe impl Send for BrokenSlot {}
    // SAFETY: same protocol argument as the Send impl above.
    unsafe impl Sync for BrokenSlot {}
    let s = Arc::new(BrokenSlot {
        seq: VirtFabric::atomic(0),
        val: UnsafeCell::new(MaybeUninit::uninit()),
        tok: VirtFabric::cell_token(),
    });
    let s2 = s.clone();
    let producer = vspawn(move || {
        VirtFabric::cell_write(&s2.tok);
        // SAFETY: slot unpublished (seq still 0), single producer —
        // exclusive write access by construction of this model.
        unsafe { (*s2.val.get()).write(42) };
        // SEEDED BUG: the publish should be Ordering::Release.
        s2.seq.store(1, Ordering::Relaxed);
    });
    if s.seq.load(Ordering::Acquire) == 1 {
        VirtFabric::cell_read(&s.tok);
        // SAFETY: guarded by the seq Acquire load — exactly the claim
        // the seeded Relaxed publish breaks; the checker must object
        // via the race detector before this read is trusted.
        let v = unsafe { (*s.val.get()).assume_init_read() };
        assert_eq!(v, 42);
    }
    producer.join();
}
