//! The cooperative scheduler behind `symphony check`: virtual threads,
//! a TSO memory model with per-thread store buffers, vector-clock
//! happens-before tracking, and a virtual Mutex/Condvar blocker.
//!
//! Model code runs on real OS threads, but every shim operation
//! (`check::virt::VirtFabric`) traps here and parks until the
//! controller (the explorer's `run_once` loop) grants it the baton —
//! so exactly one model thread makes exactly one memory step at a
//! time, and the controller chooses which. The schedule is the
//! sequence of those choices.
//!
//! Memory model — TSO, the strongest model our targets (x86) actually
//! give and weak enough to catch the fabric's real bug classes:
//!
//! * A `Relaxed`/`Release` store goes into the storing thread's FIFO
//!   buffer; it reaches shared memory either when the controller picks
//!   a *drain* action (an un-counted hardware step) or when the thread
//!   flushes — `SeqCst` stores, RMWs, SeqCst fences, blocking, and
//!   finishing all flush. Loads forward from the own buffer first.
//!   This is what detects a missing Dekker fence: both sides' stores
//!   sit buffered while both sides' loads read stale memory.
//! * Release stores carry a vector-clock snapshot; an Acquire load
//!   that reads memory joins the clock the last store published.
//!   A `Relaxed` store drains with an *empty* clock — it breaks the
//!   release chain, which is what detects a publish downgraded to
//!   `Relaxed`: the consumer sees the flag but acquires no
//!   happens-before edge to the payload write.
//! * Slot payloads (`UnsafeCell` accesses) are tracked per cell:
//!   a read must happen-after the last write, a write must
//!   happen-after every prior access, and a read before any write is
//!   a use of an uninitialized slot. Violations are reported as data
//!   races, not relied upon to crash.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

/// Panic payload used to unwind model threads when a run is abandoned
/// (failure found, schedule pruned, or deadlock detected). The thread
/// wrapper swallows it; any other payload is a real model failure.
pub(crate) struct CheckAbort;

/// Upper bound on virtual threads per model (vector clocks are
/// fixed-width).
pub(crate) const MAX_THREADS: usize = 8;

type Vc = [u32; MAX_THREADS];

fn vc_join(a: &mut Vc, b: &Vc) {
    for i in 0..MAX_THREADS {
        a[i] = a[i].max(b[i]);
    }
}

fn vc_leq(a: &Vc, b: &Vc) -> bool {
    (0..MAX_THREADS).all(|i| a[i] <= b[i])
}

fn is_acquire(o: Ordering) -> bool {
    matches!(o, Ordering::Acquire | Ordering::AcqRel | Ordering::SeqCst)
}

fn is_release(o: Ordering) -> bool {
    matches!(o, Ordering::Release | Ordering::AcqRel | Ordering::SeqCst)
}

/// What a thread parked at a scheduling point wants to do next. The
/// controller needs this for enabledness (locks, joins) and for the
/// state fingerprint; the operation itself is applied by the thread.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum Desc {
    /// Synthetic first point of every thread.
    Start,
    /// Any always-enabled atomic op on atomic `id`.
    Atomic(usize),
    /// SeqCst fence.
    Fence,
    /// Instrumented cell (slot payload) access.
    Cell(usize),
    /// Blocker lock acquire — enabled only while the lock is free.
    Lock(usize),
    /// Condvar wait (atomically releases the lock and sleeps).
    CvWait(usize),
    CvNotify(usize),
    Unlock(usize),
    /// Join on a virtual thread — enabled once the target finished.
    Join(usize),
}

impl Desc {
    fn tag(self) -> u64 {
        match self {
            Desc::Start => 1,
            Desc::Atomic(i) => 2 + ((i as u64) << 4),
            Desc::Fence => 3,
            Desc::Cell(i) => 4 + ((i as u64) << 4),
            Desc::Lock(i) => 5 + ((i as u64) << 4),
            Desc::CvWait(i) => 6 + ((i as u64) << 4),
            Desc::CvNotify(i) => 7 + ((i as u64) << 4),
            Desc::Unlock(i) => 8 + ((i as u64) << 4),
            Desc::Join(i) => 9 + ((i as u64) << 4),
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum Status {
    /// Executing model code between scheduling points.
    Running,
    /// Parked at a point, waiting for the baton.
    AtPoint(Desc),
    /// Asleep inside a virtual Condvar wait on lock `id`.
    BlockedCv(usize),
    Finished,
}

struct BufEntry {
    atom: usize,
    val: usize,
    /// Release stores carry the storer's clock; `None` for Relaxed —
    /// the drained store then *erases* the cell's sync clock, breaking
    /// the release chain (what makes a downgraded publish detectable).
    sync: Option<Vc>,
}

struct ThreadState {
    status: Status,
    vc: Vc,
    buffer: VecDeque<BufEntry>,
    /// FNV fold of (op kind, observed value) — makes the thread's
    /// local execution state a deterministic function of the
    /// fingerprint (the code is deterministic given its observations).
    obs: u64,
    /// Set by a notifier/unlocker handing this CvWait-blocked thread
    /// the lock back; the sleeping thread resumes when it sees it.
    resume: bool,
}

impl ThreadState {
    fn new(vc: Vc) -> Self {
        ThreadState {
            status: Status::Running,
            vc,
            buffer: VecDeque::new(),
            obs: 0xcbf2_9ce4_8422_2325,
            resume: false,
        }
    }
}

struct MemCell {
    val: usize,
    sync: Vc,
}

#[derive(Default)]
struct LockState {
    held_by: Option<usize>,
    /// CvWait-woken threads queued for the lock; unlock hands off
    /// FIFO. (Deterministic refinement of std's unspecified order.)
    reacquirers: VecDeque<usize>,
    cv_waiters: VecDeque<usize>,
    /// Release clock of the last holder — acquiring joins it.
    sync: Vc,
}

struct CellState {
    written: bool,
    last_write: Vc,
    /// Join of all reader clocks since the last write.
    reads: Vc,
}

pub(crate) struct State {
    threads: Vec<ThreadState>,
    mem: Vec<MemCell>,
    locks: Vec<LockState>,
    cells: Vec<CellState>,
    granted: Option<usize>,
    last_go: Option<usize>,
    /// Remaining preemption budget for this run.
    pub(crate) budget: u32,
    pub(crate) failure: Option<String>,
    pub(crate) aborting: bool,
}

impl State {
    fn new(budget: u32) -> Self {
        State {
            threads: vec![ThreadState::new([0; MAX_THREADS])],
            mem: Vec::new(),
            locks: Vec::new(),
            cells: Vec::new(),
            granted: None,
            last_go: None,
            budget,
            failure: None,
            aborting: false,
        }
    }

    fn tick(&mut self, t: usize) {
        self.threads[t].vc[t] += 1;
    }

    fn obs(&mut self, t: usize, tag: u64, val: u64) {
        let th = &mut self.threads[t];
        for x in [tag, val] {
            th.obs = (th.obs ^ x).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn fail(&mut self, msg: String) {
        if self.failure.is_none() {
            self.failure = Some(msg);
        }
        self.aborting = true;
    }

    /// Write-through to shared memory (a drain, flush, or SeqCst/RMW
    /// store). A `None` sync clock (Relaxed store) erases the cell's —
    /// a Relaxed store heads a release sequence that synchronizes with
    /// nothing.
    fn mem_write(&mut self, atom: usize, val: usize, sync: Option<Vc>) {
        let c = &mut self.mem[atom];
        c.val = val;
        c.sync = sync.unwrap_or([0; MAX_THREADS]);
    }

    fn flush(&mut self, t: usize) {
        while let Some(e) = self.threads[t].buffer.pop_front() {
            self.mem_write(e.atom, e.val, e.sync);
        }
    }

    fn drain_one(&mut self, t: usize) {
        if let Some(e) = self.threads[t].buffer.pop_front() {
            self.mem_write(e.atom, e.val, e.sync);
        }
    }

    /// Release the blocker lock `id` on behalf of `t`: publish `t`'s
    /// clock into the lock and hand off FIFO to a CvWait reacquirer if
    /// one is queued (their clock joins the lock's at handoff).
    fn lock_release(&mut self, id: usize, t: usize) {
        let vc = self.threads[t].vc;
        let l = &mut self.locks[id];
        vc_join(&mut l.sync, &vc);
        if let Some(w) = l.reacquirers.pop_front() {
            l.held_by = Some(w);
            let sync = l.sync;
            vc_join(&mut self.threads[w].vc, &sync);
            self.threads[w].resume = true;
            self.threads[w].status = Status::Running;
        } else {
            l.held_by = None;
        }
    }

    fn is_enabled(&self, t: usize) -> bool {
        match self.threads[t].status {
            Status::AtPoint(Desc::Lock(id)) => self.locks[id].held_by.is_none(),
            Status::AtPoint(Desc::Join(target)) => {
                matches!(self.threads[target].status, Status::Finished)
            }
            Status::AtPoint(_) => true,
            _ => false,
        }
    }

    fn all_finished(&self) -> bool {
        self.threads
            .iter()
            .all(|t| matches!(t.status, Status::Finished))
    }

    /// No model thread is mid-step: everyone is parked at a point,
    /// asleep in a CvWait, or finished, and no baton is outstanding.
    fn quiescent(&self) -> bool {
        self.granted.is_none()
            && self
                .threads
                .iter()
                .all(|t| !matches!(t.status, Status::Running))
    }

    /// The deterministic enabled-action list the controller chooses
    /// from: runnable threads (restricted to the incumbent once the
    /// preemption budget is spent) plus one drain action per non-empty
    /// store buffer (drains are hardware, never preemptions).
    pub(crate) fn enabled_actions(&self) -> Vec<Action> {
        let restrict = self.budget == 0 && self.last_go.map_or(false, |t| self.is_enabled(t));
        let mut acts = Vec::new();
        for t in 0..self.threads.len() {
            if self.is_enabled(t) && (!restrict || self.last_go == Some(t)) {
                acts.push(Action::Go(t));
            }
        }
        for t in 0..self.threads.len() {
            if !self.threads[t].buffer.is_empty() {
                acts.push(Action::Drain(t));
            }
        }
        acts
    }

    pub(crate) fn describe_stuck(&self) -> String {
        let mut parts = Vec::new();
        for (i, t) in self.threads.iter().enumerate() {
            parts.push(match t.status {
                Status::Finished => format!("t{i}: finished"),
                Status::BlockedCv(l) => format!("t{i}: blocked in condvar wait (lock {l})"),
                Status::AtPoint(d) => format!("t{i}: stuck at {d:?}"),
                Status::Running => format!("t{i}: running"),
            });
        }
        format!("deadlock: no enabled action [{}]", parts.join(", "))
    }

    /// Canonical state hash for pruning. Everything schedule-visible
    /// goes in: per-thread status/observation hashes, shared memory
    /// values and sync clocks, store buffers, cell race-detector
    /// state, locks, the remaining preemption budget, and the
    /// incumbent thread. Ids are assigned at *creation* (model setup
    /// runs single-threaded), so they are schedule-independent and
    /// equal hashes mean equal states.
    pub(crate) fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut f = |x: u64| {
            h = (h ^ x).wrapping_mul(0x0000_0100_0000_01b3);
        };
        for t in &self.threads {
            f(match t.status {
                Status::Running => 1,
                Status::AtPoint(d) => 2 ^ (d.tag() << 8),
                Status::BlockedCv(l) => 3 ^ ((l as u64) << 8),
                Status::Finished => 4,
            });
            f(t.obs);
            f(t.buffer.len() as u64);
            for e in &t.buffer {
                f(e.atom as u64);
                f(e.val as u64);
                match &e.sync {
                    None => f(0),
                    Some(vc) => vc.iter().for_each(|&c| f(1 + c as u64)),
                }
            }
            t.vc.iter().for_each(|&c| f(c as u64));
        }
        for m in &self.mem {
            f(m.val as u64);
            m.sync.iter().for_each(|&c| f(c as u64));
        }
        for l in &self.locks {
            f(l.held_by.map_or(0, |t| 1 + t as u64));
            f(l.reacquirers.iter().fold(7, |a, &t| a * 31 + t as u64));
            f(l.cv_waiters.iter().fold(7, |a, &t| a * 31 + t as u64));
            l.sync.iter().for_each(|&c| f(c as u64));
        }
        for c in &self.cells {
            f(c.written as u64);
            c.last_write.iter().for_each(|&x| f(x as u64));
            c.reads.iter().for_each(|&x| f(x as u64));
        }
        f(self.budget as u64);
        f(self.last_go.map_or(0, |t| 1 + t as u64));
        h
    }

    /// Grant the baton for `a` (controller side). Switching away from
    /// a still-enabled incumbent costs one preemption; drains cost
    /// nothing.
    pub(crate) fn apply_action(&mut self, a: Action) {
        match a {
            Action::Go(t) => {
                if let Some(prev) = self.last_go {
                    if prev != t && self.is_enabled(prev) {
                        self.budget = self.budget.saturating_sub(1);
                    }
                }
                self.last_go = Some(t);
                self.granted = Some(t);
            }
            Action::Drain(t) => self.drain_one(t),
        }
    }
}

/// One controller choice: hand the baton to a thread, or drain the
/// oldest buffered store of a thread (a hardware step — uncounted).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub(crate) enum Action {
    Go(usize),
    Drain(usize),
}

// ------------------------------------------------------------ scheduler

pub(crate) struct Sched {
    state: Mutex<State>,
    cv: Condvar,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// The exploration currently running in this process. `RUN_LOCK` (in
/// `explore.rs`) serializes explorations, so one slot suffices; model
/// threads find their scheduler here.
static CURRENT: Mutex<Option<Arc<Sched>>> = Mutex::new(None);

thread_local! {
    static TID: Cell<Option<usize>> = Cell::new(None);
}

fn cur_tid() -> usize {
    TID.with(|t| t.get())
        .expect("virtual fabric op outside a symphony check thread")
}

pub(crate) fn with_sched() -> Arc<Sched> {
    CURRENT
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .clone()
        .expect("virtual fabric op outside a symphony check run")
}

pub(crate) fn install(sched: &Arc<Sched>) {
    *CURRENT.lock().unwrap_or_else(PoisonError::into_inner) = Some(sched.clone());
}

pub(crate) fn uninstall() {
    *CURRENT.lock().unwrap_or_else(PoisonError::into_inner) = None;
}

impl Sched {
    pub(crate) fn new(budget: u32) -> Arc<Sched> {
        Arc::new(Sched {
            state: Mutex::new(State::new(budget)),
            cv: Condvar::new(),
            handles: Mutex::new(Vec::new()),
        })
    }

    pub(crate) fn lockst(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn cvwait<'a>(&'a self, g: MutexGuard<'a, State>) -> MutexGuard<'a, State> {
        self.cv.wait(g).unwrap_or_else(PoisonError::into_inner)
    }

    /// Controller side: block until the model is quiescent (or a
    /// failure was recorded), so `enabled_actions` is meaningful.
    pub(crate) fn wait_quiescent(&self) -> MutexGuard<'_, State> {
        let mut g = self.lockst();
        while !(g.quiescent() || g.failure.is_some()) {
            g = self.cvwait(g);
        }
        g
    }

    pub(crate) fn notify(&self) {
        self.cv.notify_all();
    }

    /// Abandon the run: unwind every model thread (blocked waits wake
    /// and panic `CheckAbort`; threads mid-unwind fall into the
    /// apply-immediately fast path so drops never double-panic).
    pub(crate) fn abort(&self) {
        self.lockst().aborting = true;
        self.cv.notify_all();
    }

    pub(crate) fn join_all(&self) {
        let handles: Vec<_> = self
            .handles
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .drain(..)
            .collect();
        for h in handles {
            let _ = h.join();
        }
    }

    /// The heart of the trap: park at a scheduling point, wait for the
    /// baton, apply the operation under the state lock, hand control
    /// back. While the run is aborting this degrades to an
    /// apply-immediately fast path (never blocks), and threads that
    /// are *not* already unwinding are unwound via `CheckAbort`.
    fn act<R>(&self, desc: Desc, apply: impl FnOnce(&mut State, usize) -> R) -> R {
        let me = cur_tid();
        let mut g = self.lockst();
        if !g.aborting {
            g.threads[me].status = Status::AtPoint(desc);
            self.cv.notify_all();
            while !g.aborting && g.granted != Some(me) {
                g = self.cvwait(g);
            }
            if g.granted == Some(me) {
                g.granted = None;
            }
            g.threads[me].status = Status::Running;
            g.tick(me);
        }
        let abort = g.aborting;
        let r = apply(&mut g, me);
        drop(g);
        self.cv.notify_all();
        if abort && !std::thread::panicking() {
            panic::panic_any(CheckAbort);
        }
        r
    }

    // ---------------------------------------------- object registration

    /// Ids are handed out at object *creation* (not first access), so
    /// they depend only on the model's deterministic setup code, never
    /// on the schedule — a requirement for fingerprint comparability
    /// across schedules. Registration is not a scheduling point.
    pub(crate) fn alloc_atomic(&self, init: usize) -> usize {
        let mut g = self.lockst();
        g.mem.push(MemCell {
            val: init,
            sync: [0; MAX_THREADS],
        });
        g.mem.len() - 1
    }

    pub(crate) fn alloc_lock(&self) -> usize {
        let mut g = self.lockst();
        g.locks.push(LockState::default());
        g.locks.len() - 1
    }

    pub(crate) fn alloc_cell(&self) -> usize {
        let mut g = self.lockst();
        g.cells.push(CellState {
            written: false,
            last_write: [0; MAX_THREADS],
            reads: [0; MAX_THREADS],
        });
        g.cells.len() - 1
    }

    // ----------------------------------------------------- atomic ops

    pub(crate) fn atomic_load(&self, id: usize, order: Ordering) -> usize {
        self.act(Desc::Atomic(id), |g, me| {
            // TSO store forwarding: a thread always sees its own
            // buffered stores, newest first.
            let forwarded = g.threads[me]
                .buffer
                .iter()
                .rev()
                .find(|e| e.atom == id)
                .map(|e| e.val);
            let v = match forwarded {
                Some(v) => v,
                None => {
                    let (val, sync) = {
                        let c = &g.mem[id];
                        (c.val, c.sync)
                    };
                    if is_acquire(order) {
                        vc_join(&mut g.threads[me].vc, &sync);
                    }
                    val
                }
            };
            g.obs(me, 10 + id as u64, v as u64);
            v
        })
    }

    pub(crate) fn atomic_store(&self, id: usize, val: usize, order: Ordering) {
        self.act(Desc::Atomic(id), |g, me| {
            if order == Ordering::SeqCst {
                // SeqCst stores flush (the x86 mapping: store + mfence).
                g.flush(me);
                let vc = g.threads[me].vc;
                g.mem_write(id, val, Some(vc));
            } else {
                let sync = is_release(order).then(|| g.threads[me].vc);
                g.threads[me].buffer.push_back(BufEntry {
                    atom: id,
                    val,
                    sync,
                });
            }
            g.obs(me, 20 + id as u64, val as u64);
        })
    }

    /// All RMWs (swap, fetch_add/sub, compare_exchange, fetch_update)
    /// funnel here: flush (LOCK-prefixed ops drain the buffer), read
    /// memory, maybe write. A successful relaxed RMW *preserves* the
    /// cell's sync clock (RMWs continue a release sequence); a
    /// release-ish one joins its own clock in.
    pub(crate) fn atomic_rmw(
        &self,
        id: usize,
        success: Ordering,
        failure: Ordering,
        f: &mut dyn FnMut(usize) -> Option<usize>,
    ) -> Result<usize, usize> {
        self.act(Desc::Atomic(id), |g, me| {
            g.flush(me);
            let old = g.mem[id].val;
            let r = match f(old) {
                Some(new) => {
                    let sync = g.mem[id].sync;
                    if is_acquire(success) {
                        vc_join(&mut g.threads[me].vc, &sync);
                    }
                    if is_release(success) {
                        let vc = g.threads[me].vc;
                        vc_join(&mut g.mem[id].sync, &vc);
                    }
                    g.mem[id].val = new;
                    Ok(old)
                }
                None => {
                    let sync = g.mem[id].sync;
                    if is_acquire(failure) {
                        vc_join(&mut g.threads[me].vc, &sync);
                    }
                    Err(old)
                }
            };
            g.obs(me, 30 + id as u64, (old as u64) << 1 | r.is_ok() as u64);
            r
        })
    }

    pub(crate) fn fence_seqcst(&self) {
        self.act(Desc::Fence, |g, me| {
            g.flush(me);
            g.obs(me, 40, 0);
        });
    }

    // ------------------------------------------------------- cell ops

    pub(crate) fn cell_read(&self, id: usize) {
        self.act(Desc::Cell(id), |g, me| {
            let my = g.threads[me].vc;
            let (written, last_write) = (g.cells[id].written, g.cells[id].last_write);
            if !written {
                g.fail(format!("cell {id}: read of uninitialized slot"));
            } else if !vc_leq(&last_write, &my) {
                g.fail(format!(
                    "cell {id}: data race — read does not happen-after last write \
                     (missing release/acquire edge on the publishing atomic)"
                ));
            } else {
                vc_join(&mut g.cells[id].reads, &my);
            }
            g.obs(me, 50 + id as u64, 0);
        });
    }

    pub(crate) fn cell_write(&self, id: usize) {
        self.act(Desc::Cell(id), |g, me| {
            let my = g.threads[me].vc;
            let (written, last_write, reads) = {
                let c = &g.cells[id];
                (c.written, c.last_write, c.reads)
            };
            if written && !vc_leq(&last_write, &my) {
                g.fail(format!("cell {id}: data race — concurrent writes"));
            } else if !vc_leq(&reads, &my) {
                g.fail(format!(
                    "cell {id}: data race — write concurrent with a prior read"
                ));
            } else {
                let c = &mut g.cells[id];
                c.written = true;
                c.last_write = my;
                c.reads = [0; MAX_THREADS];
            }
            g.obs(me, 60 + id as u64, 0);
        });
    }

    // ---------------------------------------------------- blocker ops

    pub(crate) fn blocker_lock(&self, id: usize) {
        self.act(Desc::Lock(id), |g, me| {
            if g.aborting {
                return; // lock discipline is moot on an abandoned run
            }
            debug_assert!(g.locks[id].held_by.is_none(), "granted a held lock");
            g.locks[id].held_by = Some(me);
            let sync = g.locks[id].sync;
            vc_join(&mut g.threads[me].vc, &sync);
            g.obs(me, 70 + id as u64, 0);
        });
    }

    pub(crate) fn blocker_unlock(&self, id: usize) {
        self.act(Desc::Unlock(id), |g, me| {
            if g.aborting {
                return;
            }
            g.lock_release(id, me);
            g.obs(me, 80 + id as u64, 0);
        });
    }

    pub(crate) fn blocker_notify(&self, id: usize) {
        self.act(Desc::CvNotify(id), |g, me| {
            if g.aborting {
                return;
            }
            if let Some(w) = g.locks[id].cv_waiters.pop_front() {
                if g.locks[id].held_by.is_none() {
                    g.locks[id].held_by = Some(w);
                    let sync = g.locks[id].sync;
                    vc_join(&mut g.threads[w].vc, &sync);
                    g.threads[w].resume = true;
                    g.threads[w].status = Status::Running;
                } else {
                    // Notifier holds the lock (the Parker's
                    // update_and_notify discipline): the waiter queues
                    // for the unlock handoff.
                    g.locks[id].reacquirers.push_back(w);
                }
            }
            g.obs(me, 90 + id as u64, 0);
        });
    }

    /// Condvar wait: atomically release the lock and sleep; wake
    /// holding the lock again (handed off by the notifier/unlocker).
    /// Cannot use `act` — the sleep happens *inside* the operation.
    pub(crate) fn blocker_cv_wait(&self, id: usize) {
        let me = cur_tid();
        let mut g = self.lockst();
        if !g.aborting {
            g.threads[me].status = Status::AtPoint(Desc::CvWait(id));
            self.cv.notify_all();
            while !g.aborting && g.granted != Some(me) {
                g = self.cvwait(g);
            }
            if g.granted == Some(me) {
                g.granted = None;
            }
            g.tick(me);
            if !g.aborting {
                // Blocking flushes the store buffer (kernel entry).
                g.flush(me);
                g.lock_release(id, me);
                g.threads[me].status = Status::BlockedCv(id);
                g.threads[me].resume = false;
                g.locks[id].cv_waiters.push_back(me);
                g.obs(me, 100 + id as u64, 0);
                self.cv.notify_all();
                while !g.threads[me].resume && !g.aborting {
                    g = self.cvwait(g);
                }
                g.threads[me].resume = false;
                g.threads[me].status = Status::Running;
            }
        }
        let abort = g.aborting;
        drop(g);
        self.cv.notify_all();
        if abort && !std::thread::panicking() {
            panic::panic_any(CheckAbort);
        }
    }

    // --------------------------------------------------- thread model

    fn register_thread(&self, parent: usize) -> usize {
        let mut g = self.lockst();
        g.tick(parent);
        let vc = g.threads[parent].vc;
        let tid = g.threads.len();
        assert!(tid < MAX_THREADS, "model exceeds {MAX_THREADS} threads");
        g.threads.push(ThreadState::new(vc));
        tid
    }

    fn thread_finished(&self, tid: usize, panic_payload: Option<Box<dyn std::any::Any + Send>>) {
        let mut g = self.lockst();
        g.flush(tid);
        g.threads[tid].status = Status::Finished;
        if let Some(p) = panic_payload {
            if !p.is::<CheckAbort>() {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "model panicked".to_string());
                g.fail(format!("t{tid}: {msg}"));
            }
        }
        drop(g);
        self.cv.notify_all();
    }

    fn spawn_thread<T: Send + 'static>(
        self: &Arc<Self>,
        tid: usize,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> VirtHandle<T> {
        let slot = Arc::new(Mutex::new(None));
        let sched = self.clone();
        let slot2 = slot.clone();
        let h = std::thread::spawn(move || {
            TID.with(|t| t.set(Some(tid)));
            let r = panic::catch_unwind(AssertUnwindSafe(|| {
                // Synthetic first point: a freshly spawned thread is
                // schedulable before its first real operation.
                sched.act(Desc::Start, |g, me| g.obs(me, 5, 0));
                f()
            }));
            match r {
                Ok(v) => {
                    *slot2.lock().unwrap_or_else(PoisonError::into_inner) = Some(v);
                    sched.thread_finished(tid, None);
                }
                Err(p) => sched.thread_finished(tid, Some(p)),
            }
        });
        self.handles
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(h);
        VirtHandle { tid, slot }
    }

    /// Start the model's root thread (tid 0) — called by the runner.
    pub(crate) fn spawn_root(self: &Arc<Self>, f: impl FnOnce() + Send + 'static) {
        self.spawn_thread(0, f);
    }
}

/// Handle to a virtual thread. `join` is a scheduling point (enabled
/// once the target finishes) and joins the target's vector clock.
pub struct VirtHandle<T> {
    tid: usize,
    slot: Arc<Mutex<Option<T>>>,
}

impl<T> VirtHandle<T> {
    pub fn join(self) -> T {
        let sched = with_sched();
        let tid = self.tid;
        sched.act(Desc::Join(tid), |g, me| {
            if !g.aborting {
                let tvc = g.threads[tid].vc;
                vc_join(&mut g.threads[me].vc, &tvc);
                g.obs(me, 110, tid as u64);
            }
        });
        self.slot
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .take()
            .expect("joined virtual thread left no result")
    }
}

/// Spawn a model thread under the active scheduler. Model code only —
/// panics outside a `symphony check` run.
pub fn vspawn<T: Send + 'static>(f: impl FnOnce() -> T + Send + 'static) -> VirtHandle<T> {
    let sched = with_sched();
    let tid = sched.register_thread(cur_tid());
    sched.spawn_thread(tid, f)
}
