//! The instrumented fabric: `util::shim::Fabric` implemented by
//! trapping every operation into the cooperative scheduler
//! (`check::sched`). Instantiating the *production* protocol code —
//! `GenericParker<VirtFabric>`, `ring_in::<T, VirtFabric>`,
//! `GenericFreeHints<VirtFabric>` — at this fabric is what lets
//! `symphony check` enumerate its interleavings without a second copy
//! of the protocols existing anywhere.
//!
//! Objects register with the scheduler at **creation** (not first
//! access), so their ids depend only on the model's single-threaded
//! setup code and state fingerprints are comparable across schedules.
//! Consequently the virtual fabric is only usable inside a check run;
//! constructing a `VirtAtomic` outside one panics.
//!
//! Semantics deviations from the real fabric, all safe-side:
//!
//! * `compare_exchange_weak` never fails spuriously (a deterministic
//!   refinement — spurious failure adds schedules in which the caller
//!   retries, which the surrounding loops make equivalent).
//! * Blocker deadlines are ignored (waits are `None`-infinite): models
//!   must not rely on timeouts, and none do — a lost wake must surface
//!   as a detected deadlock, not be papered over by a timeout.
//! * `spin_budget` is (0, 0): under exhaustive exploration a spin
//!   ladder is pure state-space, and the park edge is the protocol
//!   under test.

use std::sync::atomic::Ordering;
use std::time::Instant;

use super::sched::with_sched;
use crate::util::shim::{Fabric, ShimAtomic, ShimBlocker};

pub struct VirtAtomic {
    id: usize,
}

impl ShimAtomic for VirtAtomic {
    fn load(&self, order: Ordering) -> usize {
        with_sched().atomic_load(self.id, order)
    }

    fn store(&self, v: usize, order: Ordering) {
        with_sched().atomic_store(self.id, v, order)
    }

    fn swap(&self, v: usize, order: Ordering) -> usize {
        with_sched()
            .atomic_rmw(self.id, order, order, &mut |_| Some(v))
            .unwrap_or_else(|old| old)
    }

    fn compare_exchange(
        &self,
        current: usize,
        new: usize,
        success: Ordering,
        failure: Ordering,
    ) -> Result<usize, usize> {
        with_sched().atomic_rmw(self.id, success, failure, &mut |c| {
            (c == current).then_some(new)
        })
    }

    fn compare_exchange_weak(
        &self,
        current: usize,
        new: usize,
        success: Ordering,
        failure: Ordering,
    ) -> Result<usize, usize> {
        self.compare_exchange(current, new, success, failure)
    }

    fn fetch_add(&self, v: usize, order: Ordering) -> usize {
        with_sched()
            .atomic_rmw(self.id, order, order, &mut |c| Some(c.wrapping_add(v)))
            .unwrap_or_else(|old| old)
    }

    fn fetch_sub(&self, v: usize, order: Ordering) -> usize {
        with_sched()
            .atomic_rmw(self.id, order, order, &mut |c| Some(c.wrapping_sub(v)))
            .unwrap_or_else(|old| old)
    }

    fn fetch_update(
        &self,
        set_order: Ordering,
        fetch_order: Ordering,
        f: &mut dyn FnMut(usize) -> Option<usize>,
    ) -> Result<usize, usize> {
        with_sched().atomic_rmw(self.id, set_order, fetch_order, f)
    }
}

pub struct VirtBlocker {
    id: usize,
}

impl ShimBlocker for VirtBlocker {
    fn new() -> Self {
        VirtBlocker {
            id: with_sched().alloc_lock(),
        }
    }

    fn block_while(&self, keep_waiting: &mut dyn FnMut() -> bool, _deadline: Option<Instant>) {
        let s = with_sched();
        s.blocker_lock(self.id);
        while keep_waiting() {
            s.blocker_cv_wait(self.id);
        }
        s.blocker_unlock(self.id);
    }

    fn update_and_notify(&self, update: &mut dyn FnMut() -> bool) {
        let s = with_sched();
        s.blocker_lock(self.id);
        if update() {
            s.blocker_notify(self.id);
        }
        s.blocker_unlock(self.id);
    }
}

pub struct VirtCellToken {
    id: usize,
}

/// The model checker's fabric. See the module docs for the deliberate
/// semantic refinements versus [`crate::util::shim::RealFabric`].
pub struct VirtFabric;

impl Fabric for VirtFabric {
    type Atomic = VirtAtomic;
    type Blocker = VirtBlocker;
    type CellToken = VirtCellToken;

    fn atomic(v: usize) -> VirtAtomic {
        VirtAtomic {
            id: with_sched().alloc_atomic(v),
        }
    }

    fn blocker() -> VirtBlocker {
        VirtBlocker::new()
    }

    fn cell_token() -> VirtCellToken {
        VirtCellToken {
            id: with_sched().alloc_cell(),
        }
    }

    fn cell_read(tok: &VirtCellToken) {
        with_sched().cell_read(tok.id)
    }

    fn cell_write(tok: &VirtCellToken) {
        with_sched().cell_write(tok.id)
    }

    fn fence_seqcst() {
        with_sched().fence_seqcst()
    }

    fn spin_budget() -> (u32, u32) {
        (0, 0)
    }

    fn track_gauges() -> bool {
        // Gauges are advisory (never read by the handoff protocol);
        // their atomics would only multiply the explored state space.
        false
    }
}
