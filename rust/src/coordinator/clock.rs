//! Wall-clock mapping for the real-time coordinator: `Micros` since an
//! epoch `Instant`, so the same window math drives simulation and
//! serving.

use std::time::Instant;

use crate::core::time::Micros;

/// Monotonic clock with a fixed origin.
#[derive(Clone, Copy, Debug)]
pub struct Clock {
    origin: Instant,
}

impl Clock {
    pub fn new() -> Self {
        Clock {
            origin: Instant::now(),
        }
    }

    #[inline]
    pub fn now(&self) -> Micros {
        Micros(self.origin.elapsed().as_micros() as u64)
    }

    /// Duration from now until `t` (zero if already past).
    pub fn until(&self, t: Micros) -> std::time::Duration {
        let now = self.now();
        std::time::Duration::from_micros(t.0.saturating_sub(now.0))
    }
}

impl Default for Clock {
    fn default() -> Self {
        Clock::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotone() {
        let c = Clock::new();
        let a = c.now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = c.now();
        assert!(b > a);
        assert!(b.0 - a.0 >= 1_500, "elapsed {}", b.0 - a.0);
    }

    #[test]
    fn until_saturates() {
        let c = Clock::new();
        assert_eq!(c.until(Micros::ZERO), std::time::Duration::ZERO);
        let d = c.until(Micros(10_000_000));
        assert!(d.as_secs_f64() > 9.0);
    }
}
