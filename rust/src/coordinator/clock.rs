//! Wall-clock mapping for the real-time coordinator: `Micros` since an
//! epoch `Instant`, so the same window math drives simulation and
//! serving.

use std::time::Instant;

use crate::core::time::Micros;

/// Monotonic clock with a fixed origin. `base` shifts the origin so a
/// remote rank server can run its shards in the *client's* clock
/// domain: the client puts its current `now` in the wire handshake and
/// the server builds `Clock::starting_at(that)`, after which both
/// sides' timestamps (candidate windows, `GpuBusyUntil`) compare on the
/// same axis to within the handshake's one-way latency (budgeted by
/// `net_bound`, like the paper budgets the RDMA p99.99 in §5.6).
#[derive(Clone, Copy, Debug)]
pub struct Clock {
    origin: Instant,
    base: Micros,
}

impl Clock {
    pub fn new() -> Self {
        Clock {
            origin: Instant::now(),
            base: Micros::ZERO,
        }
    }

    /// A clock that reads `base` right now — the remote rank server's
    /// approximation of the connecting client's clock.
    pub fn starting_at(base: Micros) -> Self {
        Clock {
            origin: Instant::now(),
            base,
        }
    }

    #[inline]
    pub fn now(&self) -> Micros {
        self.base
            .saturating_add(Micros(self.origin.elapsed().as_micros() as u64))
    }

    /// Duration from now until `t` (zero if already past).
    pub fn until(&self, t: Micros) -> std::time::Duration {
        let now = self.now();
        std::time::Duration::from_micros(t.0.saturating_sub(now.0))
    }
}

impl Default for Clock {
    fn default() -> Self {
        Clock::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_is_monotone() {
        let c = Clock::new();
        let a = c.now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = c.now();
        assert!(b > a);
        assert!(b.0 - a.0 >= 1_500, "elapsed {}", b.0 - a.0);
    }

    #[test]
    fn starting_at_offsets_now() {
        let c = Clock::starting_at(Micros(5_000_000));
        let a = c.now();
        assert!(a >= Micros(5_000_000), "{a:?}");
        assert!(a < Micros(5_500_000), "{a:?}");
        // `until` works on the shifted axis too.
        assert!(c.until(Micros(6_000_000)).as_millis() > 400);
    }

    #[test]
    fn until_saturates() {
        let c = Clock::new();
        assert_eq!(c.until(Micros::ZERO), std::time::Duration::ZERO);
        let d = c.until(Micros(10_000_000));
        assert!(d.as_secs_f64() > 9.0);
    }
}
