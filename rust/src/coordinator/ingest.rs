//! Frontend ingest tier (§4.2 step ②, sharded): `F` ingest shards sit
//! between the producers (RPC handlers, load generators) and the model
//! workers. The paper calls request-rate work embarrassingly parallel;
//! the seed's frontend was the opposite — one heap-allocating mpsc send
//! per request into one channel per model. An ingest shard drains its
//! producer inbox in bursts, bins the burst per model into reusable
//! inline buffers, and forwards **one** [`ToModel::Requests`] message
//! per model per drain — so a k-request burst costs one channel send
//! and one candidate recompute per model downstream instead of k of
//! each (LazyBatching-style amortization of per-request scheduling
//! work).
//!
//! Producers hold an [`IngestHandle`]: a cheap clonable handle pinned
//! to one shard (clones round-robin across shards, so a fleet of
//! producer threads spreads the ingest load). Submissions that can no
//! longer be delivered — the coordinator is shutting down, a shard or
//! worker died — are **counted**, not silently swallowed; the counter
//! surfaces through `Coordinator::shutdown_stats` and
//! `ServeReport::dropped_submits`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::messages::ToModel;
use crate::coordinator::{INGEST_RING_DEPTH, MAX_DRAIN};
use crate::core::types::{ModelId, ReqBurst, Request};
use crate::obs::trace::{self, Stage};
use crate::util::affinity::{self, CorePlan};
use crate::util::ring::{ring, RingReceiver, RingSender, TryRecvError};

/// Producer → ingest shard.
#[derive(Debug)]
pub enum ToIngest {
    /// A single request ([`IngestHandle::submit`]).
    One(Request),
    /// A producer-side batch, possibly mixed-model
    /// ([`IngestHandle::submit_batch`]): one channel send for the whole
    /// batch; the shard re-bins it per model. Boxed for the same
    /// mpsc-node-size reason as `ToModel::Requests`.
    Batch(Box<ReqBurst>),
    Shutdown,
}

/// One ingest shard: drains producer submissions in bursts and
/// forwards per-model `ToModel::Requests` bursts.
pub(crate) struct IngestShard {
    pub inbox: RingReceiver<ToIngest>,
    /// One sender per model (clones of the owning worker's inbox).
    pub model_txs: Vec<RingSender<ToModel>>,
    /// Shared dropped-submission counter (see module docs).
    pub dropped: Arc<AtomicU64>,
}

impl IngestShard {
    /// Run until `Shutdown` / disconnect. Returns requests forwarded
    /// plus the inbox, so [`IngestTier::shutdown_join`] can count any
    /// submission accepted after the final drain instead of letting it
    /// vanish with the receiver.
    pub fn run(self) -> (u64, RingReceiver<ToIngest>) {
        let IngestShard {
            inbox,
            model_txs,
            dropped,
        } = self;
        let n_models = model_txs.len();
        // Per-model bins, reused across drains: `mem::take` replaces a
        // shipped bin with a fresh inline (stack-only) burst, so a
        // steady-state drain with bursts ≤ REQBURST_INLINE per model
        // never allocates.
        let mut bins: Vec<ReqBurst> = (0..n_models).map(|_| ReqBurst::new()).collect();
        let mut touched: Vec<usize> = Vec::new();
        let mut forwarded = 0u64;
        let absorb = |r: Request, bins: &mut Vec<ReqBurst>, touched: &mut Vec<usize>| {
            let mi = r.model.0 as usize;
            if mi >= n_models {
                debug_assert!(false, "submission for unknown {:?}", r.model);
                dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
            if bins[mi].is_empty() {
                touched.push(mi);
            }
            trace::req_event(Stage::IngestBin, r.id);
            bins[mi].push(r);
        };
        // Absorb one producer message; returns true when it was the
        // shutdown marker (one code path for the bounded drain and the
        // post-shutdown sweep).
        let absorb_msg = |msg: ToIngest, bins: &mut Vec<ReqBurst>, touched: &mut Vec<usize>| {
            match msg {
                ToIngest::One(r) => absorb(r, bins, touched),
                ToIngest::Batch(b) => {
                    for &r in b.iter() {
                        absorb(r, bins, touched);
                    }
                }
                ToIngest::Shutdown => return true,
            }
            false
        };
        let mut stop = false;
        loop {
            let Ok(first) = inbox.recv() else { break };
            // Drain the burst this message heads (bounded by
            // `MAX_DRAIN` so a sustained backlog cannot starve the
            // flush)...
            let mut next = Some(first);
            let mut absorbed = 0usize;
            while let Some(msg) = next.take() {
                if absorb_msg(msg, &mut bins, &mut touched) {
                    stop = true;
                    break;
                }
                absorbed += 1;
                if absorbed >= MAX_DRAIN {
                    break;
                }
                match inbox.try_recv() {
                    Ok(m) => next = Some(m),
                    Err(TryRecvError::Empty) => {}
                    Err(TryRecvError::Disconnected) => {
                        stop = true;
                        break;
                    }
                }
            }
            if stop {
                // Submissions enqueued behind the shutdown marker were
                // accepted (their send succeeded): drain and forward
                // them too — the workers shut down strictly after the
                // ingest tier; anything accepted after this sweep is
                // recovered and counted by `IngestTier::shutdown_join`.
                while let Ok(msg) = inbox.try_recv() {
                    let _ = absorb_msg(msg, &mut bins, &mut touched);
                }
            }
            // ...then forward one burst per touched model.
            for mi in touched.drain(..) {
                let burst = std::mem::take(&mut bins[mi]);
                let n = burst.len() as u64;
                let msg = ToModel::Requests {
                    model: ModelId(mi as u32),
                    burst: Box::new(burst),
                };
                // Full-queue policy (request-rate traffic): a worker
                // inbox with no room sheds the burst into the dropped
                // count — under overload the bounded ring is the shed
                // point, never a silent loss.
                if model_txs[mi].try_send(msg).is_err() {
                    dropped.fetch_add(n, Ordering::Relaxed);
                } else {
                    forwarded += n;
                }
            }
            if stop {
                break;
            }
        }
        (forwarded, inbox)
    }
}

/// Coordinator-side ownership of the spawned ingest shards.
pub(crate) struct IngestTier {
    pub txs: Vec<RingSender<ToIngest>>,
    pub handles: Vec<JoinHandle<(u64, RingReceiver<ToIngest>)>>,
    /// Round-robin allocator for handing shards to new handles.
    pub next: Arc<AtomicUsize>,
    pub dropped: Arc<AtomicU64>,
}

impl IngestTier {
    pub fn spawn(
        shards: usize,
        model_txs: Vec<RingSender<ToModel>>,
        dropped: Arc<AtomicU64>,
        busy_poll: bool,
        cores: &mut CorePlan,
    ) -> Self {
        let shards = shards.max(1);
        let mut txs = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for s in 0..shards {
            let (tx, rx) = ring::<ToIngest>(INGEST_RING_DEPTH);
            rx.set_busy_poll(busy_poll);
            txs.push(tx);
            let shard = IngestShard {
                inbox: rx,
                model_txs: model_txs.clone(),
                dropped: dropped.clone(),
            };
            let core = cores.assign();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("ingest-shard-{s}"))
                    .spawn(move || {
                        affinity::pin(core);
                        shard.run()
                    })
                    .expect("spawn ingest shard"),
            );
        }
        IngestTier {
            txs,
            handles,
            next: Arc::new(AtomicUsize::new(0)),
            dropped,
        }
    }

    pub fn handle(&self) -> IngestHandle {
        let shard = self.next.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        IngestHandle {
            txs: self.txs.clone(),
            shard,
            next: self.next.clone(),
            dropped: self.dropped.clone(),
        }
    }

    /// Stop the shards (flushing absorbed submissions) and wait for
    /// them, so no burst is in flight toward the workers afterwards.
    /// Submissions that were accepted after a shard's final drain are
    /// recovered from its returned receiver and counted as dropped —
    /// the accounting contract survives a shutdown race. Returns the
    /// total requests the tier forwarded over its lifetime.
    pub fn shutdown_join(&mut self) -> u64 {
        for tx in &self.txs {
            let _ = tx.send(ToIngest::Shutdown);
        }
        let mut forwarded = 0u64;
        for h in self.handles.drain(..) {
            let Ok((fwd, rx)) = h.join() else { continue };
            forwarded += fwd;
            while let Ok(msg) = rx.try_recv() {
                let n = match msg {
                    ToIngest::One(_) => 1,
                    ToIngest::Batch(b) => b.len() as u64,
                    ToIngest::Shutdown => 0,
                };
                self.dropped.fetch_add(n, Ordering::Relaxed);
            }
        }
        forwarded
    }
}

/// Cheap clonable per-producer submission handle, pinned to one ingest
/// shard. Cloning assigns the clone the next shard round-robin, so a
/// pool of producer threads that clones one handle per thread spreads
/// evenly across the `F` shards.
pub struct IngestHandle {
    txs: Vec<RingSender<ToIngest>>,
    shard: usize,
    next: Arc<AtomicUsize>,
    dropped: Arc<AtomicU64>,
}

impl Clone for IngestHandle {
    fn clone(&self) -> Self {
        let shard = self.next.fetch_add(1, Ordering::Relaxed) % self.txs.len();
        IngestHandle {
            txs: self.txs.clone(),
            shard,
            next: self.next.clone(),
            dropped: self.dropped.clone(),
        }
    }
}

impl IngestHandle {
    /// The ingest shard this handle submits to.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Submit one request. Full-queue policy (request-rate traffic): an
    /// ingest ring with no room — or a dead shard — counts the
    /// submission into `dropped_submits`, never a silent loss.
    pub fn submit(&self, r: Request) {
        trace::req_event(Stage::Submit, r.id);
        if self.txs[self.shard].try_send(ToIngest::One(r)).is_err() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Submit a batch (possibly mixed-model) as **one** ring send; the
    /// shard re-bins it per model and forwards one burst per model.
    /// Same full-queue policy as [`IngestHandle::submit`]: a full ring
    /// sheds the whole batch into the dropped count.
    pub fn submit_batch(&self, reqs: &[Request]) {
        if reqs.is_empty() {
            return;
        }
        let n = reqs.len() as u64;
        for r in reqs {
            trace::req_event(Stage::Submit, r.id);
        }
        let msg = ToIngest::Batch(Box::new(ReqBurst::from_slice(reqs)));
        if self.txs[self.shard].try_send(msg).is_err() {
            self.dropped.fetch_add(n, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::IDLE_RECV_TIMEOUT;
    use crate::core::time::Micros;
    use crate::core::types::RequestId;
    use std::time::Duration;

    fn req(id: u64, model: u32) -> Request {
        Request {
            id: RequestId(id),
            model: ModelId(model),
            arrival: Micros(0),
            deadline: Micros(1_000_000),
        }
    }

    /// A mixed-model batch is re-binned into one `Requests` burst per
    /// model, preserving per-model submission order.
    #[test]
    fn shard_bins_batch_per_model() {
        let dropped = Arc::new(AtomicU64::new(0));
        let (m0_tx, m0_rx) = ring::<ToModel>(64);
        let (m1_tx, m1_rx) = ring::<ToModel>(64);
        let tier = IngestTier::spawn(
            1,
            vec![m0_tx, m1_tx],
            dropped.clone(),
            false,
            &mut CorePlan::disabled(),
        );
        let h = tier.handle();
        h.submit_batch(&[req(0, 0), req(1, 1), req(2, 0), req(3, 1), req(4, 0)]);
        let msg = m0_rx.recv_timeout(IDLE_RECV_TIMEOUT).unwrap();
        match msg {
            ToModel::Requests { model, burst } => {
                assert_eq!(model, ModelId(0));
                let ids: Vec<u64> = burst.iter().map(|r| r.id.0).collect();
                assert_eq!(ids, vec![0, 2, 4]);
            }
            other => panic!("unexpected {other:?}"),
        }
        let msg = m1_rx.recv_timeout(IDLE_RECV_TIMEOUT).unwrap();
        match msg {
            ToModel::Requests { model, burst } => {
                assert_eq!(model, ModelId(1));
                assert_eq!(burst.len(), 2);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(dropped.load(Ordering::Relaxed), 0);
        let mut tier = tier;
        tier.shutdown_join();
    }

    /// Submissions toward a dead worker are counted, not swallowed.
    #[test]
    fn dead_worker_submissions_are_counted() {
        let dropped = Arc::new(AtomicU64::new(0));
        let (m0_tx, m0_rx) = ring::<ToModel>(64);
        drop(m0_rx); // the worker died
        let mut tier = IngestTier::spawn(
            1,
            vec![m0_tx],
            dropped.clone(),
            false,
            &mut CorePlan::disabled(),
        );
        let h = tier.handle();
        h.submit(req(0, 0));
        h.submit_batch(&[req(1, 0), req(2, 0)]);
        // Give the shard a beat to drain + attempt the forward.
        std::thread::sleep(Duration::from_millis(50));
        tier.shutdown_join();
        assert_eq!(dropped.load(Ordering::Relaxed), 3);
    }

    /// Handle clones round-robin across shards.
    #[test]
    fn handle_clones_spread_across_shards() {
        let dropped = Arc::new(AtomicU64::new(0));
        let (m0_tx, _m0_rx) = ring::<ToModel>(64);
        let mut tier = IngestTier::spawn(3, vec![m0_tx], dropped, false, &mut CorePlan::disabled());
        let h0 = tier.handle();
        let h1 = h0.clone();
        let h2 = h1.clone();
        let shards: std::collections::BTreeSet<usize> =
            [h0.shard(), h1.shard(), h2.shard()].into_iter().collect();
        assert_eq!(shards.len(), 3, "three clones cover three shards");
        tier.shutdown_join();
    }
}
