//! Message vocabulary between the coordinator's threads (Figure 18):
//! ModelThread ⇄ RankThread ⇄ (timers), ModelThread → backend workers,
//! backend workers → completion collector.

use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, Request};

/// A candidate's schedulable window as registered with the RankThread
/// (`inform_candidate`).
#[derive(Clone, Copy, Debug)]
pub struct CandWindow {
    pub exec: Micros,
    pub latest: Micros,
    pub size: u32,
}

/// RankThread / frontend → ModelThread.
#[derive(Debug)]
pub enum ToModel {
    /// A new inference request for this model (frontend → MT, step ②).
    Request(Request),
    /// "GPU Granted" (RankThread → MT): finalize the batch and dispatch
    /// it to `gpu` immediately (§4.2).
    Granted { gpu: GpuId },
    /// The RankThread discarded this model's candidate (its window
    /// expired un-granted); recompute and re-register.
    Revalidate,
    Shutdown,
}

/// ModelThread → RankThread.
#[derive(Debug)]
pub enum ToRank {
    /// Register / replace / clear this model's candidate.
    Candidate {
        model: ModelId,
        cand: Option<CandWindow>,
    },
    /// The granted GPU will be busy until `free_at` (`inform_gpu`).
    GpuBusyUntil { gpu: GpuId, free_at: Micros },
    Shutdown,
}

/// ModelThread → backend worker (step ④: batch metadata to the backend,
/// which in the paper then RDMA-reads inputs from frontends ⑤).
#[derive(Debug)]
pub enum ToBackend {
    Execute {
        model: ModelId,
        requests: Vec<Request>,
        dispatched_at: Micros,
    },
    Shutdown,
}

/// Backend / ModelThread → metrics collector.
#[derive(Debug)]
pub enum Completion {
    Batch {
        gpu: GpuId,
        model: ModelId,
        requests: Vec<Request>,
        dispatched_at: Micros,
        start: Micros,
        end: Micros,
    },
    Dropped(Vec<Request>),
}
