//! Message vocabulary between the coordinator's threads (Figure 18):
//! ModelThread ⇄ rank shards ⇄ (timers), ModelThread → backend workers,
//! backend workers → completion collector.

use std::sync::mpsc::Sender;

use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, Request};

/// A candidate's schedulable window as registered with a rank shard
/// (`inform_candidate`). `PartialEq` lets the [`crate::coordinator::router::RankRouter`]
/// coalesce re-registrations of an unchanged window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CandWindow {
    pub exec: Micros,
    pub latest: Micros,
    pub size: u32,
}

/// Rank shard / frontend → ModelThread.
#[derive(Debug)]
pub enum ToModel {
    /// A new inference request for this model (frontend → MT, step ②).
    Request(Request),
    /// "GPU Granted" (rank shard → MT): finalize the batch and dispatch
    /// it to `gpu` immediately (§4.2).
    Granted { gpu: GpuId },
    /// The rank shard discarded this model's candidate (its window
    /// expired un-granted); recompute and re-register.
    Revalidate,
    /// The registered shard has no free GPU, but shard `to_shard`
    /// advertises spare capacity: re-register the candidate there.
    /// `seq` echoes the registration this verdict applies to; the
    /// ModelThread ignores it if the candidate has been replaced since.
    Overflow { to_shard: usize, seq: u64 },
    Shutdown,
}

/// ModelThread → rank shard.
#[derive(Debug)]
pub enum ToRank {
    /// Register / replace / clear this model's candidate.
    ///
    /// `seq` is the ModelThread's monotone registration counter (echoed
    /// back in [`ToModel::Overflow`] so stale verdicts are detectable);
    /// `hops` counts overflow re-registrations of this logical
    /// candidate — a shard parks rather than re-steers once `hops`
    /// reaches the shard count, bounding migration.
    Candidate {
        model: ModelId,
        cand: Option<CandWindow>,
        seq: u64,
        hops: u32,
    },
    /// The granted GPU will be busy until `free_at` (`inform_gpu`).
    /// Routed to the shard owning `gpu`.
    GpuBusyUntil { gpu: GpuId, free_at: Micros },
    /// Autoscaler → shard (§3.5 live wiring): stop granting `gpu`,
    /// stop advertising it in the free hints, let any in-flight batch
    /// finish, then retire it. `ack` fires exactly once, when the GPU
    /// is provably idle and detached — the moment it is safe to tear
    /// down the backend worker or return the device to the cluster
    /// manager. Idempotent: draining an already-detached GPU acks
    /// immediately. Exception: an `Attach` of a still-draining GPU
    /// cancels the drain and its ack never fires (the GPU was never
    /// idle-retired) — callers that only attach acked/detached ids,
    /// like `autoscale::live::LiveAutoscaler`, never hit this.
    Drain { gpu: GpuId, ack: Sender<GpuId> },
    /// Autoscaler → shard: (re)activate a detached GPU — it joins the
    /// shard's free set and is advertised/grantable from the next
    /// matchmaking pass. Attaching an active GPU is a no-op.
    Attach { gpu: GpuId },
    Shutdown,
}

/// ModelThread → backend worker (step ④: batch metadata to the backend,
/// which in the paper then RDMA-reads inputs from frontends ⑤).
#[derive(Debug)]
pub enum ToBackend {
    Execute {
        model: ModelId,
        requests: Vec<Request>,
        dispatched_at: Micros,
    },
    Shutdown,
}

/// Backend / ModelThread → metrics collector.
#[derive(Debug)]
pub enum Completion {
    Batch {
        gpu: GpuId,
        model: ModelId,
        requests: Vec<Request>,
        dispatched_at: Micros,
        start: Micros,
        end: Micros,
    },
    Dropped(Vec<Request>),
}
