//! Message vocabulary between the coordinator's threads (Figure 18):
//! ingest shards → model workers, model workers ⇄ rank shards,
//! model workers → backend workers, backend workers → completion
//! collector.
//!
//! With the [`crate::coordinator::model_thread::ModelWorkerPool`], one
//! worker thread multiplexes the state of many models, so every
//! worker-bound message is addressed with its `ModelId` (the per-model
//! channel that used to imply it is gone).
//!
//! Transport: the steady-state hops ([`ToModel`], [`ToRank`]) ride the
//! bounded lock-free rings of [`crate::util::ring`] — full-queue
//! policy documented at each send site, and the `hot-path-channel`
//! lint keeps `std::sync::mpsc` from creeping back into
//! `coordinator/`. Batch-rate and one-shot edges ([`ToBackend`],
//! [`Completion`], `Drain`'s ack) stay on plain mpsc channels, where
//! unboundedness is the right policy.
//!
//! The worker ⇄ rank-shard half of this vocabulary also exists as a
//! wire protocol ([`crate::net::codec`]): `ToRank` minus `Shutdown`
//! maps onto `WireToRank` (a remote shutdown is a connection close),
//! and the shard-originated `ToModel` verdicts map onto
//! `WireFromRank` — plus an explicit `DrainAck` frame standing in for
//! `Drain`'s in-process `Sender<GpuId>` ack. The sync is machine
//! checked: `symphony lint`'s `wire-schema-drift` rule compares the
//! variant sets and field names of both sides (modulo the documented
//! local-only/wire-only exceptions) and verifies every wire variant has
//! an encode and a decode arm, so evolving one side without the other
//! fails CI instead of surfacing as a runtime `BadTag`. The handshake
//! is covered too: every field of `ServerPreamble` / `ClientHello` —
//! including the wire-v2 session/epoch pair that fences stale frames
//! across reconnects — must appear in both its encode and its decode
//! function, so a one-sided handshake edit is caught the same way.
//! [`ToModel::Reregister`] is frontend-local by design: it is the wire
//! *client's* post-reconnect nudge, so it never crosses the wire.

use std::sync::mpsc::Sender;

use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, ReqBurst, Request};

/// A candidate's schedulable window as registered with a rank shard
/// (`inform_candidate`). `PartialEq` lets the [`crate::coordinator::router::RankRouter`]
/// coalesce re-registrations of an unchanged window.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CandWindow {
    pub exec: Micros,
    pub latest: Micros,
    pub size: u32,
}

/// Rank shard / frontend → model worker.
///
/// `Requests` carries its burst **boxed**: every ring slot (and,
/// before PR 7, every mpsc node) is sized for the whole enum, so an
/// inline burst (~0.5 kB) would inflate the preallocated ring by 13×
/// and tax every per-request `Request` and every batch-rate
/// `Granted`/`Revalidate`/`Overflow` send with a 13× copy — the exact
/// hot path this tier optimizes. The box costs one allocation per
/// burst, amortized over its k requests.
#[derive(Debug)]
pub enum ToModel {
    /// A single new inference request (frontend → worker, step ②);
    /// routed by `Request::model`.
    Request(Request),
    /// A coalesced burst of requests, all for `model` (ingest shard or
    /// `submit_batch` → worker): one channel send per burst per model
    /// instead of one heap-node send per request, and the worker's
    /// latest-wins drain pays one candidate recompute for the whole
    /// burst.
    Requests { model: ModelId, burst: Box<ReqBurst> },
    /// "GPU Granted" (rank shard → worker): finalize `model`'s batch
    /// and dispatch it to `gpu` immediately (§4.2).
    Granted { model: ModelId, gpu: GpuId },
    /// The rank shard discarded `model`'s candidate (its window expired
    /// un-granted); recompute and re-register.
    Revalidate { model: ModelId },
    /// The registered shard has no free GPU, but shard `to_shard`
    /// advertises spare capacity: re-register `model`'s candidate
    /// there. `seq` echoes the registration this verdict applies to;
    /// the worker ignores it if the candidate has been replaced since.
    Overflow {
        model: ModelId,
        to_shard: usize,
        seq: u64,
    },
    /// The wire client re-established a rank-server session (reconnect
    /// epoch bump): the fresh session's shards spawned empty, so the
    /// worker must drop its coalescing state and re-register `model`'s
    /// current candidate from scratch. The worker is the single
    /// authority for its candidate — recovery is a local re-register,
    /// not a distributed handoff. Frontend-side only (never crosses the
    /// wire); behaves like `Revalidate` but skips straight to
    /// re-registration without discarding the computed candidate.
    Reregister { model: ModelId },
    Shutdown,
}

/// Model worker → rank shard.
#[derive(Debug)]
pub enum ToRank {
    /// Register / replace / clear this model's candidate.
    ///
    /// `seq` is the model worker's monotone registration counter (echoed
    /// back in [`ToModel::Overflow`] so stale verdicts are detectable);
    /// `hops` counts overflow re-registrations of this logical
    /// candidate — a shard parks rather than re-steers once `hops`
    /// reaches the shard count, bounding migration.
    Candidate {
        model: ModelId,
        cand: Option<CandWindow>,
        seq: u64,
        hops: u32,
    },
    /// The granted GPU will be busy until `free_at` (`inform_gpu`).
    /// Routed to the shard owning `gpu`.
    GpuBusyUntil { gpu: GpuId, free_at: Micros },
    /// Autoscaler → shard (§3.5 live wiring): stop granting `gpu`,
    /// stop advertising it in the free hints, let any in-flight batch
    /// finish, then retire it. `ack` fires exactly once, when the GPU
    /// is provably idle and detached — the moment it is safe to tear
    /// down the backend worker or return the device to the cluster
    /// manager. Idempotent: draining an already-detached GPU acks
    /// immediately. Exception: an `Attach` of a still-draining GPU
    /// cancels the drain and its ack never fires (the GPU was never
    /// idle-retired) — callers that only attach acked/detached ids,
    /// like `autoscale::live::LiveAutoscaler`, never hit this.
    Drain { gpu: GpuId, ack: Sender<GpuId> },
    /// Autoscaler → shard: (re)activate a detached GPU — it joins the
    /// shard's free set and is advertised/grantable from the next
    /// matchmaking pass. Attaching an active GPU is a no-op.
    Attach { gpu: GpuId },
    Shutdown,
}

/// Model worker → backend worker (step ④: batch metadata to the
/// backend, which in the paper then RDMA-reads inputs from frontends
/// ⑤). The batch rides a [`ReqBurst`], popped straight off the worker's
/// queue — allocation-free for batches ≤ `REQBURST_INLINE`.
/// Unlike `ToModel`, every non-`Shutdown` message here carries a batch
/// and the channel is batch-rate, so the burst stays inline
/// (allocation-free ≤ `REQBURST_INLINE`) — hence the deliberate
/// variant-size asymmetry.
#[allow(clippy::large_enum_variant)]
#[derive(Debug)]
pub enum ToBackend {
    Execute {
        model: ModelId,
        requests: ReqBurst,
        dispatched_at: Micros,
    },
    Shutdown,
}

/// Backend / model worker → metrics collector.
#[allow(clippy::large_enum_variant)] // batch-rate channel, inline by design — see ToBackend
#[derive(Debug)]
pub enum Completion {
    Batch {
        gpu: GpuId,
        model: ModelId,
        requests: ReqBurst,
        dispatched_at: Micros,
        start: Micros,
        end: Micros,
    },
    Dropped(ReqBurst),
}
