//! The multithreaded centralized scheduler (§4.2, Fig 18): independent
//! **ModelThreads** (request-rate work, embarrassingly parallel) and a
//! single **RankThread** (batch-rate matchmaking) — the architecture
//! that lets Symphony's scheduler process millions of requests per
//! second (Fig 13 left).
//!
//! The coordinator is backend-agnostic: callers supply one `ToBackend`
//! channel per GPU (real PJRT executors in [`crate::serve`], sleep
//! emulators, or sinks for scheduler-only benchmarks).

pub mod clock;
pub mod messages;
pub mod model_thread;
pub mod rank_thread;

use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;
use crate::core::types::{ModelId, Request};
pub use clock::Clock;
pub use messages::{CandWindow, Completion, ToBackend, ToModel, ToRank};
use model_thread::ModelThread;
use rank_thread::RankThread;

/// Configuration of a running coordinator.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub profiles: Vec<LatencyProfile>,
    pub num_gpus: usize,
    /// Network-delay budget subtracted from candidate windows (§5.6).
    pub net_bound: Micros,
    /// Safety margin added to busy estimates sent to the RankThread.
    pub exec_margin: Micros,
}

/// A live coordinator: RankThread + one ModelThread per model.
pub struct Coordinator {
    pub clock: Clock,
    model_txs: Vec<Sender<ToModel>>,
    rank_tx: Sender<ToRank>,
    model_handles: Vec<JoinHandle<u64>>,
    rank_handle: Option<JoinHandle<u64>>,
}

impl Coordinator {
    /// Spawn the scheduler threads. `backends[g]` receives the batches
    /// dispatched to GPU `g`; `completions` receives drop notices from
    /// ModelThreads (backends send their own batch completions).
    pub fn spawn(
        cfg: CoordinatorConfig,
        backends: Vec<Sender<ToBackend>>,
        completions: Sender<Completion>,
    ) -> Self {
        assert_eq!(backends.len(), cfg.num_gpus, "one backend per GPU");
        let clock = Clock::new();
        let (rank_tx, rank_rx) = channel::<ToRank>();

        let mut model_txs = Vec::new();
        let mut model_rx_store = Vec::new();
        for _ in 0..cfg.profiles.len() {
            let (tx, rx) = channel::<ToModel>();
            model_txs.push(tx);
            model_rx_store.push(rx);
        }

        let rank = RankThread {
            clock,
            inbox: rank_rx,
            model_txs: model_txs.clone(),
            num_gpus: cfg.num_gpus,
        };
        let rank_handle = std::thread::Builder::new()
            .name("rank-thread".into())
            .spawn(move || rank.run())
            .expect("spawn rank thread");

        let mut model_handles = Vec::new();
        for (i, rx) in model_rx_store.into_iter().enumerate() {
            let mt = ModelThread {
                model: ModelId(i as u32),
                profile: cfg.profiles[i],
                clock,
                inbox: rx,
                to_rank: rank_tx.clone(),
                backends: backends.clone(),
                completions: completions.clone(),
                net_bound: cfg.net_bound,
                exec_margin: cfg.exec_margin,
            };
            model_handles.push(
                std::thread::Builder::new()
                    .name(format!("model-thread-{i}"))
                    .spawn(move || mt.run())
                    .expect("spawn model thread"),
            );
        }

        Coordinator {
            clock,
            model_txs,
            rank_tx,
            model_handles,
            rank_handle: Some(rank_handle),
        }
    }

    /// Submit a request (frontend step ②). Arrival/deadline must be on
    /// this coordinator's clock.
    pub fn submit(&self, r: Request) {
        let _ = self.model_txs[r.model.0 as usize].send(ToModel::Request(r));
    }

    /// Convenience: stamp arrival = now, deadline = now + slo.
    pub fn submit_now(&self, id: u64, model: ModelId, slo: Micros) {
        let now = self.clock.now();
        self.submit(Request {
            id: crate::core::types::RequestId(id),
            model,
            arrival: now,
            deadline: now + slo,
        });
    }

    /// Stop all threads; returns (requests processed, grants issued).
    pub fn shutdown(mut self) -> (u64, u64) {
        for tx in &self.model_txs {
            let _ = tx.send(ToModel::Shutdown);
        }
        let processed: u64 = self
            .model_handles
            .drain(..)
            .map(|h| h.join().unwrap_or(0))
            .sum();
        let _ = self.rank_tx.send(ToRank::Shutdown);
        let grants = self
            .rank_handle
            .take()
            .map(|h| h.join().unwrap_or(0))
            .unwrap_or(0);
        (processed, grants)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    /// End-to-end through real threads: submit a burst, expect the
    /// deferred window to group it into one large batch. ℓ is ms-scale
    /// and `net_bound` budgets for OS-thread wakeup jitter (the paper
    /// budgets the RDMA p99.99 the same way, §5.6).
    #[test]
    fn coordinator_batches_a_burst() {
        let profile = LatencyProfile::new(1.0, 5.0);
        let (backend_tx, backend_rx) = channel::<ToBackend>();
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let coord = Coordinator::spawn(
            CoordinatorConfig {
                profiles: vec![profile],
                num_gpus: 1,
                net_bound: Micros::from_millis_f64(2.0),
                exec_margin: Micros::from_millis_f64(0.5),
            },
            vec![backend_tx],
            comp_tx,
        );
        for i in 0..8 {
            coord.submit_now(i, ModelId(0), Micros::from_millis_f64(100.0));
        }
        let msg = backend_rx
            .recv_timeout(Duration::from_millis(1_000))
            .expect("batch dispatched");
        match msg {
            ToBackend::Execute { requests, .. } => {
                assert!(
                    requests.len() >= 6,
                    "expected a large batch, got {}",
                    requests.len()
                );
            }
            other => panic!("unexpected {other:?}"),
        }
        let (processed, grants) = coord.shutdown();
        assert_eq!(processed, 8);
        assert!(grants >= 1);
    }

    /// Two models, one GPU: both get served. The second model's looser
    /// SLO leaves room for its deferred batch after the first model's
    /// batch finishes.
    #[test]
    fn coordinator_multiplexes_models() {
        let profile = LatencyProfile::new(1.0, 5.0);
        let (backend_tx, backend_rx) = channel::<ToBackend>();
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let coord = Coordinator::spawn(
            CoordinatorConfig {
                profiles: vec![profile, profile],
                num_gpus: 1,
                net_bound: Micros::from_millis_f64(2.0),
                exec_margin: Micros::from_millis_f64(0.5),
            },
            vec![backend_tx],
            comp_tx,
        );
        for i in 0..4 {
            coord.submit_now(i, ModelId(0), Micros::from_millis_f64(40.0));
            coord.submit_now(100 + i, ModelId(1), Micros::from_millis_f64(100.0));
        }
        let mut seen = std::collections::HashSet::new();
        let deadline = std::time::Instant::now() + Duration::from_millis(800);
        while seen.len() < 2 && std::time::Instant::now() < deadline {
            if let Ok(ToBackend::Execute { model, .. }) =
                backend_rx.recv_timeout(Duration::from_millis(100))
            {
                seen.insert(model);
            }
        }
        assert_eq!(seen.len(), 2, "both models dispatched");
        coord.shutdown();
    }
}
