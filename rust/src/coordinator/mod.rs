//! The multithreaded centralized scheduler (§4.2, Fig 18): a sharded
//! frontend ingest tier, a **ModelWorkerPool** doing the request-rate
//! work (embarrassingly parallel), and `R` **rank shards** (batch-rate
//! matchmaking, each owning a contiguous GPU id range) — the
//! architecture that lets Symphony's scheduler process millions of
//! requests per second and coordinate thousands of GPUs (Fig 13 left).
//! `rank_shards = 1` is exactly the paper's single-RankThread
//! configuration.
//!
//! Topology (`F` ingest shards, `W` model workers, `R` rank shards):
//!
//! ```text
//!  producers ─ IngestHandle ──▶ ingest shard 0..F     (submit_batch:
//!     (submit / submit_batch)   │  burst drain,        one send per
//!                               │  bin per model       producer batch)
//!                               ▼  ToModel::Requests (1 send/model/drain)
//!  ┌─────────────── ModelWorkerPool: W threads ────────────────┐
//!  │ worker w owns models {m : m % W == w}: queue, candidate,  │
//!  │ RankRouter; latest-wins drain ⇒ 1 recompute + 1 shard     │
//!  │ registration per model per drain                          │
//!  └──┬────────────────────────────────────────────▲───────────┘
//!     │ ToRank::{Candidate, GpuBusyUntil}          │ ToModel::{Granted,
//!     ▼  via RankPort                              │ Revalidate, Overflow}
//!  ╔══ process boundary (only with --remote-ranks) ═════════════╗
//!  ║ framed TCP (net/): WireToRank ▼ frames  ▲ WireFromRank    ║
//!  ║ one `symphony rank-server` process per GPU-range slice    ║
//!  ╚════════════════════════════════════════════════════════════╝
//!     ▼                                            ▲
//!  rank shard 0..R  (GPU range  [R·g/num_gpus], free/busy timers,
//!     │              matchmaking, FreeHints overflow steering)
//!     ▼ (via worker on Granted)
//!  backend worker per GPU  ── Completion ──▶ collector
//! ```
//!
//! Flight-recorder tap points (`crate::obs::trace`, 1-in-N sampled):
//! `Submit` where a producer hands the request over ([`IngestHandle`]
//! or [`Coordinator::submit`]), `IngestBin` as an ingest shard bins
//! it, `WorkerRecv` as its model worker absorbs it, `CandReg` when the
//! worker registers a candidate (per model), `RankGrant` when a rank
//! shard grants a GPU (per model), `GrantRecv` + `Dispatch` as the
//! worker takes the burst and ships it to the backend, and `Complete`
//! at the serve-side collector. The wire hops `WireCandTx` /
//! `WireGrantRx` bracket the `--remote-ranks` process boundary in
//! [`crate::net`].
//!
//! The rank tier is addressed through [`RankPort`]s, so it can live
//! in-process (bounded lock-free rings, [`crate::util::ring`] — the
//! default) or behind [`crate::net`]'s framed TCP in separate
//! `symphony rank-server` processes
//! ([`CoordinatorConfig::remote_ranks`]) — the workers, the overflow
//! steering, and the drain/attach autoscaler protocol don't know the
//! difference. Backends always stay in this process.
//!
//! The wire configuration survives session death (wire v2). Handshake
//! and reconnect state machine, per connection:
//!
//! ```text
//!   connect ──▶ preamble{shards,gpu_lo..hi,session} ◀── rank-server
//!          ──▶ hello{n_models,now_us,epoch} ──▶          (session++ per
//!                                                        accepted client)
//!   Live(epoch e) ──unexpected EOF / IO / protocol / backlog──▶
//!   Reconnecting(e+1)   · first detector wins a CAS: one count, by
//!        │                cause, into FrontendStats
//!        │              · frames from session e are fenced (a stale
//!        │                Granted never leases a GPU in session e+1)
//!        │              · registrations drop (Ok), drain/attach fail
//!        ├── backoff-dial (hello carries e+1) ──▶ Live(e+1):
//!        │     replay desired-detached drains, mark shards live,
//!        │     ToModel::Reregister to every worker (the worker is the
//!        │     single authority for its candidate — recovery is a
//!        │     local re-register)
//!        └── past ReconnectPolicy::dead_after: mark the server's
//!            shard range dead in ShardLiveness — RankRouters route
//!            registrations to surviving shards, the live autoscaler
//!            re-tiles the lost GPU range onto survivors; an eventual
//!            reconnect re-adopts the range
//! ```
//!
//! The coordinator is backend-agnostic: callers supply one `ToBackend`
//! channel per GPU (real PJRT executors in [`crate::serve`], sleep
//! emulators, or sinks for scheduler-only benchmarks).

pub mod clock;
pub mod ingest;
pub mod messages;
pub mod model_thread;
pub mod rank_shard;
pub mod router;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, ReqBurst, Request};
use crate::net::client::{DisconnectBreakdown, DisconnectCounts, ReconnectPolicy, RemoteRank};
use crate::net::faults::FaultPlan;
use crate::obs::trace::{self, Stage};
use crate::util::affinity::{self, CorePlan};
use crate::util::error::Result;
use crate::util::ring::{ring, RingProbe, RingSender};
pub use clock::Clock;
pub use ingest::IngestHandle;
use ingest::IngestTier;
pub use messages::{CandWindow, Completion, ToBackend, ToModel, ToRank};
pub use model_thread::{ModelWorkerPool, QueueDepthProbe, WorkerStats};
pub use rank_shard::{RankShard, ShardLive, ShardStats};
pub use router::{FreeHints, PortClosed, RankPort, RankRouter, ShardLiveness, ShardTopology};

/// How long `--remote-ranks` keeps retrying a rank server that is not
/// accepting yet (CI spawns the server and the client back to back).
const REMOTE_CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// Messages a worker or ingest shard absorbs per inbox drain before
/// its flush runs. Without a cap, producers that keep an inbox
/// non-empty (line-rate feeders) would defer the flush — and with it
/// candidate registration / burst forwarding — indefinitely. 256 keeps
/// the per-burst amortization while bounding that latency.
pub(crate) const MAX_DRAIN: usize = 256;

/// How long an idle drain loop (or a test waiting on a message that
/// should already be in flight) blocks before giving up one wait
/// round. Bounds how stale a blocked thread's view of shutdown /
/// disconnect can get; also the conventional "this message must arrive
/// promptly" test timeout.
pub const IDLE_RECV_TIMEOUT: Duration = Duration::from_millis(500);

/// Generous end-to-end settle bound: how long a test waits for a
/// multi-hop outcome (submit → worker → shard → grant → backend)
/// before declaring the pipeline wedged.
pub const SETTLE_RECV_TIMEOUT: Duration = Duration::from_millis(1_000);

/// Ingest-shard inbox depth. Submission traffic is request-rate and
/// sheddable: a full ring counts into `dropped_submits` (the same
/// policy the paper's frontend applies under overload), so the depth
/// bounds memory, not correctness. 4096 absorbs multi-ms producer
/// bursts at millions/s before shedding starts.
pub const INGEST_RING_DEPTH: usize = 4096;

/// Model-worker inbox depth. Carries both sheddable request traffic
/// (`Request`/`Requests` — full ring counts as drops at the sender)
/// and control traffic (`Granted`/`Revalidate`/`Overflow` — bounded
/// blocking retry; must not drop).
pub const MODEL_RING_DEPTH: usize = 4096;

/// Rank-shard inbox depth. All traffic here is batch-rate control
/// (candidate registrations, busy-until, drain/attach), sent with the
/// bounded blocking retry — the ring only needs to cover a drain
/// interval's burst.
pub const RANK_RING_DEPTH: usize = 2048;

/// Configuration of a running coordinator.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub profiles: Vec<LatencyProfile>,
    /// Total GPU capacity: one backend channel and one shard-owned slot
    /// per id. The ids in `initial_gpus..num_gpus` start detached —
    /// headroom the autoscaler can attach at runtime.
    pub num_gpus: usize,
    /// GPUs attached at spawn (`None` = all of `num_gpus`). Always the
    /// lowest ids: attach grows the active prefix upward, drain retires
    /// from the top — the consolidation order min-id dispatch preserves.
    pub initial_gpus: Option<usize>,
    /// Rank shards (clamped to `1..=num_gpus`); 1 = the paper's single
    /// RankThread.
    pub rank_shards: usize,
    /// Frontend ingest shards (clamped to ≥ 1): producer-side
    /// submission fan-in, drained in bursts and forwarded per model.
    pub ingest_shards: usize,
    /// Model-worker threads multiplexing the per-model scheduling state
    /// (`None` = `min(models, available_parallelism)`). The pool keeps
    /// the OS thread count at `W` regardless of the model count.
    pub model_workers: Option<usize>,
    /// Network-delay budget subtracted from candidate windows (§5.6).
    pub net_bound: Micros,
    /// Safety margin added to busy estimates sent to the rank shards.
    pub exec_margin: Micros,
    /// Remote rank tier: addresses of running `symphony rank-server`
    /// processes whose advertised GPU ranges must tile `0..num_gpus`
    /// contiguously in list order. Empty (the default) hosts the rank
    /// shards in-process per `rank_shards`; non-empty replaces the
    /// in-process tier entirely (`rank_shards` is ignored — each
    /// server brings its own shard count).
    pub remote_ranks: Vec<String>,
    /// Keep drain threads spinning instead of parking when their inbox
    /// runs dry (`--busy-poll`): trades a core per thread for the
    /// lowest hop latency. Off, the rings' adaptive spin→yield→park
    /// waiter applies.
    pub busy_poll: bool,
    /// Pin ingest shards, model workers, and rank shards round-robin
    /// onto the host's cores in NUMA-node order (`--pin-cores`). No-op
    /// when topology discovery fails or off Linux.
    pub pin_cores: bool,
    /// How remote connections behave when a session dies unexpectedly
    /// (see [`ReconnectPolicy`]). Irrelevant for an in-process tier.
    pub reconnect: ReconnectPolicy,
    /// Deterministic wire fault injection for the *client* side of the
    /// remote connections ([`FaultPlan::parse`] grammar;
    /// `--fault-plan` on the CLI). [`FaultPlan::none`] — the default —
    /// injects nothing.
    pub fault_plan: std::sync::Arc<FaultPlan>,
}

/// What the frontend/worker tier did over a run, returned by
/// [`Coordinator::shutdown_stats`].
#[derive(Clone, Copy, Debug, Default)]
pub struct FrontendStats {
    /// Requests that entered a model queue.
    pub processed: u64,
    /// End-of-drain candidate recomputes across the worker pool: the
    /// burst-amortization counter (a k-request burst for one model
    /// costs exactly one).
    pub flush_recomputes: u64,
    /// Requests forwarded by the ingest tier (cross-check against
    /// `processed` − direct-submit traffic, and against
    /// `dropped_submits`).
    pub ingest_forwarded: u64,
    /// Submissions that could not be delivered (a worker or ingest
    /// shard was already down). The seed silently swallowed these.
    pub dropped_submits: u64,
    /// Remote rank-server sessions that ended without this coordinator
    /// asking (EOF, IO error, protocol violation, handshake failure,
    /// writer-backlog overflow). Always 0 for an in-process rank tier.
    /// With reconnect enabled (the default) a disconnect is a survived
    /// incident, not a wedge: compare against `rank_reconnects`.
    pub rank_disconnects: u64,
    /// The same count split by cause (io / protocol / handshake /
    /// backlog-overflow) — which failure mode hit matters when reading
    /// a chaos run.
    pub rank_disconnect_causes: DisconnectBreakdown,
    /// Sessions successfully re-established after an unexpected
    /// disconnect (the reconnect state machine's recovery count).
    pub rank_reconnects: u64,
    /// Stale-session down-frames dropped by the epoch fence instead of
    /// being dispatched (a stale `Granted` never leases a GPU in the
    /// successor session).
    pub rank_fenced_frames: u64,
    /// High-watermark occupancy across the ingest-shard inbox rings:
    /// how close producer bursts came to the shed point
    /// ([`INGEST_RING_DEPTH`]).
    pub ingest_ring_hwm: u64,
    /// High-watermark occupancy across the model-worker inbox rings
    /// ([`MODEL_RING_DEPTH`]).
    pub model_ring_hwm: u64,
    /// High-watermark occupancy across the in-process rank-shard inbox
    /// rings ([`RANK_RING_DEPTH`]); 0 with a remote tier (the servers
    /// report their own via [`ShardStats::inbox_hwm`]).
    pub rank_ring_hwm: u64,
}

/// A live coordinator: ingest shards + model-worker pool + rank shards
/// (in-process threads, or remote `rank-server` processes).
pub struct Coordinator {
    pub clock: Clock,
    topo: ShardTopology,
    /// One sender per model (clones of the owning worker's inbox).
    model_txs: Vec<RingSender<ToModel>>,
    pool: Option<ModelWorkerPool>,
    depth: QueueDepthProbe,
    ingest: IngestTier,
    /// One transport-agnostic port per rank shard.
    ports: Vec<RankPort>,
    /// In-process shard threads (empty with a remote rank tier).
    shard_handles: Vec<JoinHandle<ShardStats>>,
    /// Remote rank-server connections (empty with an in-process tier).
    remote: Vec<Arc<RemoteRank>>,
    dropped_submits: Arc<AtomicU64>,
    disconnects: Arc<DisconnectCounts>,
    /// Shared per-shard liveness: all-live for an in-process tier;
    /// maintained by the `RemoteRank` reconnect machinery otherwise.
    liveness: ShardLiveness,
    /// Scrape-visible per-shard counters (in-process tier only; empty
    /// with remote ranks — the servers expose their own).
    shard_live: Vec<Arc<ShardLive>>,
    /// Ring occupancy probes per tier, retained for `/metrics` and the
    /// shutdown high-watermark report.
    ingest_probes: Vec<Arc<dyn RingProbe>>,
    model_probes: Vec<Arc<dyn RingProbe>>,
    rank_probes: Vec<Arc<dyn RingProbe>>,
}

/// A cheap, clonable observation bundle for live `/metrics` exposition:
/// everything a scrape needs to read from a running coordinator without
/// touching its threads. Obtained from [`Coordinator::observe`]; all
/// members are `Arc`-shared views, so the render closure can outlive
/// individual requests (but not the coordinator's rings' storage — the
/// probes keep that alive themselves).
#[derive(Clone)]
pub struct CoordObs {
    pub dropped_submits: Arc<AtomicU64>,
    pub disconnects: Arc<DisconnectCounts>,
    pub remote: Vec<Arc<RemoteRank>>,
    pub shard_live: Vec<Arc<ShardLive>>,
    pub ingest_rings: Vec<Arc<dyn RingProbe>>,
    pub model_rings: Vec<Arc<dyn RingProbe>>,
    pub rank_rings: Vec<Arc<dyn RingProbe>>,
    pub queue_depth: QueueDepthProbe,
}

/// Cheap clonable handle for runtime cluster resizing (§3.5 live
/// autoscaling): routes `Drain`/`Attach` to the shard owning the GPU —
/// over the wire when the shard is remote (the ack comes back as a
/// `DrainAck` frame; callers see the same `Sender<GpuId>` contract).
/// Obtained from [`Coordinator::cluster_ctl`]; safe to hand to an
/// autoscaler thread while the coordinator keeps serving.
#[derive(Clone)]
pub struct ClusterCtl {
    topo: ShardTopology,
    ports: Vec<RankPort>,
    num_gpus: usize,
    liveness: ShardLiveness,
}

impl ClusterCtl {
    /// Total GPU capacity (attached or not).
    pub fn num_gpus(&self) -> usize {
        self.num_gpus
    }

    /// Is the shard owning `gpu` reachable right now? Always true for
    /// an in-process tier; false while a remote server hosting it has
    /// been unreachable past [`ReconnectPolicy::dead_after`]. The live
    /// autoscaler treats a dead GPU as lost capacity and re-tiles onto
    /// survivors.
    pub fn gpu_is_live(&self, gpu: GpuId) -> bool {
        self.liveness.is_live(self.topo.shard_of(gpu))
    }

    /// Begin retiring `gpu`: its shard stops granting/advertising it
    /// immediately on receipt, lets any in-flight batch finish, then
    /// sends `gpu` on `ack` once it is provably idle.
    pub fn drain(&self, gpu: GpuId, ack: Sender<GpuId>) -> std::result::Result<(), PortClosed> {
        self.ports[self.topo.shard_of(gpu)].send(ToRank::Drain { gpu, ack })
    }

    /// Activate a detached GPU: it joins its shard's free set and is
    /// grantable from the next matchmaking pass.
    pub fn attach(&self, gpu: GpuId) -> std::result::Result<(), PortClosed> {
        self.ports[self.topo.shard_of(gpu)].send(ToRank::Attach { gpu })
    }
}

impl Coordinator {
    /// Spawn the scheduler threads. `backends[g]` receives the batches
    /// dispatched to GPU `g`; `completions` receives drop notices from
    /// the model workers (backends send their own batch completions).
    /// Panics on failure — use [`Coordinator::try_spawn`] where a
    /// remote rank tier makes failure (connection refused, topology
    /// mismatch) an expected runtime condition.
    pub fn spawn(
        cfg: CoordinatorConfig,
        backends: Vec<Sender<ToBackend>>,
        completions: Sender<Completion>,
    ) -> Self {
        Self::try_spawn(cfg, backends, completions).expect("spawn coordinator")
    }

    /// Fallible spawn: connects to `remote_ranks` (when configured)
    /// before any thread starts, so a dead or misconfigured rank tier
    /// fails the call instead of the first registration.
    pub fn try_spawn(
        cfg: CoordinatorConfig,
        backends: Vec<Sender<ToBackend>>,
        completions: Sender<Completion>,
    ) -> Result<Self> {
        assert_eq!(backends.len(), cfg.num_gpus, "one backend per GPU");
        let clock = Clock::new();
        // The attached set is always the id prefix `0..active_end`.
        let active_end = cfg.initial_gpus.unwrap_or(cfg.num_gpus).min(cfg.num_gpus) as u32;
        // One shared placement plan across the three tiers: cores are
        // handed out in NUMA-node order, so one coordinator's threads
        // fill a socket before spilling to the next.
        let mut cores = if cfg.pin_cores {
            CorePlan::detect()
        } else {
            CorePlan::disabled()
        };

        // Resolve the rank tier: in-process shard rings, or one
        // connection (hosting several shards) per remote rank server.
        let mut ports: Vec<RankPort> = Vec::new();
        let mut remote: Vec<Arc<RemoteRank>> = Vec::new();
        let mut shard_offsets: Vec<usize> = Vec::new();
        let mut shard_rx_store = Vec::new();
        let mut rank_probes: Vec<Arc<dyn RingProbe>> = Vec::new();
        let topo = if cfg.remote_ranks.is_empty() {
            let topo = ShardTopology::new(cfg.num_gpus, cfg.rank_shards);
            for _ in 0..topo.num_shards() {
                let (tx, rx) = ring::<ToRank>(RANK_RING_DEPTH);
                rx.set_busy_poll(cfg.busy_poll);
                rank_probes.push(tx.probe());
                ports.push(RankPort::Local(tx));
                shard_rx_store.push(rx);
            }
            topo
        } else {
            // Each server's advertised range must continue the tiling
            // exactly where the previous one stopped.
            let mut bounds: Vec<u32> = vec![0];
            for addr in &cfg.remote_ranks {
                let conn = Arc::new(RemoteRank::connect(
                    addr,
                    cfg.profiles.len(),
                    clock,
                    REMOTE_CONNECT_TIMEOUT,
                    cfg.reconnect,
                    cfg.fault_plan.clone(),
                )?);
                let info = conn.info;
                if info.gpu_lo != *bounds.last().unwrap() {
                    crate::bail!(
                        "rank-server {addr} owns GPUs {}..{} but the tiling is at {} — \
                         pass servers in GPU-range order",
                        info.gpu_lo,
                        info.gpu_hi,
                        bounds.last().unwrap()
                    );
                }
                shard_offsets.push(ports.len());
                let span = (info.gpu_hi - info.gpu_lo) as u64;
                let r = info.shards as usize;
                if r as u64 > span {
                    crate::bail!(
                        "rank-server {addr} advertises {r} shards over {span} GPUs \
                         (empty shard ranges)"
                    );
                }
                // Reconstruct the server's shard layout with the ONE
                // shared split formula (`ShardTopology::split`) its
                // session shards are laid out with — GPU routing
                // depends on both sides agreeing exactly.
                let server_range = info.gpu_lo..info.gpu_hi;
                for s in 0..info.shards {
                    ports.push(RankPort::Remote {
                        conn: conn.clone(),
                        shard: s,
                    });
                    bounds.push(ShardTopology::split(&server_range, r, s as usize + 1));
                }
                remote.push(conn);
            }
            if *bounds.last().unwrap() != cfg.num_gpus as u32 {
                crate::bail!(
                    "remote rank servers cover GPUs 0..{} but the cluster has {}",
                    bounds.last().unwrap(),
                    cfg.num_gpus
                );
            }
            ShardTopology::from_bounds(bounds)
        };

        let workers = cfg
            .model_workers
            .unwrap_or_else(|| ModelWorkerPool::default_workers(cfg.profiles.len()));
        // One liveness slot per rank shard, shared by every router (to
        // steer registrations off dead shards) and every connection's
        // reconnect machinery (to flip its slice).
        let liveness = ShardLiveness::all_live(topo.num_shards());
        let pool = ModelWorkerPool::spawn(
            &cfg.profiles,
            workers,
            clock,
            &topo,
            &ports,
            liveness.clone(),
            &backends,
            &completions,
            cfg.net_bound,
            cfg.exec_margin,
            cfg.busy_poll,
            &mut cores,
        );
        let model_txs = pool.model_txs();
        let depth = pool.queue_depth_probe();
        let disconnects = Arc::new(DisconnectCounts::default());

        let mut shard_handles = Vec::new();
        let mut shard_live: Vec<Arc<ShardLive>> = Vec::new();
        if cfg.remote_ranks.is_empty() {
            // Free hints exist only for in-process shards; a remote
            // tier's hints live server-side, per session.
            let hints = FreeHints::new(topo.num_shards());
            for (s, rx) in shard_rx_store.into_iter().enumerate() {
                let range = topo.range(s);
                let live = Arc::new(ShardLive::default());
                shard_live.push(live.clone());
                let shard = RankShard {
                    clock,
                    shard: s,
                    inbox: rx,
                    model_txs: model_txs.clone(),
                    active: range.start.min(active_end)..range.end.min(active_end),
                    gpus: range,
                    hints: hints.clone(),
                    live,
                };
                let core = cores.assign();
                shard_handles.push(
                    std::thread::Builder::new()
                        .name(format!("rank-shard-{s}"))
                        .spawn(move || {
                            affinity::pin(core);
                            shard.run()
                        })
                        .expect("spawn rank shard"),
                );
            }
        } else {
            for (conn, offset) in remote.iter().zip(&shard_offsets) {
                conn.start_reader(
                    model_txs.clone(),
                    *offset,
                    disconnects.clone(),
                    liveness.clone(),
                );
            }
            // Remote sessions spawn fully attached; detach the
            // headroom the way the autoscaler would — a drain of a
            // free GPU retires it immediately, and the per-connection
            // frame order guarantees the drains land before any
            // candidate traffic.
            for g in active_end..cfg.num_gpus as u32 {
                // lint:allow(hot-path-channel): drain acks are one-shot
                // control-rate traffic, and the wire ack table holds an
                // mpsc sender — not a hot hop.
                let (ack_tx, _ack_rx) = channel::<GpuId>();
                let gpu = GpuId(g);
                let _ = ports[topo.shard_of(gpu)].send(ToRank::Drain { gpu, ack: ack_tx });
            }
        }

        let dropped_submits = Arc::new(AtomicU64::new(0));
        let ingest = IngestTier::spawn(
            cfg.ingest_shards,
            model_txs.clone(),
            dropped_submits.clone(),
            cfg.busy_poll,
            &mut cores,
        );
        let ingest_probes: Vec<Arc<dyn RingProbe>> =
            ingest.txs.iter().map(|tx| tx.probe()).collect();
        let model_probes = pool.worker_ring_probes();

        Ok(Coordinator {
            clock,
            topo,
            model_txs,
            pool: Some(pool),
            depth,
            ingest,
            ports,
            shard_handles,
            remote,
            dropped_submits,
            disconnects,
            liveness,
            shard_live,
            ingest_probes,
            model_probes,
            rank_probes,
        })
    }

    /// Test-only: the shared shard-liveness map, normally maintained by
    /// the wire connections' reconnect machinery. Lets unit tests
    /// declare shards dead without standing up a rank server.
    #[cfg(test)]
    pub(crate) fn shard_liveness(&self) -> ShardLiveness {
        self.liveness.clone()
    }

    /// Handle for runtime GPU drain/attach (live autoscaling).
    pub fn cluster_ctl(&self) -> ClusterCtl {
        ClusterCtl {
            topo: self.topo.clone(),
            ports: self.ports.clone(),
            num_gpus: self.topo.range(self.topo.num_shards() - 1).end as usize,
            liveness: self.liveness.clone(),
        }
    }

    /// Live backlog across the model workers (the autoscaler's
    /// queue-depth signal).
    pub fn queue_depth_probe(&self) -> QueueDepthProbe {
        self.depth.clone()
    }

    /// Everything a live `/metrics` scrape reads (see [`CoordObs`]).
    pub fn observe(&self) -> CoordObs {
        CoordObs {
            dropped_submits: self.dropped_submits.clone(),
            disconnects: self.disconnects.clone(),
            remote: self.remote.clone(),
            shard_live: self.shard_live.clone(),
            ingest_rings: self.ingest_probes.clone(),
            model_rings: self.model_probes.clone(),
            rank_rings: self.rank_probes.clone(),
            queue_depth: self.depth.clone(),
        }
    }

    /// Remote rank-server sessions that ended without this coordinator
    /// asking (see [`FrontendStats::rank_disconnects`]).
    pub fn rank_disconnects(&self) -> u64 {
        self.disconnects.total()
    }

    /// The disconnect count split by cause.
    pub fn rank_disconnect_causes(&self) -> DisconnectBreakdown {
        self.disconnects.snapshot()
    }

    /// Sessions re-established so far across all remote connections.
    pub fn rank_reconnects(&self) -> u64 {
        self.remote.iter().map(|c| c.reconnects()).sum()
    }

    /// A producer-side submission handle routed through the ingest
    /// shards (each call / clone round-robins to the next shard).
    pub fn ingest_handle(&self) -> IngestHandle {
        self.ingest.handle()
    }

    /// Model-worker threads the pool runs on.
    pub fn num_model_workers(&self) -> usize {
        self.pool.as_ref().map_or(0, |p| p.num_workers())
    }

    /// Submissions dropped so far (undeliverable — see
    /// [`FrontendStats::dropped_submits`]).
    pub fn dropped_submits(&self) -> u64 {
        self.dropped_submits.load(Ordering::Relaxed)
    }

    /// Submit a request (frontend step ②). Arrival/deadline must be on
    /// this coordinator's clock. Full-queue policy: submissions are
    /// request-rate and sheddable — a full (or dead) worker ring counts
    /// the request into `dropped_submits` instead of blocking the
    /// producer.
    pub fn submit(&self, r: Request) {
        trace::req_event(Stage::Submit, r.id);
        if self.model_txs[r.model.0 as usize]
            .try_send(ToModel::Request(r))
            .is_err()
        {
            self.dropped_submits.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Submit a batch: sorted by model in place (stable, so per-model
    /// submission order is preserved), then forwarded as **one**
    /// [`ToModel::Requests`] burst per model — one ring send and one
    /// downstream candidate recompute per model instead of one per
    /// request. Same full-queue shed policy as [`Coordinator::submit`],
    /// counting the whole burst.
    pub fn submit_batch(&self, reqs: &mut [Request]) {
        for r in reqs.iter() {
            trace::req_event(Stage::Submit, r.id);
        }
        reqs.sort_by_key(|r| r.model);
        let mut i = 0;
        while i < reqs.len() {
            let model = reqs[i].model;
            let mut j = i + 1;
            while j < reqs.len() && reqs[j].model == model {
                j += 1;
            }
            let burst = Box::new(ReqBurst::from_slice(&reqs[i..j]));
            if self.model_txs[model.0 as usize]
                .try_send(ToModel::Requests { model, burst })
                .is_err()
            {
                self.dropped_submits
                    .fetch_add((j - i) as u64, Ordering::Relaxed);
            }
            i = j;
        }
    }

    /// Convenience: stamp arrival = now, deadline = now + slo.
    pub fn submit_now(&self, id: u64, model: ModelId, slo: Micros) {
        let now = self.clock.now();
        self.submit(Request {
            id: crate::core::types::RequestId(id),
            model,
            arrival: now,
            deadline: now.saturating_add(slo),
        });
    }

    /// Stop all threads; returns (requests processed, grants issued).
    pub fn shutdown(self) -> (u64, u64) {
        let (front, stats) = self.shutdown_stats();
        (front.processed, stats.grants)
    }

    /// Stop all threads; returns the frontend/worker statistics plus
    /// the merged per-shard grant statistics (Fig 13 left reporting).
    /// With a remote rank tier the servers keep the authoritative
    /// per-shard stats (logged there per session); the client-side
    /// count of delivered `Granted` frames is merged here so `grants`
    /// stays meaningful either way.
    pub fn shutdown_stats(mut self) -> (FrontendStats, ShardStats) {
        // Ingest first and joined: any burst they absorbed is in a
        // worker inbox before the workers see Shutdown.
        let ingest_forwarded = self.ingest.shutdown_join();
        let worker_stats = self
            .pool
            .take()
            .map(ModelWorkerPool::shutdown_join)
            .unwrap_or_default();
        for port in &self.ports {
            let _ = port.send(ToRank::Shutdown);
        }
        let mut stats = ShardStats::new();
        for h in self.shard_handles.drain(..) {
            if let Ok(s) = h.join() {
                stats.merge(&s);
            }
        }
        let mut rank_reconnects = 0;
        let mut rank_fenced_frames = 0;
        for conn in &self.remote {
            conn.join();
            stats.grants += conn.grants();
            rank_reconnects += conn.reconnects();
            rank_fenced_frames += conn.fenced();
        }
        let hwm = |probes: &[Arc<dyn RingProbe>]| {
            probes.iter().map(|p| p.high_watermark()).max().unwrap_or(0) as u64
        };
        let front = FrontendStats {
            processed: worker_stats.processed,
            flush_recomputes: worker_stats.flush_recomputes,
            ingest_forwarded,
            dropped_submits: self.dropped_submits.load(Ordering::Relaxed),
            rank_disconnects: self.disconnects.total(),
            rank_disconnect_causes: self.disconnects.snapshot(),
            rank_reconnects,
            rank_fenced_frames,
            ingest_ring_hwm: hwm(&self.ingest_probes),
            model_ring_hwm: hwm(&self.model_probes),
            rank_ring_hwm: hwm(&self.rank_probes),
        };
        (front, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn cfg(profiles: Vec<LatencyProfile>, num_gpus: usize, rank_shards: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            profiles,
            num_gpus,
            initial_gpus: None,
            rank_shards,
            ingest_shards: 1,
            model_workers: None,
            net_bound: Micros::from_millis_f64(2.0),
            exec_margin: Micros::from_millis_f64(0.5),
            remote_ranks: Vec::new(),
            busy_poll: false,
            pin_cores: false,
            reconnect: ReconnectPolicy::default(),
            fault_plan: FaultPlan::none(),
        }
    }

    /// End-to-end through real threads: submit a burst, expect the
    /// deferred window to group it into one large batch. ℓ is ms-scale
    /// and `net_bound` budgets for OS-thread wakeup jitter (the paper
    /// budgets the RDMA p99.99 the same way, §5.6).
    #[test]
    fn coordinator_batches_a_burst() {
        let profile = LatencyProfile::new(1.0, 5.0);
        let (backend_tx, backend_rx) = channel::<ToBackend>();
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let coord = Coordinator::spawn(cfg(vec![profile], 1, 1), vec![backend_tx], comp_tx);
        for i in 0..8 {
            coord.submit_now(i, ModelId(0), Micros::from_millis_f64(100.0));
        }
        let msg = backend_rx
            .recv_timeout(SETTLE_RECV_TIMEOUT)
            .expect("batch dispatched");
        match msg {
            ToBackend::Execute { requests, .. } => {
                assert!(
                    requests.len() >= 6,
                    "expected a large batch, got {}",
                    requests.len()
                );
            }
            other => panic!("unexpected {other:?}"),
        }
        let (processed, grants) = coord.shutdown();
        assert_eq!(processed, 8);
        assert!(grants >= 1);
    }

    /// Same burst submitted through `submit_batch`: one channel send,
    /// one downstream recompute, same batching outcome.
    #[test]
    fn coordinator_batches_a_submit_batch() {
        let profile = LatencyProfile::new(1.0, 5.0);
        let (backend_tx, backend_rx) = channel::<ToBackend>();
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let coord = Coordinator::spawn(cfg(vec![profile], 1, 1), vec![backend_tx], comp_tx);
        let now = coord.clock.now();
        let mut batch: Vec<Request> = (0..8)
            .map(|i| Request {
                id: crate::core::types::RequestId(i),
                model: ModelId(0),
                arrival: now,
                deadline: now + Micros::from_millis_f64(100.0),
            })
            .collect();
        coord.submit_batch(&mut batch);
        let msg = backend_rx
            .recv_timeout(SETTLE_RECV_TIMEOUT)
            .expect("batch dispatched");
        match msg {
            ToBackend::Execute { requests, .. } => {
                assert!(requests.len() >= 6, "got {}", requests.len());
            }
            other => panic!("unexpected {other:?}"),
        }
        let (front, stats) = coord.shutdown_stats();
        assert_eq!(front.processed, 8);
        assert_eq!(front.dropped_submits, 0);
        assert!(stats.grants >= 1);
    }

    /// Two models, one GPU: both get served. The second model's looser
    /// SLO leaves room for its deferred batch after the first model's
    /// batch finishes.
    #[test]
    fn coordinator_multiplexes_models() {
        let profile = LatencyProfile::new(1.0, 5.0);
        let (backend_tx, backend_rx) = channel::<ToBackend>();
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let coord = Coordinator::spawn(cfg(vec![profile, profile], 1, 1), vec![backend_tx], comp_tx);
        for i in 0..4 {
            coord.submit_now(i, ModelId(0), Micros::from_millis_f64(40.0));
            coord.submit_now(100 + i, ModelId(1), Micros::from_millis_f64(100.0));
        }
        let mut seen = std::collections::HashSet::new();
        let deadline = std::time::Instant::now() + Duration::from_millis(800);
        while seen.len() < 2 && std::time::Instant::now() < deadline {
            if let Ok(ToBackend::Execute { model, .. }) =
                backend_rx.recv_timeout(Duration::from_millis(100))
            {
                seen.insert(model);
            }
        }
        assert_eq!(seen.len(), 2, "both models dispatched");
        coord.shutdown();
    }

    /// Sharded coordinator: four models across two shards, all served,
    /// every request dispatched exactly once across the GPU channels.
    /// With `model_workers = 2` the four models share two pool threads.
    #[test]
    fn sharded_coordinator_serves_all_models() {
        let profile = LatencyProfile::new(0.5, 2.0);
        let mut backend_txs = Vec::new();
        let mut backend_rxs = Vec::new();
        for _ in 0..4 {
            let (tx, rx) = channel::<ToBackend>();
            backend_txs.push(tx);
            backend_rxs.push(rx);
        }
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let mut c = cfg(vec![profile; 4], 4, 2);
        c.model_workers = Some(2);
        let coord = Coordinator::spawn(c, backend_txs, comp_tx);
        assert_eq!(coord.num_model_workers(), 2);
        for m in 0..4u32 {
            for i in 0..6 {
                coord.submit_now(
                    (m as u64) * 100 + i,
                    ModelId(m),
                    Micros::from_millis_f64(120.0),
                );
            }
        }
        // Collect executes across all GPU channels until every model's
        // requests are accounted for (or timeout).
        let mut got: std::collections::HashMap<u32, usize> = Default::default();
        let deadline = std::time::Instant::now() + Duration::from_millis(1_500);
        while got.values().copied().sum::<usize>() < 24
            && std::time::Instant::now() < deadline
        {
            for rx in &backend_rxs {
                while let Ok(ToBackend::Execute { model, requests, .. }) = rx.try_recv() {
                    *got.entry(model.0).or_default() += requests.len();
                }
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let (processed, grants) = coord.shutdown();
        assert_eq!(processed, 24);
        assert!(grants >= 4, "at least one grant per model, got {grants}");
        for m in 0..4u32 {
            assert_eq!(
                got.get(&m).copied().unwrap_or(0),
                6,
                "model {m} must have all requests executed: {got:?}"
            );
        }
    }
}
