//! The multithreaded centralized scheduler (§4.2, Fig 18): independent
//! **ModelThreads** (request-rate work, embarrassingly parallel) and
//! `R` **rank shards** (batch-rate matchmaking, each owning a
//! contiguous GPU id range) — the architecture that lets Symphony's
//! scheduler process millions of requests per second and coordinate
//! thousands of GPUs (Fig 13 left). `rank_shards = 1` is exactly the
//! paper's single-RankThread configuration.
//!
//! The coordinator is backend-agnostic: callers supply one `ToBackend`
//! channel per GPU (real PJRT executors in [`crate::serve`], sleep
//! emulators, or sinks for scheduler-only benchmarks).

pub mod clock;
pub mod messages;
pub mod model_thread;
pub mod rank_shard;
pub mod router;

use std::sync::mpsc::{channel, SendError, Sender};
use std::thread::JoinHandle;

use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, Request};
pub use clock::Clock;
pub use messages::{CandWindow, Completion, ToBackend, ToModel, ToRank};
use model_thread::ModelThread;
pub use rank_shard::{RankShard, ShardStats};
pub use router::{FreeHints, RankRouter, ShardTopology};

/// Configuration of a running coordinator.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub profiles: Vec<LatencyProfile>,
    /// Total GPU capacity: one backend channel and one shard-owned slot
    /// per id. The ids in `initial_gpus..num_gpus` start detached —
    /// headroom the autoscaler can attach at runtime.
    pub num_gpus: usize,
    /// GPUs attached at spawn (`None` = all of `num_gpus`). Always the
    /// lowest ids: attach grows the active prefix upward, drain retires
    /// from the top — the consolidation order min-id dispatch preserves.
    pub initial_gpus: Option<usize>,
    /// Rank shards (clamped to `1..=num_gpus`); 1 = the paper's single
    /// RankThread.
    pub rank_shards: usize,
    /// Network-delay budget subtracted from candidate windows (§5.6).
    pub net_bound: Micros,
    /// Safety margin added to busy estimates sent to the rank shards.
    pub exec_margin: Micros,
}

/// A live coordinator: rank shards + one ModelThread per model.
pub struct Coordinator {
    pub clock: Clock,
    topo: ShardTopology,
    model_txs: Vec<Sender<ToModel>>,
    shard_txs: Vec<Sender<ToRank>>,
    model_handles: Vec<JoinHandle<u64>>,
    shard_handles: Vec<JoinHandle<ShardStats>>,
}

/// Cheap clonable handle for runtime cluster resizing (§3.5 live
/// autoscaling): routes `Drain`/`Attach` to the shard owning the GPU.
/// Obtained from [`Coordinator::cluster_ctl`]; safe to hand to an
/// autoscaler thread while the coordinator keeps serving.
#[derive(Clone)]
pub struct ClusterCtl {
    topo: ShardTopology,
    shard_txs: Vec<Sender<ToRank>>,
    num_gpus: usize,
}

impl ClusterCtl {
    /// Total GPU capacity (attached or not).
    pub fn num_gpus(&self) -> usize {
        self.num_gpus
    }

    /// Begin retiring `gpu`: its shard stops granting/advertising it
    /// immediately on receipt, lets any in-flight batch finish, then
    /// sends `gpu` on `ack` once it is provably idle.
    pub fn drain(&self, gpu: GpuId, ack: Sender<GpuId>) -> Result<(), SendError<ToRank>> {
        self.shard_txs[self.topo.shard_of(gpu)].send(ToRank::Drain { gpu, ack })
    }

    /// Activate a detached GPU: it joins its shard's free set and is
    /// grantable from the next matchmaking pass.
    pub fn attach(&self, gpu: GpuId) -> Result<(), SendError<ToRank>> {
        self.shard_txs[self.topo.shard_of(gpu)].send(ToRank::Attach { gpu })
    }
}

impl Coordinator {
    /// Spawn the scheduler threads. `backends[g]` receives the batches
    /// dispatched to GPU `g`; `completions` receives drop notices from
    /// ModelThreads (backends send their own batch completions).
    pub fn spawn(
        cfg: CoordinatorConfig,
        backends: Vec<Sender<ToBackend>>,
        completions: Sender<Completion>,
    ) -> Self {
        assert_eq!(backends.len(), cfg.num_gpus, "one backend per GPU");
        let clock = Clock::new();
        let topo = ShardTopology::new(cfg.num_gpus, cfg.rank_shards);
        let shards = topo.num_shards();
        let hints = FreeHints::new(shards);
        // The attached set is always the id prefix `0..active_end`.
        let active_end = cfg.initial_gpus.unwrap_or(cfg.num_gpus).min(cfg.num_gpus) as u32;

        let mut model_txs = Vec::new();
        let mut model_rx_store = Vec::new();
        for _ in 0..cfg.profiles.len() {
            let (tx, rx) = channel::<ToModel>();
            model_txs.push(tx);
            model_rx_store.push(rx);
        }

        let mut shard_txs = Vec::new();
        let mut shard_handles = Vec::new();
        for s in 0..shards {
            let (tx, rx) = channel::<ToRank>();
            shard_txs.push(tx);
            let range = topo.range(s);
            let shard = RankShard {
                clock,
                shard: s,
                inbox: rx,
                model_txs: model_txs.clone(),
                active: range.start.min(active_end)..range.end.min(active_end),
                gpus: range,
                hints: hints.clone(),
            };
            shard_handles.push(
                std::thread::Builder::new()
                    .name(format!("rank-shard-{s}"))
                    .spawn(move || shard.run())
                    .expect("spawn rank shard"),
            );
        }

        let mut model_handles = Vec::new();
        for (i, rx) in model_rx_store.into_iter().enumerate() {
            let mt = ModelThread {
                model: ModelId(i as u32),
                profile: cfg.profiles[i],
                clock,
                inbox: rx,
                router: RankRouter::new(topo.clone(), shard_txs.clone(), ModelId(i as u32)),
                backends: backends.clone(),
                completions: completions.clone(),
                net_bound: cfg.net_bound,
                exec_margin: cfg.exec_margin,
            };
            model_handles.push(
                std::thread::Builder::new()
                    .name(format!("model-thread-{i}"))
                    .spawn(move || mt.run())
                    .expect("spawn model thread"),
            );
        }

        Coordinator {
            clock,
            topo,
            model_txs,
            shard_txs,
            model_handles,
            shard_handles,
        }
    }

    /// Handle for runtime GPU drain/attach (live autoscaling).
    pub fn cluster_ctl(&self) -> ClusterCtl {
        ClusterCtl {
            topo: self.topo.clone(),
            shard_txs: self.shard_txs.clone(),
            num_gpus: self.topo.range(self.topo.num_shards() - 1).end as usize,
        }
    }

    /// Submit a request (frontend step ②). Arrival/deadline must be on
    /// this coordinator's clock.
    pub fn submit(&self, r: Request) {
        let _ = self.model_txs[r.model.0 as usize].send(ToModel::Request(r));
    }

    /// Convenience: stamp arrival = now, deadline = now + slo.
    pub fn submit_now(&self, id: u64, model: ModelId, slo: Micros) {
        let now = self.clock.now();
        self.submit(Request {
            id: crate::core::types::RequestId(id),
            model,
            arrival: now,
            deadline: now + slo,
        });
    }

    /// Stop all threads; returns (requests processed, grants issued).
    pub fn shutdown(self) -> (u64, u64) {
        let (processed, stats) = self.shutdown_stats();
        (processed, stats.grants)
    }

    /// Stop all threads; returns requests processed plus the merged
    /// per-shard grant statistics (Fig 13 left reporting).
    pub fn shutdown_stats(mut self) -> (u64, ShardStats) {
        for tx in &self.model_txs {
            let _ = tx.send(ToModel::Shutdown);
        }
        let processed: u64 = self
            .model_handles
            .drain(..)
            .map(|h| h.join().unwrap_or(0))
            .sum();
        for tx in &self.shard_txs {
            let _ = tx.send(ToRank::Shutdown);
        }
        let mut stats = ShardStats::new();
        for h in self.shard_handles.drain(..) {
            if let Ok(s) = h.join() {
                stats.merge(&s);
            }
        }
        (processed, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Duration;

    /// End-to-end through real threads: submit a burst, expect the
    /// deferred window to group it into one large batch. ℓ is ms-scale
    /// and `net_bound` budgets for OS-thread wakeup jitter (the paper
    /// budgets the RDMA p99.99 the same way, §5.6).
    #[test]
    fn coordinator_batches_a_burst() {
        let profile = LatencyProfile::new(1.0, 5.0);
        let (backend_tx, backend_rx) = channel::<ToBackend>();
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let coord = Coordinator::spawn(
            CoordinatorConfig {
                profiles: vec![profile],
                num_gpus: 1,
                initial_gpus: None,
                rank_shards: 1,
                net_bound: Micros::from_millis_f64(2.0),
                exec_margin: Micros::from_millis_f64(0.5),
            },
            vec![backend_tx],
            comp_tx,
        );
        for i in 0..8 {
            coord.submit_now(i, ModelId(0), Micros::from_millis_f64(100.0));
        }
        let msg = backend_rx
            .recv_timeout(Duration::from_millis(1_000))
            .expect("batch dispatched");
        match msg {
            ToBackend::Execute { requests, .. } => {
                assert!(
                    requests.len() >= 6,
                    "expected a large batch, got {}",
                    requests.len()
                );
            }
            other => panic!("unexpected {other:?}"),
        }
        let (processed, grants) = coord.shutdown();
        assert_eq!(processed, 8);
        assert!(grants >= 1);
    }

    /// Two models, one GPU: both get served. The second model's looser
    /// SLO leaves room for its deferred batch after the first model's
    /// batch finishes.
    #[test]
    fn coordinator_multiplexes_models() {
        let profile = LatencyProfile::new(1.0, 5.0);
        let (backend_tx, backend_rx) = channel::<ToBackend>();
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let coord = Coordinator::spawn(
            CoordinatorConfig {
                profiles: vec![profile, profile],
                num_gpus: 1,
                initial_gpus: None,
                rank_shards: 1,
                net_bound: Micros::from_millis_f64(2.0),
                exec_margin: Micros::from_millis_f64(0.5),
            },
            vec![backend_tx],
            comp_tx,
        );
        for i in 0..4 {
            coord.submit_now(i, ModelId(0), Micros::from_millis_f64(40.0));
            coord.submit_now(100 + i, ModelId(1), Micros::from_millis_f64(100.0));
        }
        let mut seen = std::collections::HashSet::new();
        let deadline = std::time::Instant::now() + Duration::from_millis(800);
        while seen.len() < 2 && std::time::Instant::now() < deadline {
            if let Ok(ToBackend::Execute { model, .. }) =
                backend_rx.recv_timeout(Duration::from_millis(100))
            {
                seen.insert(model);
            }
        }
        assert_eq!(seen.len(), 2, "both models dispatched");
        coord.shutdown();
    }

    /// Sharded coordinator: four models across two shards, all served,
    /// every request dispatched exactly once across the GPU channels.
    #[test]
    fn sharded_coordinator_serves_all_models() {
        let profile = LatencyProfile::new(0.5, 2.0);
        let mut backend_txs = Vec::new();
        let mut backend_rxs = Vec::new();
        for _ in 0..4 {
            let (tx, rx) = channel::<ToBackend>();
            backend_txs.push(tx);
            backend_rxs.push(rx);
        }
        let (comp_tx, _comp_rx) = channel::<Completion>();
        let coord = Coordinator::spawn(
            CoordinatorConfig {
                profiles: vec![profile; 4],
                num_gpus: 4,
                initial_gpus: None,
                rank_shards: 2,
                net_bound: Micros::from_millis_f64(2.0),
                exec_margin: Micros::from_millis_f64(0.5),
            },
            backend_txs,
            comp_tx,
        );
        for m in 0..4u32 {
            for i in 0..6 {
                coord.submit_now(
                    (m as u64) * 100 + i,
                    ModelId(m),
                    Micros::from_millis_f64(120.0),
                );
            }
        }
        // Collect executes across all GPU channels until every model's
        // requests are accounted for (or timeout).
        let mut got: std::collections::HashMap<u32, usize> = Default::default();
        let deadline = std::time::Instant::now() + Duration::from_millis(1_500);
        while got.values().copied().sum::<usize>() < 24
            && std::time::Instant::now() < deadline
        {
            for rx in &backend_rxs {
                while let Ok(ToBackend::Execute { model, requests, .. }) = rx.try_recv() {
                    *got.entry(model.0).or_default() += requests.len();
                }
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        let (processed, grants) = coord.shutdown();
        assert_eq!(processed, 24);
        assert!(grants >= 4, "at least one grant per model, got {grants}");
        for m in 0..4u32 {
            assert_eq!(
                got.get(&m).copied().unwrap_or(0),
                6,
                "model {m} must have all requests executed: {got:?}"
            );
        }
    }
}
