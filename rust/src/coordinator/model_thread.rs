//! ModelWorkerPool (§4.2, Fig 18, multiplexed): `W` worker threads run
//! the request-rate half of the scheduler. The paper spawns one
//! ModelThread per model — "it accesses only model-local information
//! and updates the candidate" — which is correct but does not survive
//! contact with 256 models on a 16-core host (256 OS threads thrashing
//! the run queue). The pool keeps the paper's *isolation* (model state
//! is still touched by exactly one thread: model `m` lives on worker
//! `m % W`) while capping the thread count at `W`.
//!
//! Each worker drains its inbox in bursts, latest-wins style like
//! `RankShard`'s `InboxBatch`: request arrivals only push the queue and
//! mark the model dirty; the end-of-drain flush performs **one**
//! candidate recompute and **one** router registration per dirty model,
//! so a k-request burst costs 1 recompute instead of k
//! ([`WorkerStats::flush_recomputes`] counts exactly these). Grant /
//! revalidate / overflow messages are batch-rate and handled inline at
//! their position in the stream — on "GPU Granted" the worker finalizes
//! the batch and sends it to the backend immediately, as in the paper.
//!
//! Like the single-model thread before it, a worker talks to the rank
//! shards through one [`RankRouter`] per owned model: candidate updates
//! go to whichever shard currently holds the registration, `Overflow`
//! verdicts migrate the candidate to a shard with free capacity, and a
//! grant or revalidation resets the registration to the home shard.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::{MAX_DRAIN, MODEL_RING_DEPTH};

use crate::coordinator::clock::Clock;
use crate::coordinator::messages::{CandWindow, Completion, ToBackend, ToModel};
use crate::coordinator::router::{RankPort, RankRouter, ShardLiveness, ShardTopology};
use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;
use crate::core::types::{ModelId, ReqBurst, Request};
use crate::obs::trace::{self, Stage};
use crate::util::affinity::{self, CorePlan};
use crate::util::ring::{ring, RingProbe, RingReceiver, RingSender, TryRecvError};

/// What one worker did over its lifetime; merged at shutdown into
/// [`crate::coordinator::FrontendStats`].
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerStats {
    /// Requests that entered a model queue.
    pub processed: u64,
    /// Candidate recomputes performed by the end-of-drain flush — the
    /// burst-amortization counter: a k-request burst for one model adds
    /// exactly 1 (the grant/revalidate/overflow paths recompute inline
    /// and are not counted here).
    pub flush_recomputes: u64,
}

impl WorkerStats {
    pub fn merge(&mut self, other: &WorkerStats) {
        self.processed += other.processed;
        self.flush_recomputes += other.flush_recomputes;
    }
}

/// Per-model scheduling state, owned by exactly one worker.
struct ModelSlot {
    model: ModelId,
    profile: LatencyProfile,
    queue: TrackingQueue,
    router: RankRouter,
    /// Overflow migrations of the current logical candidate.
    hops: u32,
    /// Queued work changed since the last registration; the flush will
    /// recompute + register once.
    dirty: bool,
}

enum Flow {
    Go,
    Stop,
}

/// One of the `W` pool threads: multiplexes the slots of models
/// `worker, worker + W, worker + 2W, ...`.
pub struct ModelWorker {
    worker: usize,
    num_workers: usize,
    clock: Clock,
    inbox: RingReceiver<ToModel>,
    slots: Vec<ModelSlot>,
    backends: Vec<Sender<ToBackend>>,
    completions: Sender<Completion>,
    net_bound: Micros,
    exec_margin: Micros,
    /// Requests sitting in this worker's model queues right now
    /// (delta-maintained: +arrivals, −dispatches, −sheds), published to
    /// `depth[worker]` once per drain — the autoscaler's backlog
    /// signal (`WindowStats::queue_depth`).
    queued: u64,
    depth: Arc<Vec<AtomicU64>>,
}

impl ModelWorker {
    #[inline]
    fn slot_of(&self, m: ModelId) -> usize {
        debug_assert_eq!(m.0 as usize % self.num_workers, self.worker, "misrouted {m:?}");
        m.0 as usize / self.num_workers
    }

    /// Run until `Shutdown` / disconnect. Returns the worker's stats.
    pub fn run(mut self) -> WorkerStats {
        let mut stats = WorkerStats::default();
        // Slot indices touched by the current drain (flag-deduped).
        let mut dirty: Vec<usize> = Vec::new();
        // Reusable drop scratch: `candidate` pushes expired heads here,
        // `mem::take` ships them allocation-free when non-empty.
        let mut dropped = ReqBurst::new();
        'outer: loop {
            let Ok(first) = self.inbox.recv() else { break };
            // Drain the burst this message heads (bounded by
            // `MAX_DRAIN` so a sustained backlog cannot starve the
            // flush), then flush once.
            let mut next = Some(first);
            let mut absorbed = 0usize;
            while let Some(msg) = next.take() {
                if let Flow::Stop = self.handle(msg, &mut dirty, &mut dropped, &mut stats) {
                    break 'outer;
                }
                absorbed += 1;
                if absorbed >= MAX_DRAIN {
                    break;
                }
                match self.inbox.try_recv() {
                    Ok(m) => next = Some(m),
                    Err(TryRecvError::Empty) => {}
                    Err(TryRecvError::Disconnected) => break 'outer,
                }
            }
            // Flush: one candidate recompute + one registration per
            // model with new work, no matter how many requests the
            // drain absorbed for it.
            for si in dirty.drain(..) {
                if !self.slots[si].dirty {
                    // A grant/revalidate/overflow later in the drain
                    // already registered the post-recompute state.
                    continue;
                }
                self.slots[si].dirty = false;
                stats.flush_recomputes += 1;
                let now = self.clock.now();
                let cand = self.compute(si, now, &mut dropped);
                let slot = &mut self.slots[si];
                if cand.is_none() {
                    // An emptied queue ends the logical candidate:
                    // reset the migration budget so the next one starts
                    // fresh at the home shard.
                    slot.hops = 0;
                    if slot.router.register_home(None).is_err() {
                        break 'outer;
                    }
                } else if slot.router.register_current(cand, slot.hops).is_err() {
                    // Replace in place: a steered candidate stays at
                    // its current shard (re-homing on every burst would
                    // thrash under sustained overflow).
                    break 'outer;
                }
            }
            // Publish this worker's backlog once per drain (the
            // flush-rate queue-depth signal; see `QueueDepthProbe`).
            self.depth[self.worker].store(self.queued, Ordering::Relaxed);
        }
        // A dying worker's residual backlog stays published: requests
        // stranded behind a dead rank port still read as backlog, not
        // as an idle tier.
        self.depth[self.worker].store(self.queued, Ordering::Relaxed);
        stats
    }

    /// Drop hopeless heads and compute `slots[si]`'s candidate window,
    /// reporting drops through the completion channel.
    fn compute(&mut self, si: usize, now: Micros, dropped: &mut ReqBurst) -> Option<CandWindow> {
        let slot = &mut self.slots[si];
        let cand = slot
            .queue
            .candidate(&slot.profile, now, self.net_bound, dropped);
        if !dropped.is_empty() {
            self.queued = self.queued.saturating_sub(dropped.len() as u64);
            let _ = self
                .completions
                .send(Completion::Dropped(std::mem::take(dropped)));
        }
        cand
    }

    fn mark_dirty(&mut self, si: usize, dirty: &mut Vec<usize>) {
        if !self.slots[si].dirty {
            self.slots[si].dirty = true;
            dirty.push(si);
        }
    }

    fn handle(
        &mut self,
        msg: ToModel,
        dirty: &mut Vec<usize>,
        dropped: &mut ReqBurst,
        stats: &mut WorkerStats,
    ) -> Flow {
        match msg {
            ToModel::Request(r) => {
                stats.processed += 1;
                self.queued += 1;
                let si = self.slot_of(r.model);
                debug_assert_eq!(self.slots[si].model, r.model, "slot layout broken");
                trace::req_event(Stage::WorkerRecv, r.id);
                self.slots[si].queue.push(r);
                self.mark_dirty(si, dirty);
            }
            ToModel::Requests { model, burst } => {
                stats.processed += burst.len() as u64;
                self.queued += burst.len() as u64;
                let si = self.slot_of(model);
                for &r in burst.iter() {
                    debug_assert_eq!(r.model, model, "mixed-model burst");
                    trace::req_event(Stage::WorkerRecv, r.id);
                    self.slots[si].queue.push(r);
                }
                if !burst.is_empty() {
                    self.mark_dirty(si, dirty);
                }
            }
            ToModel::Granted { model, gpu } => {
                let si = self.slot_of(model);
                // The shard consumed the registration at grant time:
                // the router must not coalesce the next one away.
                self.slots[si].router.invalidate_last_sent();
                let now = self.clock.now();
                let cand = self.compute(si, now, dropped);
                if let Some(c) = cand {
                    let slot = &mut self.slots[si];
                    let batch = slot.queue.take_burst(c.size as usize);
                    let busy_until = now
                        .saturating_add(slot.profile.latency(c.size))
                        .saturating_add(self.exec_margin);
                    let dispatched = batch.len() as u64;
                    for r in batch.iter() {
                        trace::req_event(Stage::GrantRecv, r.id);
                        trace::req_event(Stage::Dispatch, r.id);
                    }
                    let _ = self.backends[gpu.0 as usize].send(ToBackend::Execute {
                        model,
                        requests: batch,
                        dispatched_at: now,
                    });
                    let _ = slot.router.gpu_busy_until(gpu, busy_until);
                    self.queued = self.queued.saturating_sub(dispatched);
                } else {
                    // Nothing left to run; hand the GPU back as free.
                    let _ = self.slots[si].router.gpu_busy_until(gpu, now);
                }
                // Register the next candidate — a fresh logical
                // candidate, so it starts back at the home shard. This
                // also covers any requests absorbed earlier in this
                // drain: clear the dirty flag so the flush does not
                // redundantly re-register.
                let cand = self.compute(si, self.clock.now(), dropped);
                let slot = &mut self.slots[si];
                slot.hops = 0;
                slot.dirty = false;
                if slot.router.register_home(cand).is_err() {
                    return Flow::Stop;
                }
            }
            ToModel::Revalidate { model } => {
                let si = self.slot_of(model);
                // Expiry revalidation: the shard dropped the
                // registration before sending this.
                self.slots[si].router.invalidate_last_sent();
                let cand = self.compute(si, self.clock.now(), dropped);
                let slot = &mut self.slots[si];
                slot.hops = 0;
                slot.dirty = false;
                if slot.router.register_home(cand).is_err() {
                    return Flow::Stop;
                }
            }
            ToModel::Reregister { model } => {
                // Post-reconnect replay: the wire client re-established
                // a rank-server session whose shards spawned empty. The
                // router's coalescing memory describes the dead
                // session, so drop it and re-register the current
                // candidate from scratch — same shape as `Revalidate`,
                // and the router's liveness-aware `register_current`
                // routes around any shards still down. A fresh logical
                // registration starts the migration budget over.
                let si = self.slot_of(model);
                self.slots[si].router.invalidate_last_sent();
                let cand = self.compute(si, self.clock.now(), dropped);
                let slot = &mut self.slots[si];
                slot.hops = 0;
                slot.dirty = false;
                if slot.router.register_home(cand).is_err() {
                    return Flow::Stop;
                }
            }
            ToModel::Overflow { model, to_shard, seq } => {
                let si = self.slot_of(model);
                // Stale verdicts (the candidate was replaced since that
                // registration) are ignored.
                if !self.slots[si].router.overflow_is_current(seq) {
                    return Flow::Go;
                }
                // The steering shard unregistered the candidate before
                // sending the verdict.
                self.slots[si].router.invalidate_last_sent();
                let cand = self.compute(si, self.clock.now(), dropped);
                let slot = &mut self.slots[si];
                slot.dirty = false;
                // The recompute can empty the queue: that ends the
                // logical candidate, so reset the migration budget and
                // re-home (same invariant as the request arm).
                if cand.is_none() {
                    slot.hops = 0;
                    if slot.router.register_home(None).is_err() {
                        return Flow::Stop;
                    }
                    return Flow::Go;
                }
                slot.hops += 1;
                let hops = slot.hops;
                if slot.router.register_overflow(to_shard, cand, hops).is_err() {
                    return Flow::Stop;
                }
            }
            ToModel::Shutdown => return Flow::Stop,
        }
        Flow::Go
    }
}

/// Live view of the worker pool's backlog: one published counter per
/// worker, summed on read. Cheap to clone and hand to the autoscale
/// epoch loop — see [`crate::autoscale::WindowStats::queue_depth`].
#[derive(Clone)]
pub struct QueueDepthProbe(Arc<Vec<AtomicU64>>);

impl QueueDepthProbe {
    /// Requests queued across all model workers, as of each worker's
    /// last flush.
    pub fn total(&self) -> u64 {
        self.0.iter().map(|d| d.load(Ordering::Relaxed)).sum()
    }
}

/// The spawned pool: `W` [`ModelWorker`] threads plus their inboxes.
/// Rank shards and frontends address model `m` through
/// [`ModelWorkerPool::model_txs`] (clones of worker `m % W`'s sender).
pub struct ModelWorkerPool {
    worker_txs: Vec<RingSender<ToModel>>,
    handles: Vec<JoinHandle<WorkerStats>>,
    n_models: usize,
    depth: Arc<Vec<AtomicU64>>,
}

impl ModelWorkerPool {
    /// Default worker count: `min(models, available_parallelism)` — the
    /// request-rate work is embarrassingly parallel but gains nothing
    /// past the core count.
    pub fn default_workers(n_models: usize) -> usize {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(8);
        n_models.clamp(1, cores.max(1))
    }

    /// Spawn the pool. `ports` must address the live rank shards —
    /// in-process inboxes (whose threads may start later; the rings
    /// must exist) or remote rank-server connections. `busy_poll`
    /// keeps the workers' drain loops spinning instead of parking;
    /// `cores` pins each worker to its assigned core (pass
    /// [`CorePlan::disabled`] to skip pinning). `liveness` is the
    /// shared per-shard liveness map every router consults — pass
    /// [`ShardLiveness::all_live`] for in-process shards (which cannot
    /// die independently); the wire configuration hands in the map its
    /// `RemoteRank` connections maintain, so registrations route around
    /// dead servers.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        profiles: &[LatencyProfile],
        workers: usize,
        clock: Clock,
        topo: &ShardTopology,
        ports: &[RankPort],
        liveness: ShardLiveness,
        backends: &[Sender<ToBackend>],
        completions: &Sender<Completion>,
        net_bound: Micros,
        exec_margin: Micros,
        busy_poll: bool,
        cores: &mut CorePlan,
    ) -> Self {
        let n_models = profiles.len();
        let workers = workers.clamp(1, n_models.max(1));
        let depth: Arc<Vec<AtomicU64>> =
            Arc::new((0..workers).map(|_| AtomicU64::new(0)).collect());
        let mut worker_txs = Vec::with_capacity(workers);
        let mut rx_store = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = ring::<ToModel>(MODEL_RING_DEPTH);
            rx.set_busy_poll(busy_poll);
            worker_txs.push(tx);
            rx_store.push(rx);
        }
        let mut handles = Vec::with_capacity(workers);
        for (w, rx) in rx_store.into_iter().enumerate() {
            let slots: Vec<ModelSlot> = (w..n_models)
                .step_by(workers)
                .map(|m| ModelSlot {
                    model: ModelId(m as u32),
                    profile: profiles[m],
                    queue: TrackingQueue::new(),
                    router: RankRouter::with_liveness(
                        topo.clone(),
                        ports.to_vec(),
                        ModelId(m as u32),
                        liveness.clone(),
                    ),
                    hops: 0,
                    dirty: false,
                })
                .collect();
            let worker = ModelWorker {
                worker: w,
                num_workers: workers,
                clock,
                inbox: rx,
                slots,
                backends: backends.to_vec(),
                completions: completions.clone(),
                net_bound,
                exec_margin,
                queued: 0,
                depth: depth.clone(),
            };
            let core = cores.assign();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("model-worker-{w}"))
                    .spawn(move || {
                        affinity::pin(core);
                        worker.run()
                    })
                    .expect("spawn model worker"),
            );
        }
        ModelWorkerPool {
            worker_txs,
            handles,
            n_models,
            depth,
        }
    }

    /// OS threads the pool runs on.
    pub fn num_workers(&self) -> usize {
        self.worker_txs.len()
    }

    /// Clonable live backlog view (see [`QueueDepthProbe`]).
    pub fn queue_depth_probe(&self) -> QueueDepthProbe {
        QueueDepthProbe(self.depth.clone())
    }

    /// One occupancy probe per worker inbox ring (for `/metrics`; see
    /// [`crate::util::ring::RingProbe`]).
    pub fn worker_ring_probes(&self) -> Vec<std::sync::Arc<dyn RingProbe>> {
        self.worker_txs.iter().map(|tx| tx.probe()).collect()
    }

    /// One sender per model (clones of the owning worker's inbox) for
    /// the rank shards' `model_txs` routing and the frontend submit
    /// path.
    pub fn model_txs(&self) -> Vec<RingSender<ToModel>> {
        (0..self.n_models)
            .map(|m| self.worker_txs[m % self.worker_txs.len()].clone())
            .collect()
    }

    /// Stop every worker and merge their stats.
    pub fn shutdown_join(mut self) -> WorkerStats {
        for tx in &self.worker_txs {
            let _ = tx.send(ToModel::Shutdown);
        }
        let mut stats = WorkerStats::default();
        for h in self.handles.drain(..) {
            if let Ok(s) = h.join() {
                stats.merge(&s);
            }
        }
        stats
    }
}

/// A deadline-ordered queue that returns full `Request`s for drops (the
/// sim-side `ModelQueue` only tracks ids).
pub(crate) struct TrackingQueue {
    q: std::collections::VecDeque<Request>,
}

impl TrackingQueue {
    pub(crate) fn new() -> Self {
        TrackingQueue {
            q: std::collections::VecDeque::new(),
        }
    }

    /// Insert preserving deadline order (same contract as the sim-side
    /// `ModelQueue::push`): `candidate` budgets the whole batch against
    /// `q.front().deadline`, so an out-of-order delivery — frontend
    /// clock skew, a per-request SLO override — must insert-sort, not
    /// silently hide an earlier deadline behind the head. In-order
    /// arrival stays O(1).
    pub(crate) fn push(&mut self, r: Request) {
        let mut i = self.q.len();
        while i > 0 && self.q[i - 1].deadline > r.deadline {
            i -= 1;
        }
        if i == self.q.len() {
            self.q.push_back(r);
        } else {
            self.q.insert(i, r);
        }
    }

    /// Pop the first `n` requests straight into an inline [`ReqBurst`]
    /// — the live-side mirror of the sim's allocation-free
    /// `ModelQueue::take_list`: dispatching a batch ≤ `REQBURST_INLINE`
    /// touches no allocator (the seed built a fresh `Vec` per
    /// dispatch).
    pub(crate) fn take_burst(&mut self, n: usize) -> ReqBurst {
        let n = n.min(self.q.len());
        let mut out = ReqBurst::with_capacity(n);
        for _ in 0..n {
            out.push(self.q.pop_front().unwrap());
        }
        out
    }

    /// Drop hopeless heads into the caller's reusable scratch, then
    /// compute the candidate window.
    pub(crate) fn candidate(
        &mut self,
        profile: &LatencyProfile,
        now: Micros,
        net_bound: Micros,
        dropped: &mut ReqBurst,
    ) -> Option<CandWindow> {
        while let Some(front) = self.q.front() {
            let budget = front.deadline.saturating_sub(now.saturating_add(net_bound));
            if profile.max_batch_within(budget) == 0 {
                dropped.push(self.q.pop_front().unwrap());
            } else {
                break;
            }
        }
        let front = self.q.front()?;
        let budget = front.deadline.saturating_sub(now.saturating_add(net_bound));
        let b = (profile.max_batch_within(budget) as usize).min(self.q.len()) as u32;
        let d = front.deadline;
        let frontrun = d.saturating_sub(profile.latency(b + 1).saturating_add(net_bound));
        let latest = d.saturating_sub(profile.latency(b).saturating_add(net_bound));
        Some(CandWindow {
            exec: frontrun.max(now),
            latest,
            size: b,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::types::RequestId;

    fn req(id: u64, arrival: Micros, deadline: Micros) -> Request {
        Request {
            id: RequestId(id),
            model: ModelId(0),
            arrival,
            deadline,
        }
    }

    #[test]
    fn tracking_queue_window_math() {
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = TrackingQueue::new();
        for i in 0..4 {
            q.push(req(
                i,
                Micros::from_millis_f64(0.75 * i as f64),
                Micros::from_millis_f64(12.0 + 0.75 * i as f64),
            ));
        }
        let mut dropped = ReqBurst::new();
        let cand = q.candidate(&p, Micros::from_millis_f64(2.25), Micros::ZERO, &mut dropped);
        assert!(dropped.is_empty());
        let c = cand.unwrap();
        assert_eq!(c.size, 4);
        // frontrun = 12 - ℓ(5) = 2 < now -> exec = now = 2.25ms.
        assert_eq!(c.exec, Micros::from_millis_f64(2.25));
        assert_eq!(c.latest, Micros::from_millis_f64(3.0));
    }

    /// Regression: an out-of-order (earlier-deadline) delivery must
    /// become the head so the window is budgeted against it.
    #[test]
    fn tracking_queue_out_of_order_insert_sorts() {
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = TrackingQueue::new();
        q.push(req(0, Micros::ZERO, Micros::from_millis_f64(50.0)));
        q.push(req(1, Micros::ZERO, Micros::from_millis_f64(20.0)));
        let mut dropped = ReqBurst::new();
        let cand = q.candidate(&p, Micros::ZERO, Micros::ZERO, &mut dropped);
        assert!(dropped.is_empty());
        let c = cand.unwrap();
        // Window budgeted against the 20 ms head, not the 50 ms one.
        assert_eq!(c.latest, Micros::from_millis_f64(20.0 - 7.0));
        let taken = q.take_burst(2);
        assert_eq!(taken[0].id, RequestId(1));
        assert_eq!(taken[1].id, RequestId(0));
    }

    #[test]
    fn tracking_queue_drops_expired() {
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = TrackingQueue::new();
        q.push(req(0, Micros::ZERO, Micros::from_millis_f64(5.0)));
        q.push(req(1, Micros::ZERO, Micros::from_millis_f64(50.0)));
        let mut dropped = ReqBurst::new();
        let cand = q.candidate(&p, Micros::from_millis_f64(1.0), Micros::ZERO, &mut dropped);
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].id, RequestId(0));
        assert_eq!(cand.unwrap().size, 1);
    }

    /// `take_burst` caps at the queue length and drains front-first.
    #[test]
    fn take_burst_pops_prefix() {
        let mut q = TrackingQueue::new();
        for i in 0..3 {
            q.push(req(i, Micros::ZERO, Micros(1_000 + i)));
        }
        let b = q.take_burst(10);
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].id, RequestId(0));
        assert!(q.take_burst(1).is_empty());
    }
}
