//! ModelThread (§4.2, Fig 18): one thread per model. "It accesses only
//! model-local information and updates the candidate. The candidate is
//! then sent to the RankThread." On "GPU Granted" it finalizes the batch
//! and sends it to the backend immediately.
//!
//! With the sharded coordinator the ModelThread talks to the rank
//! shards through a [`RankRouter`]: candidate updates go to whichever
//! shard currently holds the registration, `Overflow` verdicts migrate
//! the candidate to a shard with free capacity, and a grant or
//! revalidation resets the registration to the home shard.

use std::sync::mpsc::{Receiver, Sender};

use crate::coordinator::clock::Clock;
use crate::coordinator::messages::{CandWindow, Completion, ToBackend, ToModel};
use crate::coordinator::router::RankRouter;
use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;
use crate::core::types::{ModelId, Request};

pub struct ModelThread {
    pub model: ModelId,
    pub profile: LatencyProfile,
    pub clock: Clock,
    pub inbox: Receiver<ToModel>,
    /// Routing handle to the rank shards.
    pub router: RankRouter,
    /// One channel per GPU backend worker.
    pub backends: Vec<Sender<ToBackend>>,
    pub completions: Sender<Completion>,
    /// Network-delay budget (§5.6).
    pub net_bound: Micros,
    /// Dispatch-overhead margin added to the busy estimate sent to the
    /// rank shard (keeps real execution from overrunning its slot).
    pub exec_margin: Micros,
}

impl ModelThread {
    /// Run until `Shutdown`. Returns the number of requests processed.
    pub fn run(self) -> u64 {
        let ModelThread {
            model,
            profile,
            clock,
            inbox,
            mut router,
            backends,
            completions,
            net_bound,
            exec_margin,
        } = self;
        // Track requests by id so drops can report full `Request`s.
        let mut queue = TrackingQueue::new();
        let mut processed = 0u64;
        // Overflow migrations of the current logical candidate.
        let mut hops = 0u32;

        let compute = |queue: &mut TrackingQueue,
                       completions: &Sender<Completion>,
                       now: Micros|
         -> Option<CandWindow> {
            let (cand, dropped) = queue.candidate(&profile, now, net_bound);
            if !dropped.is_empty() {
                let _ = completions.send(Completion::Dropped(dropped));
            }
            cand
        };

        while let Ok(msg) = inbox.recv() {
            match msg {
                ToModel::Request(r) => {
                    processed += 1;
                    queue.push(r);
                    let cand = compute(&mut queue, &completions, clock.now());
                    // An emptied queue ends the logical candidate: reset
                    // the migration budget so the next one starts fresh
                    // at the home shard instead of inheriting exhausted
                    // hops on a stale overflow shard.
                    if cand.is_none() {
                        hops = 0;
                        if router.register_home(None).is_err() {
                            break;
                        }
                        continue;
                    }
                    // Replace in place: a steered candidate stays at its
                    // current shard (re-homing on every request would
                    // thrash under sustained overflow).
                    if router.register_current(cand, hops).is_err() {
                        break;
                    }
                }
                ToModel::Granted { gpu } => {
                    // The shard consumed the registration at grant time:
                    // the router must not coalesce the next one away.
                    router.invalidate_last_sent();
                    let now = clock.now();
                    let cand = compute(&mut queue, &completions, now);
                    if let Some(c) = cand {
                        let batch = queue.take(c.size as usize);
                        let busy_until = now + profile.latency(c.size) + exec_margin;
                        let _ = backends[gpu.0 as usize].send(ToBackend::Execute {
                            model,
                            requests: batch,
                            dispatched_at: now,
                        });
                        let _ = router.gpu_busy_until(gpu, busy_until);
                    } else {
                        // Nothing left to run; hand the GPU back as free.
                        let _ = router.gpu_busy_until(gpu, now);
                    }
                    // Register the next candidate — a fresh logical
                    // candidate, so it starts back at the home shard.
                    hops = 0;
                    let cand = compute(&mut queue, &completions, clock.now());
                    if router.register_home(cand).is_err() {
                        break;
                    }
                }
                ToModel::Revalidate => {
                    // Expiry revalidation: the shard dropped the
                    // registration before sending this.
                    router.invalidate_last_sent();
                    hops = 0;
                    let cand = compute(&mut queue, &completions, clock.now());
                    if router.register_home(cand).is_err() {
                        break;
                    }
                }
                ToModel::Overflow { to_shard, seq } => {
                    // Stale verdicts (the candidate was replaced since
                    // that registration) are ignored.
                    if !router.overflow_is_current(seq) {
                        continue;
                    }
                    // The steering shard unregistered the candidate
                    // before sending the verdict.
                    router.invalidate_last_sent();
                    let cand = compute(&mut queue, &completions, clock.now());
                    // The recompute can empty the queue: that ends the
                    // logical candidate, so reset the migration budget
                    // and re-home (same invariant as the Request arm).
                    if cand.is_none() {
                        hops = 0;
                        if router.register_home(None).is_err() {
                            break;
                        }
                        continue;
                    }
                    hops += 1;
                    if router.register_overflow(to_shard, cand, hops).is_err() {
                        break;
                    }
                }
                ToModel::Shutdown => break,
            }
        }
        processed
    }
}

/// A deadline-ordered queue that returns full `Request`s for drops (the
/// sim-side `ModelQueue` only tracks ids).
struct TrackingQueue {
    q: std::collections::VecDeque<Request>,
}

impl TrackingQueue {
    fn new() -> Self {
        TrackingQueue {
            q: std::collections::VecDeque::new(),
        }
    }

    /// Insert preserving deadline order (same contract as the sim-side
    /// `ModelQueue::push`): `candidate` budgets the whole batch against
    /// `q.front().deadline`, so an out-of-order delivery — frontend
    /// clock skew, a per-request SLO override — must insert-sort, not
    /// silently hide an earlier deadline behind the head. In-order
    /// arrival stays O(1).
    fn push(&mut self, r: Request) {
        let mut i = self.q.len();
        while i > 0 && self.q[i - 1].deadline > r.deadline {
            i -= 1;
        }
        if i == self.q.len() {
            self.q.push_back(r);
        } else {
            self.q.insert(i, r);
        }
    }

    fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n.min(self.q.len()))
            .map(|_| self.q.pop_front().unwrap())
            .collect()
    }

    /// Drop hopeless heads, then compute the candidate window.
    fn candidate(
        &mut self,
        profile: &LatencyProfile,
        now: Micros,
        net_bound: Micros,
    ) -> (Option<CandWindow>, Vec<Request>) {
        let mut dropped = Vec::new();
        while let Some(front) = self.q.front() {
            let budget = front.deadline.saturating_sub(now + net_bound);
            if profile.max_batch_within(budget) == 0 {
                dropped.push(self.q.pop_front().unwrap());
            } else {
                break;
            }
        }
        let Some(front) = self.q.front() else {
            return (None, dropped);
        };
        let budget = front.deadline.saturating_sub(now + net_bound);
        let b = (profile.max_batch_within(budget) as usize).min(self.q.len()) as u32;
        let d = front.deadline;
        let frontrun = d.saturating_sub(profile.latency(b + 1) + net_bound);
        let latest = d.saturating_sub(profile.latency(b) + net_bound);
        (
            Some(CandWindow {
                exec: frontrun.max(now),
                latest,
                size: b,
            }),
            dropped,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::types::RequestId;

    fn req(id: u64, arrival: Micros, deadline: Micros) -> Request {
        Request {
            id: RequestId(id),
            model: ModelId(0),
            arrival,
            deadline,
        }
    }

    #[test]
    fn tracking_queue_window_math() {
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = TrackingQueue::new();
        for i in 0..4 {
            q.push(req(
                i,
                Micros::from_millis_f64(0.75 * i as f64),
                Micros::from_millis_f64(12.0 + 0.75 * i as f64),
            ));
        }
        let (cand, dropped) = q.candidate(&p, Micros::from_millis_f64(2.25), Micros::ZERO);
        assert!(dropped.is_empty());
        let c = cand.unwrap();
        assert_eq!(c.size, 4);
        // frontrun = 12 - ℓ(5) = 2 < now -> exec = now = 2.25ms.
        assert_eq!(c.exec, Micros::from_millis_f64(2.25));
        assert_eq!(c.latest, Micros::from_millis_f64(3.0));
    }

    /// Regression: an out-of-order (earlier-deadline) delivery must
    /// become the head so the window is budgeted against it.
    #[test]
    fn tracking_queue_out_of_order_insert_sorts() {
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = TrackingQueue::new();
        q.push(req(0, Micros::ZERO, Micros::from_millis_f64(50.0)));
        q.push(req(1, Micros::ZERO, Micros::from_millis_f64(20.0)));
        let (cand, dropped) = q.candidate(&p, Micros::ZERO, Micros::ZERO);
        assert!(dropped.is_empty());
        let c = cand.unwrap();
        // Window budgeted against the 20 ms head, not the 50 ms one.
        assert_eq!(c.latest, Micros::from_millis_f64(20.0 - 7.0));
        let taken = q.take(2);
        assert_eq!(taken[0].id, RequestId(1));
        assert_eq!(taken[1].id, RequestId(0));
    }

    #[test]
    fn tracking_queue_drops_expired() {
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = TrackingQueue::new();
        q.push(req(0, Micros::ZERO, Micros::from_millis_f64(5.0)));
        q.push(req(1, Micros::ZERO, Micros::from_millis_f64(50.0)));
        let (cand, dropped) = q.candidate(&p, Micros::from_millis_f64(1.0), Micros::ZERO);
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].id, RequestId(0));
        assert_eq!(cand.unwrap().size, 1);
    }
}
