//! RankShard (§4.2, Fig 18, sharded): one of `R` rank threads, each
//! owning a contiguous [`GpuId`] range and its own inbox. A shard runs
//! the same batch-granularity state machine the paper's single
//! RankThread runs — GPU free timers, model candidate timers,
//! model-GPU matchmaking — over its own GPU range only, so the
//! batch-rate matchmaking work parallelizes across cores instead of
//! saturating one.
//!
//! Cross-shard coordination is deliberately thin (batch-rate, not
//! request-rate): each shard publishes its free-GPU count through
//! [`FreeHints`]; a shard whose ready candidates outnumber its free
//! GPUs steers the overflow to the **lowest** shard advertising spare
//! capacity (via `ToModel::Overflow`, keeping the ModelThread the
//! single authority for its candidate). Scanning hints from shard 0
//! upward preserves the global consolidation order — shard 0's lowest
//! GPU ids fill first, so the autoscaler can still reclaim high-id
//! GPUs from the top of the id space.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::clock::Clock;
use crate::coordinator::messages::{CandWindow, ToModel, ToRank};
use crate::coordinator::router::FreeHints;
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId};
use crate::obs::trace::{self, Stage};
use crate::util::ring::{RecvTimeoutError, RingReceiver, RingSender, TryRecvError};
use crate::util::stats::Histogram;

/// Idle wake-up cap: bounds staleness of cross-shard free hints when no
/// messages arrive.
const MAX_IDLE: Duration = Duration::from_millis(50);
/// Faster poll while GPU-starved with parked candidates, so a sibling
/// shard's freed GPU is noticed promptly.
const STARVED_IDLE: Duration = Duration::from_millis(1);
/// Grant-latency histogram cap (µs); latencies above this clamp.
const LAT_CAP_US: u64 = 1_000_000;
/// Grant-latency histogram bucket width (µs): `util::stats::Histogram`
/// is a dense integer-bucket vector, so raw-µs buckets would cost up to
/// 8 MB per shard; 10 µs granularity bounds it to ~100 kB.
const LAT_BUCKET_US: u64 = 10;

/// Scrape-visible per-shard counters, shared between a running shard
/// and the `/metrics` exposition (the end-of-run [`ShardStats`] are
/// only available at shutdown). Published once per drain pass —
/// batch-rate, not per-grant.
#[derive(Debug, Default)]
pub struct ShardLive {
    pub grants: AtomicU64,
    pub mis_steers: AtomicU64,
}

impl ShardLive {
    pub fn grants(&self) -> u64 {
        // relaxed: advisory scrape counter, no payload rides on it.
        self.grants.load(Ordering::Relaxed)
    }

    pub fn mis_steers(&self) -> u64 {
        // relaxed: advisory scrape counter, no payload rides on it.
        self.mis_steers.load(Ordering::Relaxed)
    }
}

/// What one shard did over its lifetime.
#[derive(Clone, Debug)]
pub struct ShardStats {
    pub grants: u64,
    /// Mis-steers: an `Overflow`-routed candidate (`hops > 0`) arrived
    /// at this shard when it had no free GPU — the steering shard's
    /// free hint was stale. The ROADMAP's "measure mis-steer rates"
    /// item; surfaced in the fig13 scalability report.
    pub mis_steers: u64,
    /// Inbox-ring high-watermark occupancy (max across merged shards):
    /// how close the control-traffic ring came to its bound.
    pub inbox_hwm: u64,
    /// Histogram of grant latency in `LAT_BUCKET_US`-µs buckets: how
    /// long a candidate's window had been open (past `exec`) when the
    /// GPU was granted.
    pub grant_lat: Histogram,
}

impl ShardStats {
    pub fn new() -> Self {
        ShardStats {
            grants: 0,
            mis_steers: 0,
            inbox_hwm: 0,
            grant_lat: Histogram::new(),
        }
    }

    pub fn merge(&mut self, other: &ShardStats) {
        self.grants += other.grants;
        self.mis_steers += other.mis_steers;
        self.inbox_hwm = self.inbox_hwm.max(other.inbox_hwm);
        self.grant_lat.merge(&other.grant_lat);
    }

    /// p99 grant latency in µs, at bucket granularity (0 when no grants).
    pub fn p99_grant_latency_us(&self) -> usize {
        // lint:allow(float-free-hot-path): end-of-session stats reporting,
        // not the per-candidate serving path.
        self.grant_lat.quantile(0.99) * LAT_BUCKET_US as usize
    }
}

impl Default for ShardStats {
    fn default() -> Self {
        ShardStats::new()
    }
}

/// A registered candidate plus its routing metadata.
#[derive(Clone, Copy, Debug)]
struct CandState {
    win: CandWindow,
    /// ModelThread registration counter, echoed in `Overflow`.
    seq: u64,
    /// Overflow migrations this logical candidate has done.
    hops: u32,
}

#[derive(PartialEq, Eq)]
enum Flow {
    Continue,
    Shutdown,
}

struct State {
    /// This shard's GPU id range.
    gpus: std::ops::Range<u32>,
    /// Candidates registered by ModelThreads.
    cands: BTreeMap<ModelId, CandState>,
    /// Candidates whose exec has passed, by urgency: (latest, model).
    ready: BTreeSet<(Micros, ModelId)>,
    /// Candidates waiting for their exec moment: (exec, model).
    pending: BTreeSet<(Micros, ModelId)>,
    /// GPUs free right now (min id first — consolidation).
    free: BTreeSet<GpuId>,
    /// GPUs that will free at a known time: (free_at, gpu).
    busy: BTreeSet<(Micros, GpuId)>,
    /// Leased to a ModelThread, waiting for its GpuBusyUntil.
    leased: BTreeSet<GpuId>,
    /// Draining (§3.5 retire protocol): out of the free set — so never
    /// granted or advertised again — but still finishing an in-flight
    /// batch (`busy`) or an outstanding lease. The ack fires when the
    /// GPU becomes provably idle, at which point it moves to `detached`.
    draining: HashMap<GpuId, Sender<GpuId>>,
    /// Retired / not-yet-attached GPUs: owned ids that take no part in
    /// matchmaking until an `Attach` re-activates them.
    detached: BTreeSet<GpuId>,
}

impl State {
    /// `active` is the sub-range of `gpus` that starts attached; the
    /// rest of the owned ids begin detached (cluster capacity the
    /// autoscaler may activate later).
    fn new(gpus: std::ops::Range<u32>, active: std::ops::Range<u32>) -> Self {
        State {
            free: active.clone().map(GpuId).collect(),
            detached: gpus.clone().filter(|g| !active.contains(g)).map(GpuId).collect(),
            gpus,
            cands: BTreeMap::new(),
            ready: BTreeSet::new(),
            pending: BTreeSet::new(),
            busy: BTreeSet::new(),
            leased: BTreeSet::new(),
            draining: HashMap::new(),
        }
    }

    fn unregister(&mut self, m: ModelId) {
        if let Some(old) = self.cands.remove(&m) {
            self.ready.remove(&(old.win.latest, m));
            self.pending.remove(&(old.win.exec, m));
        }
    }

    /// Retire `gpu` and tell the autoscaler it is now provably idle.
    fn detach_and_ack(&mut self, gpu: GpuId, ack: Sender<GpuId>) {
        self.detached.insert(gpu);
        let _ = ack.send(gpu);
    }

    /// The single message-application code path (shared by the drain
    /// loop and the `recv_timeout` arm).
    fn apply(&mut self, msg: ToRank, now: Micros, stats: &mut ShardStats) -> Flow {
        match msg {
            ToRank::Candidate {
                model,
                cand,
                seq,
                hops,
            } => {
                // Overflow-routed candidate landing on a shard with no
                // free GPU: the steering hint was stale (ROADMAP's
                // mis-steer measurement). Only the *arrival* of a
                // steered candidate counts — its later in-place window
                // updates carry the same `hops`, and an existing
                // registration with those hops means this steering
                // event was already scored.
                if hops > 0
                    && cand.is_some()
                    && self.free.is_empty()
                    && self.cands.get(&model).map(|c| c.hops) != Some(hops)
                {
                    stats.mis_steers += 1;
                }
                self.unregister(model);
                if let Some(win) = cand {
                    self.cands.insert(model, CandState { win, seq, hops });
                    self.pending.insert((win.exec, model));
                }
            }
            ToRank::GpuBusyUntil { gpu, free_at } => {
                if !self.gpus.contains(&gpu.0) {
                    debug_assert!(false, "misrouted GpuBusyUntil for {gpu:?}");
                    return Flow::Continue;
                }
                debug_assert!(
                    !self.detached.contains(&gpu),
                    "GpuBusyUntil for detached {gpu:?}"
                );
                self.leased.remove(&gpu);
                self.free.remove(&gpu);
                self.busy.retain(|&(_, g)| g != gpu);
                if free_at <= now {
                    // A draining GPU that just went idle retires instead
                    // of rejoining the free set.
                    if let Some(ack) = self.draining.remove(&gpu) {
                        self.detach_and_ack(gpu, ack);
                    } else {
                        self.free.insert(gpu);
                    }
                } else {
                    // Still mid-batch: the GPU-timer promotion path
                    // completes the drain at free_at.
                    self.busy.insert((free_at, gpu));
                }
            }
            ToRank::Drain { gpu, ack } => {
                if !self.gpus.contains(&gpu.0) {
                    debug_assert!(false, "misrouted Drain for {gpu:?}");
                    return Flow::Continue;
                }
                if self.detached.contains(&gpu) {
                    // Idempotent: already retired.
                    let _ = ack.send(gpu);
                } else if self.free.remove(&gpu) {
                    // Idle right now: retire immediately.
                    self.detach_and_ack(gpu, ack);
                } else {
                    // Busy or leased: no new grants from this moment
                    // (it is out of `free`); retire when the in-flight
                    // batch or lease resolves.
                    self.draining.insert(gpu, ack);
                }
            }
            ToRank::Attach { gpu } => {
                if !self.gpus.contains(&gpu.0) {
                    debug_assert!(false, "misrouted Attach for {gpu:?}");
                    return Flow::Continue;
                }
                if self.detached.remove(&gpu) {
                    self.free.insert(gpu);
                } else {
                    // Attaching a draining GPU cancels the drain (its
                    // ack will never fire — callers attach only
                    // detached ids); attaching an active GPU is a no-op.
                    self.draining.remove(&gpu);
                }
            }
            ToRank::Shutdown => return Flow::Shutdown,
        }
        Flow::Continue
    }

    fn next_wakeup(&self) -> Option<Micros> {
        let exec = self.pending.iter().next().map(|&(t, _)| t);
        let gpu = self.busy.iter().next().map(|&(t, _)| t);
        // Parked candidates need a wake just past expiry to revalidate
        // (`saturating_add`: a ~u64::MAX `latest` must not wrap to 0).
        let expiry = self
            .ready
            .iter()
            .next()
            .map(|&(t, _)| t.saturating_add(Micros(1)));
        [exec, gpu, expiry].into_iter().flatten().min()
    }
}

/// Latest-wins coalescing of a drained inbox burst (the ROADMAP's
/// "shard-local batching of `GpuBusyUntil` traffic"): a burst collapses
/// to at most one candidate registration per model and one busy-until
/// per GPU before the BTree state is touched, so a shard receiving
/// request-rate traffic pays batch-rate bookkeeping. Per-sender message
/// order is preserved by keeping only the newest message per key;
/// messages for different keys touch disjoint state, so application
/// order across keys is irrelevant. The maps are reused across drains —
/// steady-state batching does not allocate.
#[derive(Default)]
struct InboxBatch {
    cands: HashMap<ModelId, (Option<CandWindow>, u64, u32)>,
    busy: HashMap<GpuId, Micros>,
    /// Drain/Attach are control-rate, not request-rate: applied in
    /// arrival order (a `Drain` followed by an `Attach` of the same GPU
    /// must not collapse), after the busy updates they may depend on.
    ctrl: Vec<ToRank>,
    shutdown: bool,
}

impl InboxBatch {
    fn absorb(&mut self, msg: ToRank) {
        match msg {
            ToRank::Candidate {
                model,
                cand,
                seq,
                hops,
            } => {
                self.cands.insert(model, (cand, seq, hops));
            }
            ToRank::GpuBusyUntil { gpu, free_at } => {
                self.busy.insert(gpu, free_at);
            }
            msg @ (ToRank::Drain { .. } | ToRank::Attach { .. }) => self.ctrl.push(msg),
            ToRank::Shutdown => self.shutdown = true,
        }
    }

    fn flush(
        &mut self,
        st: &mut State,
        now: Micros,
        stats: &mut ShardStats,
        hints: &FreeHints,
        shard: usize,
    ) {
        // Busy updates first: they touch state disjoint from the
        // candidate sets, but applying them before the candidates keeps
        // the mis-steer check honest about free/busy transitions that
        // arrived earlier in the same burst.
        for (gpu, free_at) in self.busy.drain() {
            let _ = st.apply(ToRank::GpuBusyUntil { gpu, free_at }, now, stats);
        }
        for (model, (cand, seq, hops)) in self.cands.drain() {
            // A steered candidate's arrival consumes the reservation its
            // steering shard took against this shard's hint (same
            // arrival test as the mis-steer counter in `State::apply`:
            // in-place updates of an already-arrived migrant carry the
            // same `hops` and must not redeem again).
            if hops > 0
                && cand.is_some()
                && st.cands.get(&model).map(|c| c.hops) != Some(hops)
            {
                hints.redeem(shard);
            }
            let _ = st.apply(
                ToRank::Candidate {
                    model,
                    cand,
                    seq,
                    hops,
                },
                now,
                stats,
            );
        }
        for msg in self.ctrl.drain(..) {
            let _ = st.apply(msg, now, stats);
        }
    }
}

pub struct RankShard {
    pub clock: Clock,
    /// This shard's index in the topology.
    pub shard: usize,
    pub inbox: RingReceiver<ToRank>,
    pub model_txs: Vec<RingSender<ToModel>>,
    /// Contiguous GPU id range this shard owns.
    pub gpus: std::ops::Range<u32>,
    /// The sub-range of `gpus` attached at start; the rest begin
    /// detached (autoscaler headroom).
    pub active: std::ops::Range<u32>,
    /// Shared free-GPU counters for overflow steering.
    pub hints: FreeHints,
    /// Scrape-visible counters (see [`ShardLive`]); the spawner keeps
    /// the other end for `/metrics`.
    pub live: Arc<ShardLive>,
}

impl RankShard {
    pub fn run(self) -> ShardStats {
        let RankShard {
            clock,
            shard,
            inbox,
            model_txs,
            gpus,
            active,
            hints,
            live,
        } = self;
        let num_shards = hints.num_shards();
        let mut st = State::new(gpus, active);
        let mut stats = ShardStats::new();
        let mut batch = InboxBatch::default();
        hints.publish(shard, st.free.len());

        'outer: loop {
            // 1. Drain the mailbox into the latest-wins batch, then
            //    apply the net effect through the single `apply` path.
            loop {
                match inbox.try_recv() {
                    Ok(msg) => batch.absorb(msg),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break 'outer,
                }
            }
            if batch.shutdown {
                break 'outer;
            }
            batch.flush(&mut st, clock.now(), &mut stats, &hints, shard);

            let now = clock.now();

            // 2. GPU timers: promote GPUs whose free_at has passed.
            //    A draining GPU's last batch just finished: retire it
            //    instead of re-freeing it.
            while let Some(&(t, gpu)) = st.busy.iter().next() {
                if t > now {
                    break;
                }
                st.busy.remove(&(t, gpu));
                if let Some(ack) = st.draining.remove(&gpu) {
                    st.detach_and_ack(gpu, ack);
                } else {
                    st.free.insert(gpu);
                }
            }

            // 3. Model timers. Expiry is checked *at promotion*: a
            //    candidate whose window already closed is sent straight
            //    back for revalidation instead of taking a pointless
            //    ready-insert + unregister + Revalidate round trip.
            let mut revalidate: Vec<ModelId> = Vec::new();
            while let Some(&(t, m)) = st.pending.iter().next() {
                if t > now {
                    break;
                }
                st.pending.remove(&(t, m));
                let win = st.cands[&m].win;
                if win.latest < now {
                    st.cands.remove(&m);
                    revalidate.push(m);
                } else {
                    st.ready.insert((win.latest, m));
                }
            }
            // Parked candidates whose window closed while waiting for a
            // GPU also revalidate (the single-rank code left them in the
            // ready set until a GPU happened to free).
            while let Some(&(latest, m)) = st.ready.iter().next() {
                if latest >= now {
                    break;
                }
                st.ready.remove(&(latest, m));
                st.cands.remove(&m);
                revalidate.push(m);
            }
            for m in revalidate {
                if model_txs[m.0 as usize]
                    .send(ToModel::Revalidate { model: m })
                    .is_err()
                {
                    break 'outer;
                }
            }

            // 4. Matchmaking: most urgent ready candidate × min-id free
            //    GPU (equivalent to processing the timers in time order
            //    at this instant; expired entries were purged above).
            while !st.free.is_empty() {
                let Some(&(latest, m)) = st.ready.iter().next() else {
                    break;
                };
                let gpu = *st.free.iter().next().unwrap();
                st.free.remove(&gpu);
                st.leased.insert(gpu);
                let cs = st.cands.remove(&m).expect("ready candidate registered");
                st.ready.remove(&(latest, m));
                st.pending.remove(&(cs.win.exec, m));
                stats.grants += 1;
                trace::model_event(Stage::RankGrant, m);
                let waited = now.saturating_sub(cs.win.exec);
                stats
                    .grant_lat
                    .add((waited.0.min(LAT_CAP_US) / LAT_BUCKET_US) as usize);
                if model_txs[m.0 as usize]
                    .send(ToModel::Granted { model: m, gpu })
                    .is_err()
                {
                    break 'outer;
                }
            }

            hints.publish(shard, st.free.len());
            // relaxed: advisory scrape counters, published once per pass.
            live.grants.store(stats.grants, Ordering::Relaxed);
            live.mis_steers.store(stats.mis_steers, Ordering::Relaxed);

            // 5. Overflow steering: GPU-starved candidates migrate to
            //    the lowest sibling shard advertising free capacity
            //    (consolidation order — shard 0 fills first). A
            //    candidate that has already migrated `num_shards` times
            //    parks here until it is granted or expires. Targets are
            //    *reserved*, not merely read: `FreeHints::reserve`
            //    atomically decrements the advertised count, so two
            //    starved shards steering concurrently cannot both aim
            //    a candidate at the same free GPU — the reservation
            //    satellite that cuts the mis-steer rate the fig13
            //    table measures. The target's own republish *merges*
            //    with outstanding reservations (and the migrant's
            //    arrival redeems them in `InboxBatch::flush`), so a
            //    publish interval can no longer resurrect a slot whose
            //    candidate is still in flight.
            if st.free.is_empty() && !st.ready.is_empty() && num_shards > 1 {
                let mut steer: Vec<(ModelId, usize, u64)> = Vec::new();
                for &(_, m) in st.ready.iter() {
                    let cs = &st.cands[&m];
                    if cs.hops as usize >= num_shards {
                        continue;
                    }
                    let Some(t) = (0..num_shards).find(|&s| s != shard && hints.reserve(s)) else {
                        break;
                    };
                    steer.push((m, t, cs.seq));
                }
                for (m, to_shard, seq) in steer {
                    st.unregister(m);
                    let msg = ToModel::Overflow {
                        model: m,
                        to_shard,
                        seq,
                    };
                    if model_txs[m.0 as usize].send(msg).is_err() {
                        break 'outer;
                    }
                }
            }

            // 6. Sleep until the next timer or message. The ring's
            //    `recv_timeout` is the shared adaptive drain: spin →
            //    yield → park (or pure spin under `--busy-poll`). The
            //    fast starved-poll exists only to re-read sibling free
            //    hints, so a single-shard tier never uses it.
            let idle_cap = if num_shards > 1 && st.free.is_empty() && !st.ready.is_empty() {
                STARVED_IDLE
            } else {
                MAX_IDLE
            };
            let timeout = match st.next_wakeup() {
                Some(t) => clock.until(t).min(idle_cap),
                None => idle_cap,
            };
            match inbox.recv_timeout(timeout) {
                // Absorbed only: the loop top keeps draining the burst
                // this message may be the head of, then flushes once.
                Ok(msg) => batch.absorb(msg),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break 'outer,
            }
        }
        // Stop attracting overflow traffic once this shard is gone.
        hints.publish(shard, 0);
        stats.inbox_hwm = inbox.high_watermark() as u64;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::IDLE_RECV_TIMEOUT;
    use crate::util::ring::ring;
    use std::sync::mpsc::channel;

    fn spawn_shard(
        shard: usize,
        gpus: std::ops::Range<u32>,
        hints: FreeHints,
        n_models: usize,
    ) -> (
        Clock,
        RingSender<ToRank>,
        Vec<RingReceiver<ToModel>>,
        std::thread::JoinHandle<ShardStats>,
    ) {
        let clock = Clock::new();
        let (rank_tx, rank_rx) = ring::<ToRank>(64);
        let mut model_txs = Vec::new();
        let mut model_rxs = Vec::new();
        for _ in 0..n_models {
            let (tx, rx) = ring::<ToModel>(64);
            model_txs.push(tx);
            model_rxs.push(rx);
        }
        let rs = RankShard {
            clock,
            shard,
            inbox: rank_rx,
            model_txs,
            active: gpus.clone(),
            gpus,
            hints,
            live: Arc::new(ShardLive::default()),
        };
        let h = std::thread::spawn(move || rs.run());
        (clock, rank_tx, model_rxs, h)
    }

    fn ms(v: f64) -> Micros {
        Micros::from_millis_f64(v)
    }

    /// Regression (stale-candidate promotion): an expired candidate must
    /// be revalidated even when the shard has no free GPU — the old
    /// single-rank loop only noticed expiry during matchmaking, so a
    /// GPU-less shard never sent Revalidate.
    #[test]
    fn expired_candidate_revalidates_without_free_gpu() {
        let hints = FreeHints::new(1);
        let (_clock, rank_tx, model_rxs, h) = spawn_shard(0, 0..0, hints, 1);
        rank_tx
            .send(ToRank::Candidate {
                model: ModelId(0),
                cand: Some(CandWindow {
                    exec: Micros(0),
                    latest: Micros(0),
                    size: 1,
                }),
                seq: 1,
                hops: 0,
            })
            .unwrap();
        let msg = model_rxs[0]
            .recv_timeout(IDLE_RECV_TIMEOUT)
            .expect("revalidate sent");
        assert!(matches!(msg, ToModel::Revalidate { .. }), "{msg:?}");
        rank_tx.send(ToRank::Shutdown).unwrap();
        let stats = h.join().unwrap();
        assert_eq!(stats.grants, 0, "expired candidate must not be granted");
    }

    /// A live candidate on a shard with a free GPU is granted the
    /// lowest id; the lease blocks a second grant until GpuBusyUntil.
    #[test]
    fn grants_min_id_and_respects_lease() {
        let hints = FreeHints::new(1);
        let (clock, rank_tx, model_rxs, h) = spawn_shard(0, 4..6, hints, 2);
        let far = clock.now() + ms(500.0);
        rank_tx
            .send(ToRank::Candidate {
                model: ModelId(0),
                cand: Some(CandWindow {
                    exec: Micros(0),
                    latest: far,
                    size: 1,
                }),
                seq: 1,
                hops: 0,
            })
            .unwrap();
        let msg = model_rxs[0]
            .recv_timeout(IDLE_RECV_TIMEOUT)
            .expect("granted");
        assert!(
            matches!(msg, ToModel::Granted { gpu: GpuId(4), .. }),
            "lowest owned id: {msg:?}"
        );
        // Occupy the granted GPU, register a second model: it must get
        // the *other* GPU, not the leased one.
        rank_tx
            .send(ToRank::GpuBusyUntil {
                gpu: GpuId(4),
                free_at: far,
            })
            .unwrap();
        rank_tx
            .send(ToRank::Candidate {
                model: ModelId(1),
                cand: Some(CandWindow {
                    exec: Micros(0),
                    latest: far,
                    size: 1,
                }),
                seq: 1,
                hops: 0,
            })
            .unwrap();
        let msg = model_rxs[1]
            .recv_timeout(IDLE_RECV_TIMEOUT)
            .expect("granted second gpu");
        assert!(matches!(msg, ToModel::Granted { gpu: GpuId(5), .. }), "{msg:?}");
        rank_tx.send(ToRank::Shutdown).unwrap();
        let stats = h.join().unwrap();
        assert_eq!(stats.grants, 2);
    }

    /// A GPU-starved shard steers a ready candidate toward the lowest
    /// sibling shard advertising free capacity.
    #[test]
    fn starved_shard_overflows_to_advertised_sibling() {
        let hints = FreeHints::new(2);
        // Shard 1 exists only as a hint here: pretend it has capacity.
        hints.publish(1, 3);
        let (clock, rank_tx, model_rxs, h) = spawn_shard(0, 0..1, hints, 1);
        let far = clock.now() + ms(500.0);
        // Occupy shard 0's only GPU, then register a candidate.
        rank_tx
            .send(ToRank::GpuBusyUntil {
                gpu: GpuId(0),
                free_at: far,
            })
            .unwrap();
        rank_tx
            .send(ToRank::Candidate {
                model: ModelId(0),
                cand: Some(CandWindow {
                    exec: Micros(0),
                    latest: far,
                    size: 1,
                }),
                seq: 7,
                hops: 0,
            })
            .unwrap();
        let msg = model_rxs[0]
            .recv_timeout(IDLE_RECV_TIMEOUT)
            .expect("overflow verdict");
        assert!(
            matches!(msg, ToModel::Overflow { to_shard: 1, seq: 7, .. }),
            "{msg:?}"
        );
        rank_tx.send(ToRank::Shutdown).unwrap();
        let stats = h.join().unwrap();
        assert_eq!(stats.grants, 0);
    }

    /// A candidate that has exhausted its migration budget parks
    /// instead of bouncing, and is granted once the local GPU frees.
    #[test]
    fn exhausted_hops_park_until_local_gpu_frees() {
        let hints = FreeHints::new(2);
        hints.publish(1, 1); // tempting, but hops are exhausted
        let (clock, rank_tx, model_rxs, h) = spawn_shard(0, 0..1, hints, 1);
        let soon = clock.now() + ms(30.0);
        let far = clock.now() + ms(500.0);
        rank_tx
            .send(ToRank::GpuBusyUntil {
                gpu: GpuId(0),
                free_at: soon,
            })
            .unwrap();
        rank_tx
            .send(ToRank::Candidate {
                model: ModelId(0),
                cand: Some(CandWindow {
                    exec: Micros(0),
                    latest: far,
                    size: 1,
                }),
                seq: 1,
                hops: 2, // >= num_shards: sticky
            })
            .unwrap();
        let msg = model_rxs[0]
            .recv_timeout(IDLE_RECV_TIMEOUT)
            .expect("grant after local GPU frees");
        assert!(matches!(msg, ToModel::Granted { gpu: GpuId(0), .. }), "{msg:?}");
        rank_tx.send(ToRank::Shutdown).unwrap();
        let stats = h.join().unwrap();
        assert_eq!(stats.grants, 1);
    }

    /// Draining a free GPU retires and acks immediately; a later
    /// candidate must be granted a *different* GPU.
    #[test]
    fn drain_free_gpu_acks_and_stops_granting() {
        let hints = FreeHints::new(1);
        let (clock, rank_tx, model_rxs, h) = spawn_shard(0, 0..2, hints, 1);
        let (ack_tx, ack_rx) = channel();
        rank_tx
            .send(ToRank::Drain {
                gpu: GpuId(0),
                ack: ack_tx,
            })
            .unwrap();
        let acked = ack_rx
            .recv_timeout(IDLE_RECV_TIMEOUT)
            .expect("idle GPU acks immediately");
        assert_eq!(acked, GpuId(0));
        let far = clock.now() + ms(500.0);
        rank_tx
            .send(ToRank::Candidate {
                model: ModelId(0),
                cand: Some(CandWindow {
                    exec: Micros(0),
                    latest: far,
                    size: 1,
                }),
                seq: 1,
                hops: 0,
            })
            .unwrap();
        let msg = model_rxs[0]
            .recv_timeout(IDLE_RECV_TIMEOUT)
            .expect("granted");
        assert!(
            matches!(msg, ToModel::Granted { gpu: GpuId(1), .. }),
            "drained GPU 0 must never be granted: {msg:?}"
        );
        rank_tx.send(ToRank::Shutdown).unwrap();
        let stats = h.join().unwrap();
        assert_eq!(stats.grants, 1);
    }

    /// Draining a busy GPU defers the ack until its in-flight batch
    /// completes, and the GPU never rejoins the free set.
    #[test]
    fn drain_busy_gpu_waits_for_inflight_batch() {
        let hints = FreeHints::new(1);
        let (clock, rank_tx, model_rxs, h) = spawn_shard(0, 0..1, hints, 1);
        let soon = clock.now() + ms(40.0);
        rank_tx
            .send(ToRank::GpuBusyUntil {
                gpu: GpuId(0),
                free_at: soon,
            })
            .unwrap();
        let (ack_tx, ack_rx) = channel();
        rank_tx
            .send(ToRank::Drain {
                gpu: GpuId(0),
                ack: ack_tx,
            })
            .unwrap();
        // The ack must not arrive before the batch finishes.
        assert!(
            ack_rx.recv_timeout(Duration::from_millis(10)).is_err(),
            "ack fired while the batch was still in flight"
        );
        let acked = ack_rx
            .recv_timeout(IDLE_RECV_TIMEOUT)
            .expect("ack after free_at");
        assert_eq!(acked, GpuId(0));
        // The shard's only GPU is retired: a live candidate parks
        // un-granted until shutdown.
        let far = clock.now() + ms(300.0);
        rank_tx
            .send(ToRank::Candidate {
                model: ModelId(0),
                cand: Some(CandWindow {
                    exec: Micros(0),
                    latest: far,
                    size: 1,
                }),
                seq: 1,
                hops: 0,
            })
            .unwrap();
        assert!(
            model_rxs[0].recv_timeout(Duration::from_millis(60)).is_err(),
            "no grant may come from a retired GPU"
        );
        rank_tx.send(ToRank::Shutdown).unwrap();
        let stats = h.join().unwrap();
        assert_eq!(stats.grants, 0);
    }

    /// The symmetric add path: a shard spawned with zero attached GPUs
    /// grants nothing until an `Attach` activates one.
    #[test]
    fn attach_activates_detached_gpu() {
        let clock = Clock::new();
        let hints = FreeHints::new(1);
        let (rank_tx, rank_rx) = ring::<ToRank>(64);
        let (model_tx, model_rx) = ring::<ToModel>(64);
        let rs = RankShard {
            clock,
            shard: 0,
            inbox: rank_rx,
            model_txs: vec![model_tx],
            gpus: 0..2,
            active: 0..0, // all capacity starts detached
            hints,
            live: Arc::new(ShardLive::default()),
        };
        let h = std::thread::spawn(move || rs.run());
        let far = clock.now() + ms(500.0);
        rank_tx
            .send(ToRank::Candidate {
                model: ModelId(0),
                cand: Some(CandWindow {
                    exec: Micros(0),
                    latest: far,
                    size: 1,
                }),
                seq: 1,
                hops: 0,
            })
            .unwrap();
        assert!(
            model_rx.recv_timeout(Duration::from_millis(40)).is_err(),
            "no grant before any GPU is attached"
        );
        rank_tx.send(ToRank::Attach { gpu: GpuId(1) }).unwrap();
        let msg = model_rx
            .recv_timeout(IDLE_RECV_TIMEOUT)
            .expect("granted after attach");
        assert!(matches!(msg, ToModel::Granted { gpu: GpuId(1), .. }), "{msg:?}");
        rank_tx.send(ToRank::Shutdown).unwrap();
        let stats = h.join().unwrap();
        assert_eq!(stats.grants, 1);
    }

    /// Regression (ROADMAP "measure mis-steer rates"): an
    /// Overflow-routed candidate (`hops > 0`) arriving at a shard whose
    /// free hint went stale — it has no free GPU — is counted.
    #[test]
    fn stale_hint_missteer_is_counted() {
        let hints = FreeHints::new(2);
        // This shard (index 1) advertised capacity, but its GPU is
        // occupied by the time the steered candidate arrives.
        let (clock, rank_tx, _model_rxs, h) = spawn_shard(1, 4..5, hints.clone(), 1);
        let far = clock.now() + ms(500.0);
        rank_tx
            .send(ToRank::GpuBusyUntil {
                gpu: GpuId(4),
                free_at: far,
            })
            .unwrap();
        // Keep the messages in separate inbox batches: in one batch
        // the later home registration would latest-wins over the
        // steered one before it is ever applied.
        std::thread::sleep(Duration::from_millis(20));
        // A candidate steered here by shard 0 (hops = 1) on the stale
        // free hint.
        rank_tx
            .send(ToRank::Candidate {
                model: ModelId(0),
                cand: Some(CandWindow {
                    exec: Micros(0),
                    latest: far,
                    size: 1,
                }),
                seq: 3,
                hops: 1,
            })
            .unwrap();
        std::thread::sleep(Duration::from_millis(20));
        // Home-shard registrations (hops = 0) never count as mis-steers.
        rank_tx
            .send(ToRank::Candidate {
                model: ModelId(0),
                cand: Some(CandWindow {
                    exec: Micros(0),
                    latest: far,
                    size: 2,
                }),
                seq: 4,
                hops: 0,
            })
            .unwrap();
        std::thread::sleep(Duration::from_millis(30));
        rank_tx.send(ToRank::Shutdown).unwrap();
        let stats = h.join().unwrap();
        assert_eq!(stats.mis_steers, 1, "exactly the steered arrival counts");
        assert_eq!(stats.grants, 0);
    }

    /// The reservation satellite, extending the mis-steer scenario to
    /// *concurrent* steering: two starved shards race for one
    /// advertised slot on a third. With the old read-only hints both
    /// could steer — the loser's candidate arrives at a full shard, a
    /// guaranteed mis-steer. `FreeHints::reserve` lets exactly one
    /// claim the slot; the other's candidate parks, so the would-be
    /// mis-steer never leaves its shard.
    #[test]
    fn concurrent_steering_reserves_one_slot() {
        let hints = FreeHints::new(3);
        // Shard 2 (not spawned: its hint never republishes, keeping the
        // race window open for the whole test) advertises ONE slot.
        hints.publish(2, 1);
        // Two real, permanently GPU-starved shards with one ready
        // candidate each.
        let (clock0, tx0, rx0, h0) = spawn_shard(0, 0..0, hints.clone(), 1);
        let (_clock1, tx1, rx1, h1) = spawn_shard(1, 0..0, hints.clone(), 1);
        let far = clock0.now() + ms(500.0);
        let cand = CandWindow {
            exec: Micros(0),
            latest: far,
            size: 1,
        };
        for tx in [&tx0, &tx1] {
            tx.send(ToRank::Candidate {
                model: ModelId(0),
                cand: Some(cand),
                seq: 1,
                hops: 0,
            })
            .unwrap();
        }
        // Both shards retry steering on their starved poll for the
        // whole window; only one may ever emit an Overflow verdict.
        std::thread::sleep(Duration::from_millis(120));
        tx0.send(ToRank::Shutdown).unwrap();
        tx1.send(ToRank::Shutdown).unwrap();
        let _ = h0.join().unwrap();
        let _ = h1.join().unwrap();
        let verdicts: Vec<ToModel> = rx0[0]
            .try_iter()
            .chain(rx1[0].try_iter())
            .filter(|m| matches!(m, ToModel::Overflow { .. }))
            .collect();
        assert_eq!(
            verdicts.len(),
            1,
            "one advertised slot must yield exactly one steer: {verdicts:?}"
        );
        assert!(
            matches!(verdicts[0], ToModel::Overflow { to_shard: 2, .. }),
            "{verdicts:?}"
        );
        assert_eq!(hints.free_of(2), 0, "the slot was claimed");
    }
}
