//! RankThread (§4.2, Fig 18): "organizes the global information: GPU
//! free time, each model's timer, and each GPU's timer. Model-GPU
//! matchmaking is triggered by the timers." A single RankThread serves
//! dozens of ModelThreads because it only processes batch-granularity
//! events, an order of magnitude fewer than request-granularity ones.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use crate::coordinator::clock::Clock;
use crate::coordinator::messages::{CandWindow, ToModel, ToRank};
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId};

pub struct RankThread {
    pub clock: Clock,
    pub inbox: Receiver<ToRank>,
    pub model_txs: Vec<Sender<ToModel>>,
    pub num_gpus: usize,
}

struct State {
    /// Candidates registered by ModelThreads.
    cands: BTreeMap<ModelId, CandWindow>,
    /// Candidates whose exec has passed, by urgency: (latest, model).
    ready: BTreeSet<(Micros, ModelId)>,
    /// Candidates waiting for their exec moment: (exec, model).
    pending: BTreeSet<(Micros, ModelId)>,
    /// GPUs free right now (min id first — consolidation).
    free: BTreeSet<GpuId>,
    /// GPUs that will free at a known time: (free_at, gpu).
    busy: BTreeSet<(Micros, GpuId)>,
    /// Leased to a ModelThread, waiting for its GpuBusyUntil.
    leased: BTreeSet<GpuId>,
}

impl State {
    fn unregister(&mut self, m: ModelId) {
        if let Some(old) = self.cands.remove(&m) {
            self.ready.remove(&(old.latest, m));
            self.pending.remove(&(old.exec, m));
        }
    }

    fn next_wakeup(&self) -> Option<Micros> {
        let a = self.pending.iter().next().map(|&(t, _)| t);
        let b = self.busy.iter().next().map(|&(t, _)| t);
        match (a, b) {
            (Some(x), Some(y)) => Some(x.min(y)),
            (x, y) => x.or(y),
        }
    }
}

impl RankThread {
    pub fn run(self) -> u64 {
        let RankThread {
            clock,
            inbox,
            model_txs,
            num_gpus,
        } = self;
        let mut st = State {
            cands: BTreeMap::new(),
            ready: BTreeSet::new(),
            pending: BTreeSet::new(),
            free: (0..num_gpus as u32).map(GpuId).collect(),
            busy: BTreeSet::new(),
            leased: BTreeSet::new(),
        };
        let mut grants = 0u64;

        'outer: loop {
            // 1. Drain the mailbox.
            loop {
                match inbox.try_recv() {
                    Ok(ToRank::Candidate { model, cand }) => {
                        st.unregister(model);
                        if let Some(c) = cand {
                            st.cands.insert(model, c);
                            st.pending.insert((c.exec, model));
                        }
                    }
                    Ok(ToRank::GpuBusyUntil { gpu, free_at }) => {
                        st.leased.remove(&gpu);
                        st.free.remove(&gpu);
                        st.busy.retain(|&(_, g)| g != gpu);
                        if free_at <= clock.now() {
                            st.free.insert(gpu);
                        } else {
                            st.busy.insert((free_at, gpu));
                        }
                    }
                    Ok(ToRank::Shutdown) => break 'outer,
                    Err(_) => break,
                }
            }

            let now = clock.now();

            // 2. GPU timers: promote GPUs whose free_at has passed.
            while let Some(&(t, gpu)) = st.busy.iter().next() {
                if t > now {
                    break;
                }
                st.busy.remove(&(t, gpu));
                st.free.insert(gpu);
            }

            // 3. Model timers: promote candidates whose exec has passed.
            while let Some(&(t, m)) = st.pending.iter().next() {
                if t > now {
                    break;
                }
                st.pending.remove(&(t, m));
                let c = st.cands[&m];
                st.ready.insert((c.latest, m));
            }

            // 4. Matchmaking.
            //    OnModelTimer semantics: a ready candidate takes the
            //    free GPU with the smallest id. OnGpuTimer semantics:
            //    among ready candidates the closest `latest` wins. The
            //    combined loop below pairs (min-latest candidate,
            //    min-id GPU) until one side is empty — equivalent to
            //    processing the timers in time order at this instant.
            while !st.free.is_empty() {
                let Some(&(latest, m)) = st.ready.iter().next() else {
                    break;
                };
                if latest < now {
                    // Expired: tell the ModelThread to re-register.
                    st.unregister(m);
                    let _ = model_txs[m.0 as usize].send(ToModel::Revalidate);
                    continue;
                }
                let gpu = *st.free.iter().next().unwrap();
                st.free.remove(&gpu);
                st.leased.insert(gpu);
                st.unregister(m);
                grants += 1;
                if model_txs[m.0 as usize].send(ToModel::Granted { gpu }).is_err() {
                    break 'outer;
                }
            }

            // 5. Sleep until the next timer or message.
            let timeout = match st.next_wakeup() {
                Some(t) => clock.until(t).min(Duration::from_millis(50)),
                None => Duration::from_millis(50),
            };
            match inbox.recv_timeout(timeout) {
                Ok(msg) => {
                    // Re-inject and loop (drain handles it).
                    match msg {
                        ToRank::Candidate { model, cand } => {
                            st.unregister(model);
                            if let Some(c) = cand {
                                st.cands.insert(model, c);
                                st.pending.insert((c.exec, model));
                            }
                        }
                        ToRank::GpuBusyUntil { gpu, free_at } => {
                            st.leased.remove(&gpu);
                            st.free.remove(&gpu);
                            st.busy.retain(|&(_, g)| g != gpu);
                            if free_at <= clock.now() {
                                st.free.insert(gpu);
                            } else {
                                st.busy.insert((free_at, gpu));
                            }
                        }
                        ToRank::Shutdown => break 'outer,
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break 'outer,
            }
        }
        grants
    }
}
