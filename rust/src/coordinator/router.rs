//! Shard-routing layer between ModelThreads and the rank shards.
//!
//! [`ShardTopology`] splits the GPU id space into `R` contiguous ranges
//! (shard 0 owns the lowest ids — the consolidation prefix the
//! autoscaler reclaims from the top). [`RankRouter`] is the
//! ModelThread-side handle: it remembers which shard currently holds
//! this model's candidate (exactly one shard at a time), routes
//! candidate updates there, clears the old registration when the
//! candidate migrates on overflow, and routes `GpuBusyUntil` to the
//! shard owning the GPU.
//!
//! The router addresses shards through [`RankPort`]s: an in-process
//! ring sender ([`crate::util::ring`]), or one shard of a
//! [`crate::net`] rank-server connection. Everything above this layer — the router's coalescing,
//! overflow steering, the drain/attach autoscaler protocol — is
//! transport-agnostic; `serve --remote-ranks` swaps the port kind and
//! nothing else.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::coordinator::messages::{CandWindow, ToRank};
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId};
use crate::net::client::RemoteRank;
use crate::net::codec::WireToRank;
use crate::obs::trace::{self, Stage};
use crate::util::ring::RingSender;
use crate::util::shim::{Fabric, RealFabric, ShimAtomic};

/// The rank shard behind a [`RankPort`] is unreachable: its thread
/// exited (in-process) or its connection closed (remote). The message
/// is gone either way — senders treat this like a disconnected channel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PortClosed;

impl std::fmt::Display for PortClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "rank port closed")
    }
}

impl std::error::Error for PortClosed {}

/// Transport-agnostic handle to one rank shard.
#[derive(Clone)]
pub enum RankPort {
    /// In-process shard thread (the pre-wire configuration). Candidate
    /// registrations, busy-until updates, and drain/attach control all
    /// ride the bounded ring; the blocking `send` retries on a
    /// transiently full ring — control traffic must not drop.
    Local(RingSender<ToRank>),
    /// One shard of a remote `symphony rank-server` connection; the
    /// shard index rides in every up-frame's header.
    Remote { conn: Arc<RemoteRank>, shard: u16 },
}

impl RankPort {
    /// Deliver `msg` to the shard. For a remote port the in-process
    /// vocabulary maps onto the wire: `Drain`'s ack sender is parked in
    /// the connection's ack table until the matching `DrainAck` frame
    /// returns, and `Shutdown` becomes a connection close (the server
    /// shuts its session shards down on EOF).
    pub fn send(&self, msg: ToRank) -> Result<(), PortClosed> {
        match self {
            RankPort::Local(tx) => tx.send(msg).map_err(|_| PortClosed),
            RankPort::Remote { conn, shard } => match msg {
                ToRank::Candidate {
                    model,
                    cand,
                    seq,
                    hops,
                } => conn.send(
                    *shard,
                    &WireToRank::Candidate {
                        model,
                        cand,
                        seq,
                        hops,
                    },
                ),
                ToRank::GpuBusyUntil { gpu, free_at } => {
                    conn.send(*shard, &WireToRank::GpuBusyUntil { gpu, free_at })
                }
                ToRank::Drain { gpu, ack } => conn.drain(*shard, gpu, ack),
                ToRank::Attach { gpu } => conn.attach(*shard, gpu),
                ToRank::Shutdown => {
                    conn.close();
                    Ok(())
                }
            },
        }
    }
}

/// Per-shard liveness, shared between the wire clients (whose dialers
/// mark a server's shards dead once it stays unreachable past the
/// reconnect policy's deadline, and live again on re-handshake), the
/// [`RankRouter`]s (which redirect registrations off dead shards), and
/// the autoscaler (which re-tiles a dead range's capacity onto
/// survivors). In-process shards never die, so the default
/// all-live instance makes every redirect a no-op.
#[derive(Clone)]
pub struct ShardLiveness {
    live: Arc<Vec<AtomicBool>>,
}

impl ShardLiveness {
    pub fn all_live(shards: usize) -> Self {
        ShardLiveness {
            live: Arc::new((0..shards.max(1)).map(|_| AtomicBool::new(true)).collect()),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.live.len()
    }

    /// Is `shard` reachable? Out-of-range indices read as live so a
    /// stale caller degrades to the pre-liveness behavior (send and let
    /// the port fail) instead of inventing a dead shard.
    pub fn is_live(&self, shard: usize) -> bool {
        // relaxed: liveness is an advisory routing hint — a stale read
        // sends one registration at a dead (or just-revived) shard,
        // which the reconnect replay / overflow path already heals; no
        // payload is published under this flag.
        self.live.get(shard).map_or(true, |l| l.load(Ordering::Relaxed))
    }

    pub fn set_live(&self, shard: usize, live: bool) {
        if let Some(l) = self.live.get(shard) {
            // relaxed: see `is_live` — an advisory flag with no payload
            // riding on it; markers and readers tolerate staleness.
            l.store(live, Ordering::Relaxed);
        }
    }

    /// Mark a contiguous run of shards (one wire connection's slice of
    /// the global topology) dead or live.
    pub fn set_range_live(&self, shards: std::ops::Range<usize>, live: bool) {
        for s in shards {
            self.set_live(s, live);
        }
    }
}

/// Contiguous partition of `num_gpus` GPU ids across `shards` ranges.
#[derive(Clone, Debug)]
pub struct ShardTopology {
    /// `bounds[s]..bounds[s+1]` is shard `s`'s GPU id range.
    bounds: Vec<u32>,
}

impl ShardTopology {
    pub fn new(num_gpus: usize, shards: usize) -> Self {
        let shards = shards.clamp(1, num_gpus.max(1));
        let mut bounds = Vec::with_capacity(shards + 1);
        for s in 0..=shards {
            bounds.push(Self::split(&(0..num_gpus as u32), shards, s));
        }
        ShardTopology { bounds }
    }

    /// The one contiguous-split formula both ends of the wire derive
    /// from: splitting `range` into `shards` sub-ranges, sub-range `s`
    /// is `split(range, shards, s)..split(range, shards, s + 1)`.
    /// Used by `new` (in-process), by the rank server laying out its
    /// session shards, and by the client rebuilding the topology from
    /// server preambles — GPU routing depends on all three agreeing,
    /// so none of them may hand-roll the arithmetic.
    pub fn split(range: &std::ops::Range<u32>, shards: usize, s: usize) -> u32 {
        let len = (range.end - range.start) as u64;
        range.start + (len * s as u64 / shards.max(1) as u64) as u32
    }

    /// Topology from explicit shard bounds (`bounds[s]..bounds[s+1]`
    /// per shard) — how a remote rank tier's topology is assembled from
    /// the per-server preambles. Bounds must start at 0 and be strictly
    /// ascending (no empty shard ranges).
    pub fn from_bounds(bounds: Vec<u32>) -> Self {
        assert!(bounds.len() >= 2, "need at least one shard range");
        assert_eq!(bounds[0], 0, "shard 0 must start at GPU id 0");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "shard bounds must be strictly ascending: {bounds:?}"
        );
        ShardTopology { bounds }
    }

    pub fn num_shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// The GPU ids shard `s` owns.
    pub fn range(&self, s: usize) -> std::ops::Range<u32> {
        self.bounds[s]..self.bounds[s + 1]
    }

    /// The shard owning GPU `g`.
    pub fn shard_of(&self, g: GpuId) -> usize {
        // Shard ranges are contiguous and ascending: binary search on
        // the upper bounds.
        match self.bounds[1..].binary_search(&(g.0 + 1)) {
            Ok(i) => i,
            Err(i) => i,
        }
    }

    /// The home shard for a model: registrations spread round-robin so
    /// candidate bookkeeping parallelizes even when grants consolidate
    /// onto shard 0.
    pub fn home_of(&self, m: ModelId) -> usize {
        m.0 as usize % self.num_shards()
    }
}

/// One shard's advertisement: the free count the owner last published,
/// and the reservations steering shards have taken against it since.
struct ShardHint<F: Fabric> {
    free: F::Atomic,
    reserved: F::Atomic,
}

/// Free-GPU hints: one `{free, reserved}` pair per shard. `free` is
/// written by the owning shard and decremented by racing steerers
/// (reservations); `reserved` remembers those claims so the owner's
/// next `publish` *merges* with them instead of overwriting them.
/// Staleness is benign — a mis-steered candidate is re-steered or
/// revalidated — but a republish must not resurrect slots that were
/// just claimed, or every starved sibling re-steers at the same GPU
/// each publish interval.
///
/// Generic over the [`Fabric`] so `symphony check` can enumerate the
/// reserve/republish/redeem races on its virtual atomics (models
/// `hints-reserve` / `hints-republish`); [`FreeHints`] is the
/// production instantiation.
pub struct GenericFreeHints<F: Fabric> {
    counts: Arc<Vec<ShardHint<F>>>,
}

/// [`GenericFreeHints`] on the production fabric.
pub type FreeHints = GenericFreeHints<RealFabric>;

impl<F: Fabric> Clone for GenericFreeHints<F> {
    fn clone(&self) -> Self {
        GenericFreeHints {
            counts: self.counts.clone(),
        }
    }
}

impl<F: Fabric> GenericFreeHints<F> {
    pub fn new(shards: usize) -> Self {
        GenericFreeHints {
            counts: Arc::new(
                (0..shards)
                    .map(|_| ShardHint {
                        free: F::atomic(0),
                        reserved: F::atomic(0),
                    })
                    .collect(),
            ),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.counts.len()
    }

    /// The owning shard republishes its current free count. Outstanding
    /// reservations discount the advertisement exactly once: a steered
    /// candidate is still in flight when its target republishes (the
    /// owner cannot see it yet), so the claimed slot must stay claimed
    /// for one more publish interval. A reservation whose candidate
    /// arrives is consumed by [`FreeHints::redeem`] before that; one
    /// whose candidate never arrives (steering shard died mid-send) is
    /// dropped here after discounting once — a leaked claim self-heals
    /// instead of permanently shrinking the advertisement.
    pub fn publish(&self, shard: usize, free: usize) {
        let h = &self.counts[shard];
        // relaxed: hints are advisory counters, not a publication of
        // other memory — no payload is handed over, so no acquire/
        // release pairing is needed; atomicity of the swap alone keeps
        // every carried reservation discounted exactly once.
        let carried = h.reserved.swap(0, Ordering::Relaxed);
        // relaxed: same advisory-counter argument; a steerer reading a
        // stale count mis-steers one candidate, which revalidation
        // already handles.
        h.free.store(free.saturating_sub(carried), Ordering::Relaxed);
    }

    pub fn free_of(&self, shard: usize) -> usize {
        // relaxed: advisory read for steering-order heuristics only.
        self.counts[shard].free.load(Ordering::Relaxed)
    }

    /// Atomically claim one advertised free slot on `shard`: decrement
    /// its published count if still positive, returning whether a slot
    /// was claimed. Steering shards reserve instead of merely reading,
    /// so two GPU-starved shards racing on the same advertisement
    /// cannot both steer a candidate at one free GPU (the ROADMAP's
    /// "per-shard reserved count"). The claim also registers in
    /// `reserved` so the owner's next `publish` merges with it — the
    /// hint stays a hint, not a ledger, but a republish no longer hands
    /// the same GPU out again while the steered candidate is in flight.
    pub fn reserve(&self, shard: usize) -> bool {
        let h = &self.counts[shard];
        // relaxed: the claim is the RMW's atomicity itself — two racing
        // steerers cannot both take the last slot because fetch_update
        // is a CAS loop on the single counter; no other memory rides on
        // the edge, so no ordering is required.
        if h.free
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, &mut |c| c.checked_sub(1))
            .is_ok()
        {
            // relaxed: counter-only bookkeeping; the owner's `publish`
            // swap observes any interleaving of this increment exactly
            // once (atomicity), and no payload accompanies it.
            h.reserved.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// A steered candidate reached `shard`: the reservation its steerer
    /// took is now visible to the owner as a registered candidate, so it
    /// stops discounting future publishes. Called by the owning shard on
    /// arrival; redeeming with no outstanding reservation is a no-op
    /// (the reservation may already have been dropped by a publish).
    pub fn redeem(&self, shard: usize) {
        // relaxed: counter-only RMW, same argument as `reserve` — the
        // checked_sub keeps the count from underflowing when the
        // reservation was already dropped by a publish.
        let _ = self.counts[shard].reserved.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            &mut |c| c.checked_sub(1),
        );
    }
}

/// ModelThread-side routing handle. Owns the single-authority invariant:
/// at any time at most one shard holds this model's candidate (modulo
/// messages in flight, which the `seq` echo makes detectable).
pub struct RankRouter {
    topo: ShardTopology,
    ports: Vec<RankPort>,
    model: ModelId,
    home: usize,
    /// Which shards are currently reachable (dead-server failover
    /// redirects registrations to the first live shard).
    liveness: ShardLiveness,
    /// Shard currently holding the registration.
    reg_shard: usize,
    /// Monotone registration counter (echoed by `ToModel::Overflow`).
    seq: u64,
    /// What `reg_shard` provably holds, when known: `Some(x)` = exactly
    /// the registration `x`; `None` = unknown (the shard consumed the
    /// registration — grant, expiry revalidation, or overflow verdict —
    /// so the next registration must be sent even if unchanged).
    last_sent: Option<Option<CandWindow>>,
}

impl RankRouter {
    pub fn new(topo: ShardTopology, ports: Vec<RankPort>, model: ModelId) -> Self {
        let liveness = ShardLiveness::all_live(topo.num_shards());
        Self::with_liveness(topo, ports, model, liveness)
    }

    /// [`RankRouter::new`] with a shared liveness map (the wire
    /// configuration: clients mark their slice dead/live, every router
    /// reads it).
    pub fn with_liveness(
        topo: ShardTopology,
        ports: Vec<RankPort>,
        model: ModelId,
        liveness: ShardLiveness,
    ) -> Self {
        assert_eq!(topo.num_shards(), ports.len(), "one port per shard");
        let home = topo.home_of(model);
        RankRouter {
            topo,
            ports,
            model,
            home,
            liveness,
            reg_shard: home,
            seq: 0,
            // A fresh shard holds no registration, which "cleared" (None)
            // describes exactly.
            last_sent: Some(None),
        }
    }

    /// Redirect a registration target off a dead shard: wrap-scan from
    /// `shard` for the first live one. With everything dead (or nothing
    /// marked), the original target stands — the send then fails or
    /// drops exactly as it did before liveness existed.
    fn pick_live(&self, shard: usize) -> usize {
        let n = self.ports.len();
        (0..n)
            .map(|i| (shard + i) % n)
            .find(|&s| self.liveness.is_live(s))
            .unwrap_or(shard)
    }

    pub fn num_shards(&self) -> usize {
        self.ports.len()
    }

    /// The registration sequence the router most recently sent.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Is this overflow verdict about the current registration?
    pub fn overflow_is_current(&self, seq: u64) -> bool {
        seq == self.seq
    }

    /// Register / replace / clear the candidate at its *home* shard
    /// (post-grant re-registration, revalidation — a fresh logical
    /// candidate).
    pub fn register_home(&mut self, cand: Option<CandWindow>) -> Result<(), PortClosed> {
        self.register_at(self.home, cand, 0)
    }

    /// Replace the candidate wherever it is currently registered
    /// (request arrivals update the window without re-homing).
    ///
    /// Coalescing: when the shard provably already holds an equivalent
    /// registration, the send is skipped — arrivals recompute the window
    /// at request rate while the shard only needs batch-rate traffic.
    /// Equivalent means same `size` and `latest` with `exec` not moving
    /// backward: once the window is open, `exec = max(now, frontrun)`
    /// drifts forward with the clock on every arrival, but the shard
    /// only compares `exec` against *its* clock to decide readiness —
    /// an already-past `exec` is behaviorally identical to a
    /// slightly-later already-past `exec` (grants re-plan the batch at
    /// the ModelThread anyway), so forward drift alone is no reason to
    /// re-register. `last_sent` is invalidated whenever the shard
    /// consumes the registration, so a skip can never lose a candidate.
    pub fn register_current(
        &mut self,
        cand: Option<CandWindow>,
        hops: u32,
    ) -> Result<(), PortClosed> {
        // A dead registered shard defeats coalescing: whatever it held
        // is unreachable, so the next recompute must actually send (and
        // `register_at` will redirect it to a live shard) instead of
        // leaving the candidate pinned to a corpse.
        if self.liveness.is_live(self.reg_shard) {
            if let (Some(new), Some(Some(prev))) = (cand.as_ref(), self.last_sent.as_ref()) {
                if new.size == prev.size && new.latest == prev.latest && new.exec >= prev.exec {
                    return Ok(());
                }
            }
        }
        self.register_at(self.reg_shard, cand, hops)
    }

    /// The registered shard consumed or raced this model's registration
    /// (a grant, expiry revalidation, or overflow verdict arrived): the
    /// router can no longer assume what the shard holds.
    pub fn invalidate_last_sent(&mut self) {
        self.last_sent = None;
    }

    /// Re-register at `shard` after an overflow verdict; `hops` bounds
    /// how often one logical candidate migrates.
    pub fn register_overflow(
        &mut self,
        shard: usize,
        cand: Option<CandWindow>,
        hops: u32,
    ) -> Result<(), PortClosed> {
        self.register_at(shard.min(self.num_shards() - 1), cand, hops)
    }

    fn register_at(
        &mut self,
        shard: usize,
        cand: Option<CandWindow>,
        hops: u32,
    ) -> Result<(), PortClosed> {
        let shard = self.pick_live(shard);
        if shard != self.reg_shard {
            // Clear the old registration first so at most one shard can
            // grant for this model (a grant already in flight is handled
            // by the ModelThread returning the GPU unused).
            self.seq += 1;
            let _ = self.ports[self.reg_shard].send(ToRank::Candidate {
                model: self.model,
                cand: None,
                seq: self.seq,
                hops: 0,
            });
            self.reg_shard = shard;
        }
        self.seq += 1;
        let res = self.ports[shard].send(ToRank::Candidate {
            model: self.model,
            cand,
            seq: self.seq,
            hops,
        });
        if res.is_ok() && cand.is_some() {
            trace::model_event(Stage::CandReg, self.model);
        }
        self.last_sent = if res.is_ok() { Some(cand) } else { None };
        res
    }

    /// `inform_gpu`: routed to the shard that owns the GPU.
    pub fn gpu_busy_until(&self, gpu: GpuId, free_at: Micros) -> Result<(), PortClosed> {
        self.ports[self.topo.shard_of(gpu)].send(ToRank::GpuBusyUntil { gpu, free_at })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_partitions_contiguously() {
        let t = ShardTopology::new(10, 4);
        assert_eq!(t.num_shards(), 4);
        let mut seen = Vec::new();
        for s in 0..4 {
            for g in t.range(s) {
                assert_eq!(t.shard_of(GpuId(g)), s, "gpu {g}");
                seen.push(g);
            }
        }
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn topology_clamps_shards_to_gpus() {
        let t = ShardTopology::new(2, 8);
        assert_eq!(t.num_shards(), 2);
        let t = ShardTopology::new(5, 1);
        assert_eq!(t.num_shards(), 1);
        assert_eq!(t.range(0), 0..5);
        // Zero shards is coerced to one.
        let t = ShardTopology::new(3, 0);
        assert_eq!(t.num_shards(), 1);
    }

    #[test]
    fn homes_cover_all_shards() {
        let t = ShardTopology::new(8, 4);
        let homes: std::collections::BTreeSet<usize> =
            (0..8).map(|m| t.home_of(ModelId(m))).collect();
        assert_eq!(homes.len(), 4);
    }

    #[test]
    fn topology_from_explicit_bounds() {
        let t = ShardTopology::from_bounds(vec![0, 2, 3, 7]);
        assert_eq!(t.num_shards(), 3);
        assert_eq!(t.range(0), 0..2);
        assert_eq!(t.range(2), 3..7);
        assert_eq!(t.shard_of(GpuId(2)), 1);
        assert_eq!(t.shard_of(GpuId(6)), 2);
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn topology_from_bounds_rejects_empty_ranges() {
        let _ = ShardTopology::from_bounds(vec![0, 2, 2, 4]);
    }

    /// Both ends of the wire derive shard layouts from
    /// `ShardTopology::split`; pin that `new` agrees with it and that
    /// an offset range tiles contiguously with no empty sub-range
    /// (what the rank server and the client reconstruction rely on).
    #[test]
    fn split_is_the_single_layout_formula() {
        let t = ShardTopology::new(10, 4);
        for s in 0..4 {
            let lo = ShardTopology::split(&(0..10), 4, s);
            let hi = ShardTopology::split(&(0..10), 4, s + 1);
            assert_eq!(t.range(s), lo..hi, "shard {s}");
        }
        // Offset range (a rank server owning 3..11, 3 shards).
        let r = 3..11u32;
        let mut expect = 3u32;
        for s in 0..3 {
            let lo = ShardTopology::split(&r, 3, s);
            let hi = ShardTopology::split(&r, 3, s + 1);
            assert_eq!(lo, expect, "contiguous tiling");
            assert!(hi > lo, "no empty sub-range");
            expect = hi;
        }
        assert_eq!(expect, 11);
    }

    #[test]
    fn hints_publish_and_read_per_shard() {
        let h = FreeHints::new(3);
        assert_eq!(h.num_shards(), 3);
        assert_eq!(h.free_of(0), 0);
        h.publish(2, 4);
        let h2 = h.clone();
        assert_eq!(h2.free_of(2), 4, "clones share the counters");
        h2.publish(2, 0);
        assert_eq!(h.free_of(2), 0);
    }

    /// The reservation satellite: `k` advertised slots yield at most
    /// `k` successful reservations no matter how many threads race on
    /// them — concurrent steerers can no longer all claim the same
    /// free GPU off a shared hint.
    #[test]
    fn reserve_caps_concurrent_claims_at_advertised() {
        use std::sync::atomic::AtomicUsize;
        let h = FreeHints::new(2);
        h.publish(1, 3);
        let wins = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let h = h.clone();
            let wins = wins.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..4 {
                    if h.reserve(1) {
                        wins.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }));
        }
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(wins.load(Ordering::Relaxed), 3, "3 slots, 3 winners");
        assert_eq!(h.free_of(1), 0);
        assert!(!h.reserve(1), "an empty hint is never claimable");
        // A republish while the 3 steered candidates are still in
        // flight must not resurrect their slots (merge-publish).
        h.publish(1, 3);
        assert_eq!(h.free_of(1), 0, "outstanding reservations discount the republish");
        assert!(!h.reserve(1));
        // The un-redeemed reservations are dropped after discounting
        // once, so the publish after that advertises freely again.
        h.publish(1, 1);
        assert!(h.reserve(1));
    }

    /// The merge-publish regression (this PR's motivating bug): the old
    /// `publish` stored the owner's free count over the counter,
    /// erasing reservations and letting every publish interval hand the
    /// same free GPU to another steerer.
    #[test]
    fn republish_does_not_resurrect_reserved_slots() {
        let h = FreeHints::new(2);
        h.publish(1, 2);
        assert!(h.reserve(1) && h.reserve(1), "both advertised slots claimable");
        // Owner still sees 2 free GPUs (the steered candidates are in
        // flight) and republishes: the claims must survive.
        h.publish(1, 2);
        assert_eq!(h.free_of(1), 0);
        assert!(!h.reserve(1));
    }

    /// `redeem` consumes a reservation when its steered candidate
    /// arrives: the owner now *sees* the candidate, so the next publish
    /// (whose free count already reflects any grant to it) is no longer
    /// discounted.
    #[test]
    fn redeemed_reservations_stop_discounting() {
        let h = FreeHints::new(2);
        h.publish(1, 2);
        assert!(h.reserve(1) && h.reserve(1));
        h.redeem(1);
        h.redeem(1);
        // Redeeming more than was reserved stays a no-op.
        h.redeem(1);
        h.publish(1, 2);
        assert_eq!(h.free_of(1), 2, "arrived candidates no longer discount");
    }

    /// Concurrent merge-publish regression: with ONE free GPU and an
    /// owner republishing `1` over and over (never seeing an arrival),
    /// a racing steerer must win at most ~half the publish intervals —
    /// each win's reservation blanks at least the following publish.
    /// The pre-merge counter handed the slot out on almost every
    /// publish (wins ≈ publishes).
    #[test]
    fn concurrent_republish_caps_claim_rate() {
        use std::sync::atomic::AtomicBool;
        const PUBLISHES: usize = 200;
        let h = FreeHints::new(2);
        let stop = Arc::new(AtomicBool::new(false));
        let wins = {
            let h = h.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut wins = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    if h.reserve(1) {
                        wins += 1;
                    } else {
                        std::hint::spin_loop();
                    }
                }
                wins
            })
        };
        for _ in 0..PUBLISHES {
            h.publish(1, 1);
            std::thread::yield_now();
        }
        stop.store(true, Ordering::Relaxed);
        let wins = wins.join().unwrap();
        assert!(
            wins <= PUBLISHES / 2 + 2,
            "a reservation must discount the next republish: {wins} wins \
             over {PUBLISHES} publishes"
        );
    }

    /// Unchanged-window re-registrations coalesce to a single send; an
    /// invalidation (grant/revalidate/overflow) forces the next send.
    #[test]
    fn router_coalesces_unchanged_registrations() {
        use crate::util::ring::ring;
        let topo = ShardTopology::new(2, 1);
        let (tx, rx) = ring::<ToRank>(64);
        let mut r = RankRouter::new(topo, vec![RankPort::Local(tx)], ModelId(0));
        let w = CandWindow {
            exec: Micros(10),
            latest: Micros(20),
            size: 3,
        };
        r.register_current(Some(w), 0).unwrap();
        let seq_after_first = r.seq();
        // Identical window: skipped, seq unchanged.
        r.register_current(Some(w), 0).unwrap();
        // Open-window exec drift (same size/latest, exec moved forward
        // with the clock): behaviorally identical, also skipped.
        r.register_current(Some(CandWindow { exec: Micros(15), ..w }), 0)
            .unwrap();
        assert_eq!(r.seq(), seq_after_first);
        // Changed window: sent.
        let w2 = CandWindow { size: 4, ..w };
        r.register_current(Some(w2), 0).unwrap();
        // Shard consumed the registration (e.g. grant): identical window
        // must be re-sent.
        r.invalidate_last_sent();
        r.register_current(Some(w2), 0).unwrap();
        let msgs: Vec<ToRank> = rx.try_iter().collect();
        assert_eq!(msgs.len(), 3, "{msgs:?}");
        assert!(msgs.iter().all(|m| matches!(
            m,
            ToRank::Candidate { cand: Some(_), .. }
        )));
    }

    /// Dead-shard failover at the routing layer: registrations redirect
    /// to the first live shard (wrap scan from the target), a dead
    /// registered shard defeats coalescing, and revival routes the next
    /// home registration back.
    #[test]
    fn router_redirects_off_dead_shards() {
        use crate::util::ring::ring;
        let topo = ShardTopology::new(4, 2);
        let (tx0, rx0) = ring::<ToRank>(64);
        let (tx1, rx1) = ring::<ToRank>(64);
        let liveness = ShardLiveness::all_live(2);
        // ModelId(0) homes on shard 0.
        let mut r = RankRouter::with_liveness(
            topo,
            vec![RankPort::Local(tx0), RankPort::Local(tx1)],
            ModelId(0),
            liveness.clone(),
        );
        let w = CandWindow {
            exec: Micros(10),
            latest: Micros(20),
            size: 3,
        };
        r.register_home(Some(w)).unwrap();
        assert_eq!(rx0.try_iter().count(), 1, "home shard live: routed home");
        // Shard 0 dies. The identical window would normally coalesce to
        // zero sends; the dead shard must force a redirected send.
        liveness.set_live(0, false);
        r.register_current(Some(w), 0).unwrap();
        let msgs1: Vec<ToRank> = rx1.try_iter().collect();
        assert!(
            matches!(&msgs1[..], [ToRank::Candidate { cand: Some(_), .. }]),
            "registration must land on the survivor: {msgs1:?}"
        );
        // The clearing send at the dead shard is attempted (and may be
        // dropped by a reconnecting port); nothing else lands there.
        let cleared: Vec<ToRank> = rx0.try_iter().collect();
        assert!(
            matches!(&cleared[..], [ToRank::Candidate { cand: None, .. }]),
            "{cleared:?}"
        );
        // Revival: the next home registration goes home again.
        liveness.set_live(0, true);
        r.register_home(Some(w)).unwrap();
        assert_eq!(rx0.try_iter().count(), 1, "revived home shard reached");
    }

    #[test]
    fn router_clears_old_shard_on_migration() {
        use crate::util::ring::ring;
        let topo = ShardTopology::new(4, 2);
        let (tx0, rx0) = ring::<ToRank>(64);
        let (tx1, rx1) = ring::<ToRank>(64);
        // ModelId(0) homes on shard 0.
        let mut r = RankRouter::new(
            topo,
            vec![RankPort::Local(tx0), RankPort::Local(tx1)],
            ModelId(0),
        );
        let cand = CandWindow {
            exec: Micros(10),
            latest: Micros(20),
            size: 2,
        };
        r.register_home(Some(cand)).unwrap();
        let first_seq = r.seq();
        assert!(r.overflow_is_current(first_seq));
        // Overflow to shard 1: shard 0 must see a clearing registration.
        r.register_overflow(1, Some(cand), 1).unwrap();
        assert!(!r.overflow_is_current(first_seq));
        let msgs0: Vec<ToRank> = rx0.try_iter().collect();
        assert_eq!(msgs0.len(), 2);
        assert!(
            matches!(&msgs0[1], ToRank::Candidate { cand: None, .. }),
            "{msgs0:?}"
        );
        let msgs1: Vec<ToRank> = rx1.try_iter().collect();
        assert!(
            matches!(&msgs1[..], [ToRank::Candidate { cand: Some(_), hops: 1, .. }]),
            "{msgs1:?}"
        );
        // GpuBusyUntil routes by GPU id range.
        r.gpu_busy_until(GpuId(3), Micros(99)).unwrap();
        assert!(matches!(
            rx1.try_iter().next(),
            Some(ToRank::GpuBusyUntil {
                gpu: GpuId(3),
                ..
            })
        ));
    }
}
