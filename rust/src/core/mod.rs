//! Core domain types: virtual time, identifiers, latency profiles, and
//! the paper's model zoo (Appendix C).

pub mod model_zoo;
pub mod profile;
pub mod time;
pub mod types;
