//! The paper's model zoo: latency profiles measured on NVIDIA 1080Ti
//! (Appendix C, Table 3) and A100 (Table 4), transcribed verbatim.
//! α/β in milliseconds, SLO in milliseconds. These drive every
//! emulated-cluster experiment, exactly as in the paper ("we emulate the
//! execution by simply introducing a delay at the backend").

use crate::core::profile::{LatencyProfile, ModelSpec};
use crate::core::time::Micros;

/// GPU generation the profile was measured on.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum GpuKind {
    Gtx1080Ti,
    A100,
}

impl GpuKind {
    pub fn name(&self) -> &'static str {
        match self {
            GpuKind::Gtx1080Ti => "1080Ti",
            GpuKind::A100 => "A100",
        }
    }
}

/// (name, alpha_ms, beta_ms, slo_ms) — Table 3 (NVIDIA 1080Ti).
pub const TABLE3_1080TI: &[(&str, f64, f64, f64)] = &[
    ("NASNetMobile", 0.570, 14.348, 33.0),
    ("MobileNetV3Small", 0.335, 5.350, 20.0),
    ("DenseNet169", 1.271, 13.618, 37.0),
    ("DenseNet121", 1.061, 10.312, 29.0),
    ("DenseNet201", 1.733, 15.687, 45.0),
    ("EfficientNetV2B0", 1.006, 7.493, 23.0),
    ("MobileNetV3Large", 0.820, 5.256, 20.0),
    ("InceptionV3", 1.964, 8.771, 33.0),
    ("EfficientNetV2B1", 1.661, 7.247, 27.0),
    ("ResNet50V2", 1.409, 5.947, 23.0),
    ("ResNet152V2", 3.471, 13.049, 53.0),
    ("ResNet101V2", 2.438, 9.095, 37.0),
    ("InceptionResNetV2", 5.090, 18.368, 77.0),
    ("EfficientNetB0", 1.569, 5.586, 23.0),
    ("MobileNetV2", 1.180, 3.483, 20.0),
    ("ResNet101", 3.164, 9.065, 43.0),
    ("EfficientNetB1", 2.489, 6.674, 33.0),
    ("ResNet50", 2.050, 5.378, 27.0),
    ("EfficientNetV2B2", 2.254, 5.896, 29.0),
    ("VGG19", 3.059, 7.857, 40.0),
    ("ResNet152", 4.599, 11.212, 59.0),
    ("MobileNet", 1.009, 2.390, 20.0),
    ("VGG16", 2.734, 5.786, 33.0),
    ("EfficientNetB2", 3.446, 5.333, 38.0),
    ("EfficientNetV2B3", 4.072, 5.981, 44.0),
    ("NASNetLarge", 17.656, 18.952, 179.0),
    ("EfficientNetV2S", 8.463, 8.862, 85.0),
    ("EfficientNetB3", 5.924, 4.849, 57.0),
    ("EfficientNetV2L", 40.313, 28.208, 378.0),
    ("EfficientNetV2M", 22.619, 14.786, 210.0),
    ("EfficientNetB5", 23.435, 10.301, 208.0),
    ("Xception", 4.751, 2.046, 42.0),
    ("SSDMobilenet", 23.778, 9.729, 209.0),
    ("EfficientNetB4", 12.088, 4.412, 105.0),
    ("BERT", 7.008, 0.159, 56.0),
];

/// (name, alpha_ms, beta_ms, slo_ms) — Table 4 (NVIDIA A100).
pub const TABLE4_A100: &[(&str, f64, f64, f64)] = &[
    ("DenseNet121", 0.054, 10.546, 21.0),
    ("DenseNet201", 0.304, 14.345, 31.0),
    ("DenseNet169", 0.289, 13.365, 29.0),
    ("ResNet50V2", 0.135, 5.560, 20.0),
    ("EfficientNetB0", 0.115, 4.326, 20.0),
    ("ResNet101", 0.284, 8.266, 20.0),
    ("ResNet152", 0.390, 10.449, 24.0),
    ("ResNet101V2", 0.391, 8.219, 20.0),
    ("MobileNetV3Large", 0.196, 4.072, 20.0),
    ("EfficientNetB1", 0.291, 5.797, 20.0),
    ("ResNet50", 0.268, 5.172, 20.0),
    ("ResNet152V2", 0.589, 10.054, 24.0),
    ("MobileNetV2", 0.190, 2.892, 20.0),
    ("EfficientNetV2B3", 0.543, 7.596, 20.0),
    ("InceptionResNetV2", 1.112, 15.270, 39.0),
    ("EfficientNetV2B1", 0.443, 5.929, 20.0),
    ("NASNetMobile", 0.536, 6.860, 20.0),
    ("EfficientNetV2B0", 0.377, 4.272, 20.0),
    ("EfficientNetB2", 0.520, 5.333, 20.0),
    ("MobileNetV3Small", 0.315, 3.211, 20.0),
    ("InceptionV3", 0.913, 6.732, 20.0),
    ("MobileNet", 0.285, 1.901, 20.0),
    ("EfficientNetV2S", 1.454, 7.378, 26.0),
    ("EfficientNetV2B2", 0.901, 4.532, 20.0),
    ("VGG16", 0.660, 2.252, 20.0),
    ("EfficientNetB3", 1.239, 4.205, 20.0),
    ("Xception", 0.801, 2.638, 20.0),
    ("VGG19", 0.893, 2.181, 20.0),
    ("NASNetLarge", 3.464, 7.154, 42.0),
    ("EfficientNetV2M", 4.479, 6.861, 49.0),
    ("EfficientNetB4", 2.881, 4.103, 31.0),
    ("EfficientNetV2L", 7.520, 6.675, 73.0),
    ("EfficientNetB5", 6.121, 2.283, 53.0),
    ("SSDMobilenet", 19.448, 4.442, 164.0),
    ("EfficientNetB6", 9.754, 1.984, 82.0),
    ("EfficientNetB7", 16.339, 2.751, 136.0),
    ("BERT", 7.353, 0.222, 59.0),
];

/// Table 2's two single-model case studies (1080Ti measurements).
pub fn resnet50_table2() -> ModelSpec {
    ModelSpec::new("ResNet50", 1.053, 5.072, 25.0)
}
pub fn inception_resnet_v2_table2() -> ModelSpec {
    ModelSpec::new("InceptionResNetV2", 5.090, 18.368, 70.0)
}

/// Full zoo for a GPU generation.
pub fn zoo(kind: GpuKind) -> Vec<ModelSpec> {
    let table = match kind {
        GpuKind::Gtx1080Ti => TABLE3_1080TI,
        GpuKind::A100 => TABLE4_A100,
    };
    table
        .iter()
        .map(|&(name, a, b, slo)| ModelSpec::new(name, a, b, slo))
        .collect()
}

/// Models with a strong batching effect (β/α > 2), per §5.1.
pub fn zoo_strong(kind: GpuKind) -> Vec<ModelSpec> {
    zoo(kind)
        .into_iter()
        .filter(|m| m.profile.batch_effect() > 2.0)
        .collect()
}

/// Models with a weak batching effect (β/α < 2), per §5.1.
pub fn zoo_weak(kind: GpuKind) -> Vec<ModelSpec> {
    zoo(kind)
        .into_iter()
        .filter(|m| m.profile.batch_effect() < 2.0)
        .collect()
}

/// Look a model up by name.
pub fn by_name(kind: GpuKind, name: &str) -> Option<ModelSpec> {
    zoo(kind).into_iter().find(|m| m.name == name)
}

/// N identical "ResNet50-like" variants (Fig 11 / Fig 13R: specialized
/// instantiations of the same architecture with a shared SLO).
pub fn resnet_like_variants(n: usize, slo_ms: f64, kind: GpuKind) -> Vec<ModelSpec> {
    let base = by_name(kind, "ResNet50").expect("ResNet50 in zoo");
    (0..n)
        .map(|i| {
            let mut m = ModelSpec::new(
                &format!("ResNet50-v{i}"),
                base.profile.alpha_ms,
                base.profile.beta_ms,
                slo_ms,
            );
            m.slo = Micros::from_millis_f64(slo_ms);
            m
        })
        .collect()
}

/// Synthetic profile family used by Fig 6a: α = 1 ms, β ∈ 1..15 ms,
/// SLO = 2·ℓ(8).
pub fn synthetic_beta_family(beta_ms: f64) -> ModelSpec {
    let profile = LatencyProfile::new(1.0, beta_ms);
    let slo = Micros(2 * profile.latency(8).0);
    let mut m = ModelSpec::new(&format!("synthetic-b{beta_ms}"), 1.0, beta_ms, 1.0);
    m.slo = slo;
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_have_expected_sizes() {
        assert_eq!(TABLE3_1080TI.len(), 35);
        assert_eq!(TABLE4_A100.len(), 37);
    }

    #[test]
    fn table3_ordered_by_descending_batch_effect() {
        // Paper: "Models listed in Table 1 are ordered by descending
        // batching effect (β/α ranging from 9.7 to 0.02)" — Table 3 is
        // likewise sorted.
        let z = zoo(GpuKind::Gtx1080Ti);
        for w in z.windows(2) {
            assert!(
                w[0].profile.batch_effect() >= w[1].profile.batch_effect() - 1e-9,
                "{} before {}",
                w[0].name,
                w[1].name
            );
        }
    }

    #[test]
    fn strong_weak_split() {
        let strong = zoo_strong(GpuKind::Gtx1080Ti);
        let weak = zoo_weak(GpuKind::Gtx1080Ti);
        assert!(strong.iter().all(|m| m.profile.batch_effect() > 2.0));
        assert!(weak.iter().all(|m| m.profile.batch_effect() < 2.0));
        assert_eq!(strong.len() + weak.len(), 35);
        assert!(strong.iter().any(|m| m.name == "ResNet50"));
        assert!(weak.iter().any(|m| m.name == "BERT"));
    }

    #[test]
    fn every_model_fits_batch_4_within_slo() {
        // Appendix C: "Latency SLO associated with each model ensures that
        // each model can run with batch size >= 4."
        for kind in [GpuKind::Gtx1080Ti, GpuKind::A100] {
            for m in zoo(kind) {
                assert!(
                    m.profile.max_batch_within(m.slo) >= 4,
                    "{} on {} only fits {}",
                    m.name,
                    kind.name(),
                    m.profile.max_batch_within(m.slo)
                );
            }
        }
    }

    #[test]
    fn lookup_and_variants() {
        let r50 = by_name(GpuKind::A100, "ResNet50").unwrap();
        assert!((r50.profile.alpha_ms - 0.268).abs() < 1e-9);
        let variants = resnet_like_variants(20, 100.0, GpuKind::Gtx1080Ti);
        assert_eq!(variants.len(), 20);
        assert_eq!(variants[7].slo, Micros::from_millis_f64(100.0));
    }

    #[test]
    fn synthetic_family_slo_rule() {
        let m = synthetic_beta_family(5.0);
        // ℓ(8) = 8 + 5 = 13ms, SLO = 26ms.
        assert_eq!(m.slo, Micros::from_millis_f64(26.0));
    }
}
