//! Latency profiles: the affine batching model ℓ(b) = αb + β (§2.1).
//!
//! Everything the deferred batch scheduler does — the schedulable window,
//! the staggered-execution analysis, the goodput bounds — is a function
//! of this profile, so it lives in `core` and is shared by the simulator,
//! the schedulers, and the analytical model.

use crate::core::time::Micros;

/// Affine latency profile ℓ(b) = αb + β, stored in milliseconds like the
/// paper's tables; evaluated to integer microseconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencyProfile {
    /// Per-request marginal cost (ms).
    pub alpha_ms: f64,
    /// Fixed batch-invocation cost (ms).
    pub beta_ms: f64,
}

impl LatencyProfile {
    pub fn new(alpha_ms: f64, beta_ms: f64) -> Self {
        assert!(alpha_ms > 0.0, "alpha must be positive");
        assert!(beta_ms >= 0.0, "beta must be non-negative");
        LatencyProfile { alpha_ms, beta_ms }
    }

    /// ℓ(b) in microseconds.
    #[inline]
    pub fn latency(&self, batch: u32) -> Micros {
        debug_assert!(batch > 0, "latency of empty batch");
        Micros::from_millis_f64(self.alpha_ms * batch as f64 + self.beta_ms)
    }

    /// Batching-effect strength β/α — the paper's classifier: strong if
    /// β/α > 2, weak otherwise (§5.1).
    #[inline]
    pub fn batch_effect(&self) -> f64 {
        self.beta_ms / self.alpha_ms
    }

    /// Largest b ≥ 0 with ℓ(b) ≤ budget (0 when even b=1 doesn't fit).
    pub fn max_batch_within(&self, budget: Micros) -> u32 {
        let budget_ms = budget.as_millis_f64();
        if budget_ms < self.alpha_ms + self.beta_ms {
            return 0;
        }
        let b = ((budget_ms - self.beta_ms) / self.alpha_ms).floor() as u32;
        // Guard against float rounding on the boundary.
        let mut b = b.max(1);
        while self.latency(b) > budget {
            b -= 1;
            if b == 0 {
                return 0;
            }
        }
        while self.latency(b + 1) <= budget {
            b += 1;
        }
        b
    }

    /// Per-GPU throughput at batch size b: b / ℓ(b), in requests/second.
    pub fn throughput(&self, batch: u32) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        batch as f64 / (self.latency(batch).as_secs_f64())
    }

    /// Asymptotic per-GPU throughput (1/α), requests/second.
    pub fn peak_throughput(&self) -> f64 {
        1_000.0 / self.alpha_ms
    }
}

/// A model entry: profile + latency SLO (+ memory, for partitioning).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub profile: LatencyProfile,
    pub slo: Micros,
    /// Static (weights) memory footprint in MB — partitioning constraint.
    pub static_mem_mb: f64,
    /// Peak runtime (activations) memory in MB.
    pub dyn_mem_mb: f64,
}

impl ModelSpec {
    pub fn new(name: &str, alpha_ms: f64, beta_ms: f64, slo_ms: f64) -> Self {
        ModelSpec {
            name: name.to_string(),
            profile: LatencyProfile::new(alpha_ms, beta_ms),
            slo: Micros::from_millis_f64(slo_ms),
            // Default memory model: proportional to compute cost — used
            // only when the experiment doesn't specify real numbers.
            static_mem_mb: 50.0 + 40.0 * beta_ms,
            dyn_mem_mb: 20.0 + 10.0 * alpha_ms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_affine() {
        // The paper's worked example: ℓ(b) = b + 5 (time units = ms here).
        let p = LatencyProfile::new(1.0, 5.0);
        assert_eq!(p.latency(4), Micros::from_millis_f64(9.0));
        assert_eq!(p.latency(5), Micros::from_millis_f64(10.0));
        assert!((p.batch_effect() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn max_batch_within_budget() {
        let p = LatencyProfile::new(1.0, 5.0);
        // ℓ(7) = 12 <= 12, ℓ(8) = 13 > 12.
        assert_eq!(p.max_batch_within(Micros::from_millis_f64(12.0)), 7);
        assert_eq!(p.max_batch_within(Micros::from_millis_f64(5.9)), 0);
        assert_eq!(p.max_batch_within(Micros::from_millis_f64(6.0)), 1);
        assert_eq!(p.max_batch_within(Micros::ZERO), 0);
    }

    #[test]
    fn max_batch_boundary_exact() {
        // ResNet50 on 1080Ti (Table 3): α=2.050, β=5.378, SLO 27ms.
        let p = LatencyProfile::new(2.050, 5.378);
        let b = p.max_batch_within(Micros::from_millis_f64(27.0));
        assert!(p.latency(b) <= Micros::from_millis_f64(27.0));
        assert!(p.latency(b + 1) > Micros::from_millis_f64(27.0));
    }

    #[test]
    fn throughput_grows_with_batch() {
        let p = LatencyProfile::new(1.053, 5.072); // ResNet50, Table 2
        assert!(p.throughput(16) > p.throughput(7));
        assert!(p.throughput(16) < p.peak_throughput());
        // Table 2 staggered column: 8 GPUs * ℓ(16)-batches ≈ 5839 r/s.
        let n_gpu_tput = 8.0 * p.throughput(16);
        assert!((n_gpu_tput - 5839.0).abs() / 5839.0 < 0.02, "{n_gpu_tput}");
    }
}
