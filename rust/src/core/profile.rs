//! Latency profiles: the affine batching model ℓ(b) = αb + β (§2.1).
//!
//! Everything the deferred batch scheduler does — the schedulable window,
//! the staggered-execution analysis, the goodput bounds — is a function
//! of this profile, so it lives in `core` and is shared by the simulator,
//! the schedulers, and the analytical model.

use crate::core::time::Micros;

/// Affine latency profile ℓ(b) = αb + β, stored in milliseconds like the
/// paper's tables; evaluated to integer microseconds.
///
/// The hot-path evaluations (`latency`, `max_batch_within`) are
/// closed-form integer arithmetic on `alpha_us`/`beta_us`, precomputed
/// at construction — the scheduler calls them on every arrival and
/// dispatch, and the seed's ms-float round-trip plus boundary-correction
/// loops dominated that path. α and β are quantized to whole
/// microseconds (the resolution of [`Micros`] and of the paper's
/// tables); the float fields remain for reporting and the analytical
/// model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencyProfile {
    /// Per-request marginal cost (ms).
    pub alpha_ms: f64,
    /// Fixed batch-invocation cost (ms).
    pub beta_ms: f64,
    /// `round(alpha_ms · 1000)`, clamped to ≥ 1 µs (the integer model's
    /// resolution floor; also the `max_batch_within` division guard).
    alpha_us: u64,
    /// `round(beta_ms · 1000)`.
    beta_us: u64,
}

impl LatencyProfile {
    pub fn new(alpha_ms: f64, beta_ms: f64) -> Self {
        assert!(alpha_ms > 0.0, "alpha must be positive");
        assert!(beta_ms >= 0.0, "beta must be non-negative");
        let alpha_us = Micros::from_millis_f64(alpha_ms).0.max(1);
        let beta_us = Micros::from_millis_f64(beta_ms).0;
        LatencyProfile {
            alpha_ms,
            beta_ms,
            alpha_us,
            beta_us,
        }
    }

    /// α in integer microseconds (≥ 1).
    #[inline]
    pub fn alpha_us(&self) -> u64 {
        self.alpha_us
    }

    /// β in integer microseconds.
    #[inline]
    pub fn beta_us(&self) -> u64 {
        self.beta_us
    }

    /// ℓ(b) in microseconds: `α_us·b + β_us`, exact.
    #[inline]
    pub fn latency(&self, batch: u32) -> Micros {
        debug_assert!(batch > 0, "latency of empty batch");
        Micros(
            self.alpha_us
                .saturating_mul(batch as u64)
                .saturating_add(self.beta_us),
        )
    }

    /// Batching-effect strength β/α — the paper's classifier: strong if
    /// β/α > 2, weak otherwise (§5.1).
    #[inline]
    pub fn batch_effect(&self) -> f64 {
        self.beta_ms / self.alpha_ms
    }

    /// Largest b ≥ 0 with ℓ(b) ≤ budget (0 when even b=1 doesn't fit).
    /// Closed form: `⌊(budget − β) / α⌋` over integer microseconds — no
    /// float round-trip, no correction loops.
    #[inline]
    pub fn max_batch_within(&self, budget: Micros) -> u32 {
        if budget.0 < self.alpha_us.saturating_add(self.beta_us) {
            return 0;
        }
        ((budget.0 - self.beta_us) / self.alpha_us).min(u32::MAX as u64) as u32
    }

    /// Per-GPU throughput at batch size b: b / ℓ(b), in requests/second.
    pub fn throughput(&self, batch: u32) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        batch as f64 / (self.latency(batch).as_secs_f64())
    }

    /// Asymptotic per-GPU throughput (1/α), requests/second.
    pub fn peak_throughput(&self) -> f64 {
        1_000.0 / self.alpha_ms
    }
}

/// The seed's float implementations, kept verbatim as the ground truth
/// for the integer hot path: `rust/tests/hotpath_equivalence.rs` checks
/// the closed-form integer math against these across random µs-grain
/// α/β/budget, and `bench_hotpath` times both so every run records the
/// float→integer speedup.
pub mod reference {
    use crate::core::time::Micros;

    /// ℓ(b) via the ms-float round-trip (seed `LatencyProfile::latency`).
    pub fn latency(alpha_ms: f64, beta_ms: f64, batch: u32) -> Micros {
        Micros::from_millis_f64(alpha_ms * batch as f64 + beta_ms)
    }

    /// Seed `max_batch_within`: float estimate plus boundary-correction
    /// loops. Note the early-out guard compares ms floats, so exactly at
    /// the ℓ(1) boundary it can be one ulp off — the equivalence tests
    /// account for that corner.
    pub fn max_batch_within(alpha_ms: f64, beta_ms: f64, budget: Micros) -> u32 {
        let budget_ms = budget.as_millis_f64();
        if budget_ms < alpha_ms + beta_ms {
            return 0;
        }
        let b = ((budget_ms - beta_ms) / alpha_ms).floor() as u32;
        let mut b = b.max(1);
        while latency(alpha_ms, beta_ms, b) > budget {
            b -= 1;
            if b == 0 {
                return 0;
            }
        }
        while latency(alpha_ms, beta_ms, b + 1) <= budget {
            b += 1;
        }
        b
    }

    /// Seed throughput b / ℓ(b), requests/second.
    pub fn throughput(alpha_ms: f64, beta_ms: f64, batch: u32) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        batch as f64 / latency(alpha_ms, beta_ms, batch).as_secs_f64()
    }

    /// Seed shedding target (`DeferredScheduler::target_batch` before
    /// memoization), built on the float pieces above.
    pub fn target_batch(
        alpha_ms: f64,
        beta_ms: f64,
        slo: Micros,
        n: usize,
        max_batch: u32,
    ) -> u32 {
        let budget = Micros((slo.0 as f64 / (1.0 + 1.0 / n.max(1) as f64)) as u64);
        let mut b_star = max_batch_within(alpha_ms, beta_ms, budget);
        if max_batch > 0 {
            b_star = b_star.min(max_batch);
        }
        if b_star <= 1 {
            return b_star;
        }
        let goal = 0.9 * throughput(alpha_ms, beta_ms, b_star);
        for b in 1..b_star {
            if throughput(alpha_ms, beta_ms, b) >= goal {
                return b;
            }
        }
        b_star
    }
}

/// A model entry: profile + latency SLO (+ memory, for partitioning).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub profile: LatencyProfile,
    pub slo: Micros,
    /// Static (weights) memory footprint in MB — partitioning constraint.
    pub static_mem_mb: f64,
    /// Peak runtime (activations) memory in MB.
    pub dyn_mem_mb: f64,
}

impl ModelSpec {
    pub fn new(name: &str, alpha_ms: f64, beta_ms: f64, slo_ms: f64) -> Self {
        ModelSpec {
            name: name.to_string(),
            profile: LatencyProfile::new(alpha_ms, beta_ms),
            slo: Micros::from_millis_f64(slo_ms),
            // Default memory model: proportional to compute cost — used
            // only when the experiment doesn't specify real numbers.
            static_mem_mb: 50.0 + 40.0 * beta_ms,
            dyn_mem_mb: 20.0 + 10.0 * alpha_ms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_affine() {
        // The paper's worked example: ℓ(b) = b + 5 (time units = ms here).
        let p = LatencyProfile::new(1.0, 5.0);
        assert_eq!(p.latency(4), Micros::from_millis_f64(9.0));
        assert_eq!(p.latency(5), Micros::from_millis_f64(10.0));
        assert!((p.batch_effect() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn max_batch_within_budget() {
        let p = LatencyProfile::new(1.0, 5.0);
        // ℓ(7) = 12 <= 12, ℓ(8) = 13 > 12.
        assert_eq!(p.max_batch_within(Micros::from_millis_f64(12.0)), 7);
        assert_eq!(p.max_batch_within(Micros::from_millis_f64(5.9)), 0);
        assert_eq!(p.max_batch_within(Micros::from_millis_f64(6.0)), 1);
        assert_eq!(p.max_batch_within(Micros::ZERO), 0);
    }

    #[test]
    fn max_batch_boundary_exact() {
        // ResNet50 on 1080Ti (Table 3): α=2.050, β=5.378, SLO 27ms.
        let p = LatencyProfile::new(2.050, 5.378);
        let b = p.max_batch_within(Micros::from_millis_f64(27.0));
        assert!(p.latency(b) <= Micros::from_millis_f64(27.0));
        assert!(p.latency(b + 1) > Micros::from_millis_f64(27.0));
    }

    #[test]
    fn integer_fields_precomputed() {
        let p = LatencyProfile::new(2.050, 5.378);
        assert_eq!(p.alpha_us(), 2_050);
        assert_eq!(p.beta_us(), 5_378);
        assert_eq!(p.latency(3), Micros(3 * 2_050 + 5_378));
        // Sub-µs α clamps to the 1 µs resolution floor instead of
        // dividing by zero in `max_batch_within`.
        let tiny = LatencyProfile::new(1e-6, 0.0);
        assert_eq!(tiny.alpha_us(), 1);
        assert_eq!(tiny.max_batch_within(Micros(5)), 5);
    }

    #[test]
    fn integer_matches_reference_float_on_table_profiles() {
        // Spot-check the closed form against the seed implementation on
        // the paper's Table 2/3 profiles (the property tests sweep
        // random µs-grain profiles).
        for &(a, b) in &[(1.0, 5.0), (2.050, 5.378), (1.053, 5.072), (0.268, 5.172)] {
            let p = LatencyProfile::new(a, b);
            for batch in 1..64u32 {
                assert_eq!(p.latency(batch), reference::latency(a, b, batch));
            }
            for budget_us in (0..60_000u64).step_by(137) {
                let budget = Micros(budget_us);
                assert_eq!(
                    p.max_batch_within(budget),
                    reference::max_batch_within(a, b, budget),
                    "α={a} β={b} budget={budget:?}"
                );
            }
        }
    }

    #[test]
    fn throughput_grows_with_batch() {
        let p = LatencyProfile::new(1.053, 5.072); // ResNet50, Table 2
        assert!(p.throughput(16) > p.throughput(7));
        assert!(p.throughput(16) < p.peak_throughput());
        // Table 2 staggered column: 8 GPUs * ℓ(16)-batches ≈ 5839 r/s.
        let n_gpu_tput = 8.0 * p.throughput(16);
        assert!((n_gpu_tput - 5839.0).abs() / 5839.0 < 0.02, "{n_gpu_tput}");
    }
}
