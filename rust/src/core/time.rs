//! Virtual time. All scheduling math runs on integer microseconds —
//! `Micros` — so simulations are exact and deterministic (no float drift
//! in event ordering). Wall-clock serving maps `Instant`s onto the same
//! type.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in time (or a duration) in microseconds.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Micros(pub u64);

impl Micros {
    pub const ZERO: Micros = Micros(0);
    pub const MAX: Micros = Micros(u64::MAX);

    #[inline]
    pub fn from_millis_f64(ms: f64) -> Micros {
        debug_assert!(ms >= 0.0, "negative duration {ms}");
        Micros((ms * 1_000.0).round() as u64)
    }

    #[inline]
    pub fn from_secs_f64(s: f64) -> Micros {
        Micros((s * 1_000_000.0).round() as u64)
    }

    #[inline]
    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1_000.0
    }

    #[inline]
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1_000_000.0
    }

    #[inline]
    pub fn saturating_sub(self, rhs: Micros) -> Micros {
        Micros(self.0.saturating_sub(rhs.0))
    }

    /// Saturating add for deadline arithmetic near `u64::MAX` (e.g. the
    /// "revalidate just past expiry" timer at `latest + 1`): a plain add
    /// would wrap a ~`u64::MAX` `latest` to 0 in release builds.
    #[inline]
    pub fn saturating_add(self, rhs: Micros) -> Micros {
        Micros(self.0.saturating_add(rhs.0))
    }

    #[inline]
    pub fn min(self, other: Micros) -> Micros {
        Micros(self.0.min(other.0))
    }

    #[inline]
    pub fn max(self, other: Micros) -> Micros {
        Micros(self.0.max(other.0))
    }
}

impl Add for Micros {
    type Output = Micros;
    /// Panics on overflow in **all** build profiles, mirroring `Sub`'s
    /// contract: a plain `u64` add wraps silently in release, and a
    /// wire peer can supply times near `u64::MAX` (e.g. `free_at`), so
    /// a wrapping deadline is a scheduling corruption, not a rounding
    /// error. Paths where saturation is the intended edge-case behavior
    /// must say so with [`Micros::saturating_add`].
    #[inline]
    fn add(self, rhs: Micros) -> Micros {
        match self.0.checked_add(rhs.0) {
            Some(v) => Micros(v),
            None => panic!("time overflow {} + {}", self.0, rhs.0),
        }
    }
}

impl AddAssign for Micros {
    #[inline]
    fn add_assign(&mut self, rhs: Micros) {
        *self = *self + rhs;
    }
}

impl Sub for Micros {
    type Output = Micros;
    /// Panics on underflow in **all** build profiles. A `debug_assert`
    /// here once let `--release` wrap `d - a` to ~u64::MAX in the
    /// deferred scheduler's shedding target, silently inflating the SLO
    /// budget; hot paths that may legitimately cross zero must say so
    /// explicitly with [`Micros::saturating_sub`].
    #[inline]
    fn sub(self, rhs: Micros) -> Micros {
        assert!(self.0 >= rhs.0, "time underflow {} - {}", self.0, rhs.0);
        Micros(self.0 - rhs.0)
    }
}

impl fmt::Debug for Micros {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}us", self.0)
    }
}

impl fmt::Display for Micros {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1_000_000 {
            write!(f, "{:.3}s", self.as_secs_f64())
        } else if self.0 >= 1_000 {
            write!(f, "{:.3}ms", self.as_millis_f64())
        } else {
            write!(f, "{}us", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_round_trip() {
        assert_eq!(Micros::from_millis_f64(25.0).0, 25_000);
        assert_eq!(Micros::from_secs_f64(1.5).0, 1_500_000);
        assert!((Micros(25_000).as_millis_f64() - 25.0).abs() < 1e-12);
    }

    #[test]
    fn arithmetic() {
        let a = Micros(100) + Micros(50);
        assert_eq!(a, Micros(150));
        assert_eq!(a - Micros(150), Micros::ZERO);
        assert_eq!(Micros(10).saturating_sub(Micros(20)), Micros::ZERO);
        assert_eq!(Micros(u64::MAX).saturating_add(Micros(1)), Micros::MAX);
        assert_eq!(Micros(5).max(Micros(9)), Micros(9));
    }

    /// Regression: `Sub` must panic (not wrap) in release builds too.
    #[test]
    #[should_panic(expected = "time underflow")]
    fn sub_underflow_panics_in_all_profiles() {
        let _ = Micros(1) - Micros(2);
    }

    /// Regression: `Add` must panic (not wrap) in release builds too —
    /// the other half of the PR 1 wrap class.
    #[test]
    #[should_panic(expected = "time overflow")]
    fn add_overflow_panics_in_all_profiles() {
        let _ = Micros(u64::MAX) + Micros(1);
    }

    #[test]
    #[should_panic(expected = "time overflow")]
    fn add_assign_overflow_panics_in_all_profiles() {
        let mut t = Micros(u64::MAX);
        t += Micros(1);
    }

    #[test]
    fn display_units() {
        assert_eq!(Micros(12).to_string(), "12us");
        assert_eq!(Micros(12_500).to_string(), "12.500ms");
        assert_eq!(Micros(2_000_000).to_string(), "2.000s");
    }
}
