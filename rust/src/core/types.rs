//! Core identifiers and request/batch records shared by the simulator,
//! the schedulers, and the real-time coordinator.

use crate::core::time::Micros;

/// Model identifier — index into the experiment's model table.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct ModelId(pub u32);

/// GPU identifier. Symphony's "pick the smallest identifier" rule (§3.2)
/// makes the ordering semantically meaningful: low ids consolidate load,
/// high ids go idle and can be reclaimed by the autoscaler.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct GpuId(pub u32);

/// Request identifier, unique within a run.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct RequestId(pub u64);

/// An inference request: which model, when it arrived, when it must be
/// done. `deadline = arrival + SLO` (frontends attach deadlines, §4.1).
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: RequestId,
    pub model: ModelId,
    pub arrival: Micros,
    pub deadline: Micros,
}

impl Request {
    pub fn slo(&self) -> Micros {
        self.deadline - self.arrival
    }
}

/// A batch dispatched to a GPU.
#[derive(Clone, Debug)]
pub struct Batch {
    pub model: ModelId,
    pub gpu: GpuId,
    pub requests: Vec<RequestId>,
    /// When the scheduler issued the dispatch.
    pub dispatched_at: Micros,
    /// When the GPU begins executing (>= dispatched_at under network delay).
    pub start: Micros,
    /// When execution completes.
    pub end: Micros,
}

impl Batch {
    pub fn size(&self) -> usize {
        self.requests.len()
    }
}

/// Terminal state of a request.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OutcomeKind {
    /// Completed at or before its deadline.
    Good,
    /// Completed after its deadline (an SLO violation that still ran).
    Late,
    /// Dropped by the scheduler (could not meet the deadline).
    Dropped,
    /// Still queued/in-flight when the experiment ended (excluded from
    /// goodput accounting).
    Unfinished,
}

/// Per-request outcome record consumed by the metrics layer.
#[derive(Clone, Copy, Debug)]
pub struct Outcome {
    pub id: RequestId,
    pub model: ModelId,
    pub arrival: Micros,
    pub deadline: Micros,
    /// Batch execution start (queueing delay = start - arrival), if run.
    pub start: Option<Micros>,
    /// Completion time, if run.
    pub end: Option<Micros>,
    pub kind: OutcomeKind,
    /// Batch size the request executed in, if run.
    pub batch_size: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_slo() {
        let r = Request {
            id: RequestId(1),
            model: ModelId(0),
            arrival: Micros(1_000),
            deadline: Micros(26_000),
        };
        assert_eq!(r.slo(), Micros(25_000));
    }

    #[test]
    fn ids_order() {
        assert!(GpuId(0) < GpuId(1));
        assert!(ModelId(2) > ModelId(1));
    }
}
