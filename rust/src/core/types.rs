//! Core identifiers and request/batch records shared by the simulator,
//! the schedulers, and the real-time coordinator.

use crate::core::time::Micros;

/// Model identifier — index into the experiment's model table.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct ModelId(pub u32);

/// GPU identifier. Symphony's "pick the smallest identifier" rule (§3.2)
/// makes the ordering semantically meaningful: low ids consolidate load,
/// high ids go idle and can be reclaimed by the autoscaler.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct GpuId(pub u32);

/// Request identifier, unique within a run.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct RequestId(pub u64);

/// Inline capacity of [`ReqList`]: batches and drop sets up to this size
/// live on the stack, so steady-state dispatching touches no allocator.
/// Sized for the paper's typical batches (Fig 1 medians ≤ 16).
pub const REQLIST_INLINE: usize = 16;

#[derive(Clone, Debug)]
enum ReqListRepr {
    Inline {
        len: u8,
        buf: [RequestId; REQLIST_INLINE],
    },
    Heap(Vec<RequestId>),
}

/// A hand-rolled inline small-vec of request ids (zero registry deps).
/// Carried by `scheduler::Command::{Dispatch, Drop}` so the per-event
/// hot path is allocation-free for batches ≤ [`REQLIST_INLINE`]; larger
/// batches spill to a heap `Vec` transparently.
#[derive(Clone, Debug)]
pub struct ReqList(ReqListRepr);

impl ReqList {
    pub fn new() -> Self {
        ReqList(ReqListRepr::Inline {
            len: 0,
            buf: [RequestId(0); REQLIST_INLINE],
        })
    }

    /// Inline when `n` fits, pre-sized heap otherwise.
    pub fn with_capacity(n: usize) -> Self {
        if n <= REQLIST_INLINE {
            ReqList::new()
        } else {
            ReqList(ReqListRepr::Heap(Vec::with_capacity(n)))
        }
    }

    pub fn from_slice(ids: &[RequestId]) -> Self {
        let mut out = ReqList::with_capacity(ids.len());
        for &id in ids {
            out.push(id);
        }
        out
    }

    pub fn push(&mut self, id: RequestId) {
        match &mut self.0 {
            ReqListRepr::Inline { len, buf } => {
                if (*len as usize) < REQLIST_INLINE {
                    buf[*len as usize] = id;
                    *len += 1;
                } else {
                    let mut v = Vec::with_capacity(REQLIST_INLINE * 2);
                    v.extend_from_slice(&buf[..]);
                    v.push(id);
                    self.0 = ReqListRepr::Heap(v);
                }
            }
            ReqListRepr::Heap(v) => v.push(id),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn as_slice(&self) -> &[RequestId] {
        match &self.0 {
            ReqListRepr::Inline { len, buf } => &buf[..*len as usize],
            ReqListRepr::Heap(v) => v.as_slice(),
        }
    }

    #[inline]
    pub fn iter(&self) -> std::slice::Iter<'_, RequestId> {
        self.as_slice().iter()
    }

    pub fn into_vec(self) -> Vec<RequestId> {
        match self.0 {
            ReqListRepr::Inline { len, buf } => buf[..len as usize].to_vec(),
            ReqListRepr::Heap(v) => v,
        }
    }
}

impl Default for ReqList {
    fn default() -> Self {
        ReqList::new()
    }
}

impl std::ops::Deref for ReqList {
    type Target = [RequestId];
    #[inline]
    fn deref(&self) -> &[RequestId] {
        self.as_slice()
    }
}

impl From<Vec<RequestId>> for ReqList {
    fn from(v: Vec<RequestId>) -> Self {
        ReqList(ReqListRepr::Heap(v))
    }
}

impl FromIterator<RequestId> for ReqList {
    fn from_iter<I: IntoIterator<Item = RequestId>>(iter: I) -> Self {
        let mut out = ReqList::new();
        for id in iter {
            out.push(id);
        }
        out
    }
}

impl<'a> IntoIterator for &'a ReqList {
    type Item = &'a RequestId;
    type IntoIter = std::slice::Iter<'a, RequestId>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl PartialEq for ReqList {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for ReqList {}

impl PartialEq<Vec<RequestId>> for ReqList {
    fn eq(&self, other: &Vec<RequestId>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[RequestId]> for ReqList {
    fn eq(&self, other: &[RequestId]) -> bool {
        self.as_slice() == other
    }
}

/// An inference request: which model, when it arrived, when it must be
/// done. `deadline = arrival + SLO` (frontends attach deadlines, §4.1).
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: RequestId,
    pub model: ModelId,
    pub arrival: Micros,
    pub deadline: Micros,
}

/// Inline capacity of [`ReqBurst`]: coalesced frontend bursts and
/// dispatched batches up to this size live on the stack, so the
/// steady-state ingest → dispatch path touches no allocator (the same
/// sizing rationale as [`REQLIST_INLINE`]).
pub const REQBURST_INLINE: usize = 16;

const EMPTY_REQUEST: Request = Request {
    id: RequestId(0),
    model: ModelId(0),
    arrival: Micros(0),
    deadline: Micros(0),
};

#[derive(Clone, Debug)]
enum ReqBurstRepr {
    Inline {
        len: u8,
        buf: [Request; REQBURST_INLINE],
    },
    Heap(Vec<Request>),
}

/// [`ReqList`]'s sibling for full `Request` records: the inline
/// small-vec carried by the coordinator's burst messages
/// (`ToModel::Requests`, `ToBackend::Execute`, `Completion`). `ReqList`
/// stays id-only for the sim-side schedulers; the live coordinator
/// moves whole requests between threads, so it needs the records
/// themselves. Bursts ≤ [`REQBURST_INLINE`] never allocate; larger ones
/// spill to a heap `Vec` transparently.
#[derive(Clone, Debug)]
pub struct ReqBurst(ReqBurstRepr);

impl ReqBurst {
    pub fn new() -> Self {
        ReqBurst(ReqBurstRepr::Inline {
            len: 0,
            buf: [EMPTY_REQUEST; REQBURST_INLINE],
        })
    }

    /// Inline when `n` fits, pre-sized heap otherwise.
    pub fn with_capacity(n: usize) -> Self {
        if n <= REQBURST_INLINE {
            ReqBurst::new()
        } else {
            ReqBurst(ReqBurstRepr::Heap(Vec::with_capacity(n)))
        }
    }

    pub fn from_slice(reqs: &[Request]) -> Self {
        let mut out = ReqBurst::with_capacity(reqs.len());
        for &r in reqs {
            out.push(r);
        }
        out
    }

    pub fn push(&mut self, r: Request) {
        match &mut self.0 {
            ReqBurstRepr::Inline { len, buf } => {
                if (*len as usize) < REQBURST_INLINE {
                    buf[*len as usize] = r;
                    *len += 1;
                } else {
                    let mut v = Vec::with_capacity(REQBURST_INLINE * 2);
                    v.extend_from_slice(&buf[..]);
                    v.push(r);
                    self.0 = ReqBurstRepr::Heap(v);
                }
            }
            ReqBurstRepr::Heap(v) => v.push(r),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn as_slice(&self) -> &[Request] {
        match &self.0 {
            ReqBurstRepr::Inline { len, buf } => &buf[..*len as usize],
            ReqBurstRepr::Heap(v) => v.as_slice(),
        }
    }

    #[inline]
    pub fn iter(&self) -> std::slice::Iter<'_, Request> {
        self.as_slice().iter()
    }

    pub fn into_vec(self) -> Vec<Request> {
        match self.0 {
            ReqBurstRepr::Inline { len, buf } => buf[..len as usize].to_vec(),
            ReqBurstRepr::Heap(v) => v,
        }
    }
}

impl Default for ReqBurst {
    fn default() -> Self {
        ReqBurst::new()
    }
}

impl std::ops::Deref for ReqBurst {
    type Target = [Request];
    #[inline]
    fn deref(&self) -> &[Request] {
        self.as_slice()
    }
}

impl FromIterator<Request> for ReqBurst {
    fn from_iter<I: IntoIterator<Item = Request>>(iter: I) -> Self {
        let mut out = ReqBurst::new();
        for r in iter {
            out.push(r);
        }
        out
    }
}

impl<'a> IntoIterator for &'a ReqBurst {
    type Item = &'a Request;
    type IntoIter = std::slice::Iter<'a, Request>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl Request {
    pub fn slo(&self) -> Micros {
        // A wire peer may hand us deadline < arrival; a zero SLO sheds
        // the request instead of panicking the worker.
        self.deadline.saturating_sub(self.arrival)
    }
}

/// A batch dispatched to a GPU.
#[derive(Clone, Debug)]
pub struct Batch {
    pub model: ModelId,
    pub gpu: GpuId,
    pub requests: Vec<RequestId>,
    /// When the scheduler issued the dispatch.
    pub dispatched_at: Micros,
    /// When the GPU begins executing (>= dispatched_at under network delay).
    pub start: Micros,
    /// When execution completes.
    pub end: Micros,
}

impl Batch {
    pub fn size(&self) -> usize {
        self.requests.len()
    }
}

/// Terminal state of a request.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OutcomeKind {
    /// Completed at or before its deadline.
    Good,
    /// Completed after its deadline (an SLO violation that still ran).
    Late,
    /// Dropped by the scheduler (could not meet the deadline).
    Dropped,
    /// Still queued/in-flight when the experiment ended (excluded from
    /// goodput accounting).
    Unfinished,
}

/// Per-request outcome record consumed by the metrics layer.
#[derive(Clone, Copy, Debug)]
pub struct Outcome {
    pub id: RequestId,
    pub model: ModelId,
    pub arrival: Micros,
    pub deadline: Micros,
    /// Batch execution start (queueing delay = start - arrival), if run.
    pub start: Option<Micros>,
    /// Completion time, if run.
    pub end: Option<Micros>,
    pub kind: OutcomeKind,
    /// Batch size the request executed in, if run.
    pub batch_size: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_slo() {
        let r = Request {
            id: RequestId(1),
            model: ModelId(0),
            arrival: Micros(1_000),
            deadline: Micros(26_000),
        };
        assert_eq!(r.slo(), Micros(25_000));
    }

    #[test]
    fn ids_order() {
        assert!(GpuId(0) < GpuId(1));
        assert!(ModelId(2) > ModelId(1));
    }

    #[test]
    fn reqlist_inline_then_spills() {
        let mut l = ReqList::new();
        assert!(l.is_empty());
        for i in 0..REQLIST_INLINE as u64 {
            l.push(RequestId(i));
        }
        assert_eq!(l.len(), REQLIST_INLINE);
        assert_eq!(l[0], RequestId(0));
        // One past the inline capacity spills to the heap, preserving
        // contents and order.
        l.push(RequestId(99));
        assert_eq!(l.len(), REQLIST_INLINE + 1);
        let expect: Vec<RequestId> = (0..REQLIST_INLINE as u64)
            .map(RequestId)
            .chain(std::iter::once(RequestId(99)))
            .collect();
        assert_eq!(l, expect);
        assert_eq!(l.clone().into_vec(), expect);
    }

    #[test]
    fn reqburst_inline_then_spills() {
        let req = |i: u64| Request {
            id: RequestId(i),
            model: ModelId(0),
            arrival: Micros(i),
            deadline: Micros(i + 1_000),
        };
        let mut b = ReqBurst::new();
        assert!(b.is_empty());
        for i in 0..REQBURST_INLINE as u64 {
            b.push(req(i));
        }
        assert_eq!(b.len(), REQBURST_INLINE);
        // One past the inline capacity spills to the heap, preserving
        // contents and order.
        b.push(req(99));
        assert_eq!(b.len(), REQBURST_INLINE + 1);
        let ids: Vec<u64> = b.iter().map(|r| r.id.0).collect();
        let expect: Vec<u64> = (0..REQBURST_INLINE as u64).chain([99]).collect();
        assert_eq!(ids, expect);
        // Round trips.
        let v = b.clone().into_vec();
        let b2 = ReqBurst::from_slice(&v);
        assert_eq!(b2.len(), v.len());
        let collected: ReqBurst = v.iter().copied().collect();
        assert_eq!(collected[0].id, RequestId(0));
        assert_eq!((&collected).into_iter().count(), REQBURST_INLINE + 1);
    }

    #[test]
    fn reqlist_conversions() {
        let v = vec![RequestId(3), RequestId(4)];
        let l: ReqList = v.clone().into();
        assert_eq!(l, v);
        let l2 = ReqList::from_slice(&v);
        assert_eq!(l2, l);
        let collected: ReqList = v.iter().copied().collect();
        assert_eq!(collected.as_slice(), &v[..]);
        let sum: u64 = (&collected).into_iter().map(|r| r.0).sum();
        assert_eq!(sum, 7);
    }
}
