//! One entry point per paper table/figure (DESIGN.md §5). Each returns
//! a [`Table`] whose rows mirror what the paper plots; benches print it
//! and write `results/<id>.tsv`.
//!
//! Simulation lengths are sized so `cargo bench` completes in minutes;
//! set `SYMPHONY_FULL_SWEEP=1` for the full Fig 7 grid and longer runs.

use std::time::Duration;

use crate::autoscale::{Advice, AutoscaleConfig, AutoscaleController, WindowStats};
use crate::core::model_zoo::{self, GpuKind};
use crate::core::profile::ModelSpec;
use crate::core::time::Micros;
use crate::harness::goodput::GoodputExperiment;
use crate::harness::systems::SystemKind;
use crate::metrics::Metrics;
use crate::partition;
use crate::scheduler::analytical;
use crate::sim::{ClusterOps, Engine, EngineDriver, NetworkModel, SimConfig};
use crate::util::par::par_map;
use crate::util::rng::Rng;
use crate::util::stats::{percentile, Histogram};
use crate::util::table::{f1, f2, pct, Table};
use crate::workload::trace::TraceSpec;
use crate::workload::{ArrivalKind, ArrivalStream, Popularity, Workload, WorkloadSpec};

fn full_sweep() -> bool {
    std::env::var("SYMPHONY_FULL_SWEEP").map_or(false, |v| v != "0" && !v.is_empty())
}

fn goodput_of(exp: &GoodputExperiment, sys: SystemKind) -> f64 {
    exp.goodput(|e| sys.build(&e.models, e.num_gpus, e.network.bound()))
        .goodput
}

/// Metrics of `sys` serving `exp`'s workload at rate `rate`.
fn metrics_at(exp: &GoodputExperiment, sys: SystemKind, rate: f64) -> Metrics {
    let spec = WorkloadSpec::new(exp.models.clone(), rate)
        .popularity(exp.popularity)
        .gamma_shape(exp.gamma_shape)
        .seed(exp.seed);
    let cfg = SimConfig::new(exp.num_gpus, Micros::from_secs_f64(exp.sim_secs))
        .network(exp.network)
        .warmup(Micros::from_secs_f64(exp.warmup_secs))
        .seed(exp.seed ^ 0x5A5A);
    Engine::new(
        spec.build(),
        sys.build(&exp.models, exp.num_gpus, exp.network.bound()),
        cfg,
    )
    .run()
    .metrics
}

// ---------------------------------------------------------------------
// Figure 1 — batch size distribution
// ---------------------------------------------------------------------

/// Fig 1: batch-size distribution of ResNet50 (25 ms) and
/// InceptionResNetV2 (70 ms), one model on 8 GPUs, each system driven at
/// its own goodput.
pub fn fig01_batch_sizes() -> Table {
    let cases = [
        model_zoo::resnet50_table2(),
        model_zoo::inception_resnet_v2_table2(),
    ];
    let mut table = Table::new(vec![
        "model", "system", "goodput", "batch_p25", "batch_median", "batch_p75",
        "batch_p95",
    ]);
    for model in cases {
        let exp = GoodputExperiment::new(vec![model.clone()], 8).sim_secs(8.0);
        let rows = par_map(SystemKind::HEADLINE.to_vec(), |&sys| {
            let res = exp.goodput(|e| sys.build(&e.models, e.num_gpus, Micros::ZERO));
            let hist = res.metrics.batch_hist_all();
            (
                sys.label(),
                res.goodput,
                hist.quantile(0.25),
                hist.median(),
                hist.quantile(0.75),
                hist.quantile(0.95),
            )
        });
        for (label, goodput, q25, med, q75, q95) in rows {
            table.row(vec![
                model.name.clone(),
                label,
                f1(goodput),
                q25.to_string(),
                med.to_string(),
                q75.to_string(),
                q95.to_string(),
            ]);
        }
    }
    table
}

// ---------------------------------------------------------------------
// Figure 2 — goodput stability + load-proportional GPU usage
// ---------------------------------------------------------------------

/// Fig 2: 10 ResNet-like models, 100 ms SLO, 24 emulated GPUs; sweep the
/// offered load and report goodput (left) and GPU utilization (right).
pub fn fig02_flattop() -> Table {
    let models = model_zoo::resnet_like_variants(10, 100.0, GpuKind::Gtx1080Ti);
    let exp = GoodputExperiment::new(models, 24).sim_secs(6.0);
    let loads: Vec<f64> = (1..=10).map(|i| i as f64 * 3_000.0).collect();
    let mut table = Table::new(vec![
        "offered_rps", "system", "goodput", "bad_rate", "utilization", "gpus_used",
    ]);
    let mut jobs = Vec::new();
    for &load in &loads {
        for sys in SystemKind::HEADLINE {
            jobs.push((load, sys));
        }
    }
    let rows = par_map(jobs, |&(load, sys)| {
        let m = metrics_at(&exp, sys, load);
        (
            load,
            sys.label(),
            m.goodput(),
            m.bad_fraction(),
            m.utilization(24),
            m.gpus_used(),
        )
    });
    for (load, label, goodput, bad, util, used) in rows {
        table.row(vec![
            f1(load),
            label,
            f1(goodput),
            pct(bad),
            pct(util),
            used.to_string(),
        ]);
    }
    table
}

// ---------------------------------------------------------------------
// Figures 4 & 5 — worked-example traces
// ---------------------------------------------------------------------

/// Build the §3.3 workload: ℓ(b) = b + 5 ms, SLO 12 ms, R_i at
/// 0.75·(i−1) ms; optionally skipping R13..R15 (Fig 5).
pub fn worked_example_workload(n: usize, skip_13_15: bool) -> (Vec<ModelSpec>, Workload) {
    let model = ModelSpec::new("example", 1.0, 5.0, 12.0);
    let times: Vec<Micros> = (0..n)
        .filter(|&i| !(skip_13_15 && (12..15).contains(&i)))
        .map(|i| Micros::from_millis_f64(0.75 * i as f64))
        .collect();
    let w = Workload::explicit(vec![model.clone()], vec![times]);
    (vec![model], w)
}

/// Render an execution trace as ASCII rows per GPU (Figs 4/5).
pub fn render_trace(trace: &[crate::sim::TraceEntry], gpus: usize, until_ms: f64) -> String {
    let scale = 1.0; // 1 char per ms
    let width = (until_ms * scale) as usize + 2;
    let mut rows = vec![vec![b'.'; width]; gpus];
    for t in trace {
        let s = ((t.start.as_millis_f64() * scale) as usize).min(width - 1);
        let e = ((t.end.as_millis_f64() * scale) as usize).min(width - 1);
        let c = if t.preempted {
            b'x'
        } else {
            b'0' + (t.size as u8).min(9)
        };
        for x in s..=e.max(s) {
            rows[t.gpu.0 as usize][x] = c;
        }
    }
    let mut out = String::new();
    for (g, row) in rows.iter().enumerate() {
        out.push_str(&format!("GPU{g} |{}|\n", String::from_utf8_lossy(row)));
    }
    out
}

/// Fig 4 / Fig 5: deferred vs eager traces, plus summary counters.
pub fn fig04_05_traces() -> Table {
    let mut table = Table::new(vec![
        "scenario", "system", "good", "dropped", "median_batch",
    ]);
    for (scenario, skip) in [("fig4_uniform", false), ("fig5_missing", true)] {
        for sys in [SystemKind::Symphony, SystemKind::Eager] {
            let (models, workload) = worked_example_workload(64, skip);
            let cfg = SimConfig::new(3, Micros::from_secs_f64(0.2)).trace(true);
            let res = Engine::new(workload, sys.build(&models, 3, Micros::ZERO), cfg).run();
            table.row(vec![
                scenario.to_string(),
                sys.label(),
                res.metrics.per_model[0].good.to_string(),
                res.metrics.per_model[0].dropped.to_string(),
                res.metrics.per_model[0].median_batch().to_string(),
            ]);
        }
    }
    table
}

// ---------------------------------------------------------------------
// Figure 6a — batching effect strength (β/α)
// ---------------------------------------------------------------------

/// Fig 6a: α = 1 ms, β ∈ 1..15 ms, SLO = 2ℓ(8), 32 GPUs, 10 identical
/// models, Poisson arrivals. Plots eager goodput as % of deferred.
pub fn fig06a_betaalpha() -> Table {
    let betas: Vec<f64> = (1..=15).map(|b| b as f64).collect();
    let mut table = Table::new(vec!["beta_over_alpha", "eager_pct_of_deferred"]);
    let rows = par_map(betas, |&beta| {
        let base = model_zoo::synthetic_beta_family(beta);
        let models: Vec<ModelSpec> = (0..10)
            .map(|i| {
                let mut m = base.clone();
                m.name = format!("syn-b{beta}-{i}");
                m
            })
            .collect();
        let exp = GoodputExperiment::new(models, 32).sim_secs(5.0);
        let def = goodput_of(&exp, SystemKind::Symphony);
        let eag = goodput_of(&exp, SystemKind::Eager);
        (beta, if def > 0.0 { eag / def } else { f64::NAN })
    });
    for (beta, ratio) in rows {
        table.row(vec![f1(beta), pct(ratio)]);
    }
    table
}

// ---------------------------------------------------------------------
// Figure 6b — timeout-based scheduling comparison
// ---------------------------------------------------------------------

/// Fig 6b: timeout value as a fraction of SLO; goodput relative to
/// deferred. Single ResNet50 (50 ms, 8 GPUs) and the 37-model A100 mix
/// (64 GPUs).
pub fn fig06b_timeout() -> Table {
    let fracs: Vec<f64> = vec![0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];
    let mut table = Table::new(vec!["workload", "timeout_frac_slo", "pct_of_deferred"]);

    // Case 1: single ResNet50, SLO 50 ms, 8 GPUs.
    let mut r50 = model_zoo::by_name(GpuKind::Gtx1080Ti, "ResNet50").unwrap();
    r50.slo = Micros::from_millis_f64(50.0);
    let single = GoodputExperiment::new(vec![r50], 8).sim_secs(6.0);
    let def_single = goodput_of(&single, SystemKind::Symphony);

    // Case 2: mixed 37 models (A100), 64 GPUs.
    let mixed_models = model_zoo::zoo(GpuKind::A100);
    let mixed = GoodputExperiment::new(mixed_models, 64).sim_secs(5.0);
    let def_mixed = goodput_of(&mixed, SystemKind::Symphony);

    let single_rows = par_map(fracs.clone(), |&f| {
        // Per-model timeout k = f * SLO (single model: one SLO).
        let k = Micros((single.models[0].slo.0 as f64 * f) as u64);
        let g = goodput_of(&single, SystemKind::Timeout { k });
        (f, g / def_single.max(1e-9))
    });
    for (f, r) in single_rows {
        table.row(vec!["resnet50_50ms".into(), f2(f), pct(r)]);
    }

    // Mixed models share one timeout fraction but have different SLOs:
    // use the *minimum* SLO as the reference the way an operator with a
    // single knob would ("tuning per model ... significant operational
    // overhead").
    let min_slo = mixed.models.iter().map(|m| m.slo).min().unwrap();
    let mixed_rows = par_map(fracs, |&f| {
        let k = Micros((min_slo.0 as f64 * f) as u64);
        let g = goodput_of(&mixed, SystemKind::Timeout { k });
        (f, g / def_mixed.max(1e-9))
    });
    for (f, r) in mixed_rows {
        table.row(vec!["mixed37_a100".into(), f2(f), pct(r)]);
    }
    table
}

// ---------------------------------------------------------------------
// Figure 7 — the synthetic-workload sweep
// ---------------------------------------------------------------------

/// One Fig 7 configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub model_name: String,
    pub n_models: usize,
    pub gpu_ratio: f64,
    pub slo_ms: f64,
    pub gamma_shape: f64,
}

/// The Table 1 grid. `full` = all 5880+ configs; otherwise a stratified
/// sample (~1 in 48 — this sandbox exposes a single core, so the
/// default keeps `cargo bench` to minutes).
pub fn fig07_grid(full: bool) -> Vec<SweepConfig> {
    let model_names = [
        "DenseNet121", "InceptionV3", "ResNet50V2", "VGG16", "Xception", "BERT",
    ];
    let n_models = [8usize, 16, 24, 32, 48, 64];
    let ratios = [1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0];
    let slos = [20.0, 25.0, 30.0, 40.0, 50.0];
    let shapes = [0.1, 0.2, 0.3, 0.5, 0.7, 1.0];
    let mut grid = Vec::new();
    let mut idx = 0usize;
    for name in model_names {
        for &n in &n_models {
            for &r in &ratios {
                for &slo in &slos {
                    for &sh in &shapes {
                        idx += 1;
                        // Stride coprime with every grid dimension so
                        // the subset covers all axes (48 would alias the
                        // 6-value burstiness axis).
                        if !full && idx % 47 != 0 {
                            continue;
                        }
                        grid.push(SweepConfig {
                            model_name: name.to_string(),
                            n_models: n,
                            gpu_ratio: r,
                            slo_ms: slo,
                            gamma_shape: sh,
                        });
                    }
                }
            }
        }
    }
    grid
}

/// Run one sweep config: returns deferred/eager goodput ratio.
pub fn fig07_run_one(cfg: &SweepConfig) -> f64 {
    let base = model_zoo::by_name(GpuKind::Gtx1080Ti, &cfg.model_name).unwrap();
    let models: Vec<ModelSpec> = (0..cfg.n_models)
        .map(|i| {
            ModelSpec::new(
                &format!("{}-{i}", cfg.model_name),
                base.profile.alpha_ms,
                base.profile.beta_ms,
                cfg.slo_ms,
            )
        })
        .collect();
    let gpus = ((cfg.n_models as f64 * cfg.gpu_ratio).round() as usize).max(1);
    let exp = GoodputExperiment::new(models, gpus)
        .gamma_shape(cfg.gamma_shape)
        .sim_secs(3.0);
    let def = goodput_of(&exp, SystemKind::Symphony);
    let eag = goodput_of(&exp, SystemKind::Eager);
    if eag <= 0.0 {
        if def > 0.0 {
            f64::INFINITY
        } else {
            1.0
        }
    } else {
        def / eag
    }
}

/// Fig 7: distribution of deferred/eager goodput ratios over the grid.
pub fn fig07_sweep() -> Table {
    let grid = fig07_grid(full_sweep());
    let ratios = par_map(grid.clone(), fig07_run_one);

    let mut table = Table::new(vec![
        "slice", "cases", "ratio_p10", "ratio_median", "ratio_p90",
        "pct_no_worse(>=0.95)", "pct_gain>=1.5x",
    ]);
    let mut slice = |name: &str, sel: &dyn Fn(&SweepConfig) -> bool| {
        let vals: Vec<f64> = grid
            .iter()
            .zip(&ratios)
            .filter(|(c, _)| sel(c))
            .map(|(_, &r)| if r.is_finite() { r } else { 10.0 })
            .collect();
        if vals.is_empty() {
            return;
        }
        let no_worse = vals.iter().filter(|&&r| r >= 0.95).count() as f64 / vals.len() as f64;
        let big = vals.iter().filter(|&&r| r >= 1.5).count() as f64 / vals.len() as f64;
        table.row(vec![
            name.to_string(),
            vals.len().to_string(),
            f2(percentile(&vals, 10.0)),
            f2(percentile(&vals, 50.0)),
            f2(percentile(&vals, 90.0)),
            pct(no_worse),
            pct(big),
        ]);
    };
    slice("all", &|_| true);
    slice("densenet121(strong)", &|c| c.model_name == "DenseNet121");
    slice("bert(weak)", &|c| c.model_name == "BERT");
    slice("slo<=30ms", &|c| c.slo_ms <= 30.0);
    slice("slo>=50ms", &|c| c.slo_ms >= 50.0);
    slice("bursty(shape<=0.2)", &|c| c.gamma_shape <= 0.2);
    slice("poisson(shape=1)", &|c| c.gamma_shape >= 1.0);
    table
}

// ---------------------------------------------------------------------
// Figure 9 — end-to-end goodput on the model zoo
// ---------------------------------------------------------------------

/// Fig 9: mixed / strong / weak zoo splits on 64 emulated GPUs, 1080Ti
/// and A100 profiles; scheduler-only (s: ideal network) and end-to-end
/// (e: RDMA network) for Symphony; baselines + Nexus with 8 frontends.
pub fn fig09_e2e_goodput() -> Table {
    let mut table = Table::new(vec!["gpu", "setting", "system", "goodput"]);
    let mut jobs = Vec::new();
    for kind in [GpuKind::Gtx1080Ti, GpuKind::A100] {
        for (setting, models) in [
            ("mixed", model_zoo::zoo(kind)),
            ("strong", model_zoo::zoo_strong(kind)),
            ("weak", model_zoo::zoo_weak(kind)),
        ] {
            let systems = vec![
                (SystemKind::Symphony, NetworkModel::Ideal, "symphony(s)"),
                (SystemKind::Symphony, NetworkModel::Rdma, "symphony(e)"),
                (SystemKind::Clockwork, NetworkModel::Ideal, "clockwork(s)"),
                (SystemKind::Clockwork, NetworkModel::Rdma, "clockwork(e)"),
                (
                    SystemKind::Nexus { frontends: 1 },
                    NetworkModel::Rdma,
                    "nexus1fe",
                ),
                (
                    SystemKind::Nexus { frontends: 8 },
                    NetworkModel::Rdma,
                    "nexus8fe",
                ),
                (SystemKind::Shepherd, NetworkModel::Ideal, "shepherd(s)"),
            ];
            for (sys, net, label) in systems {
                jobs.push((kind, setting, models.clone(), sys, net, label));
            }
        }
    }
    let rows = par_map(jobs, |(kind, setting, models, sys, net, label)| {
        let exp = GoodputExperiment::new(models.clone(), 64)
            .network(*net)
            .sim_secs(3.0);
        let g = goodput_of(&exp, *sys);
        (kind.name(), setting.to_string(), label.to_string(), g)
    });
    for (gpu, setting, label, g) in rows {
        table.row(vec![gpu.to_string(), setting, label, f1(g)]);
    }
    table
}

// ---------------------------------------------------------------------
// Figure 10 — minimum GPUs for 15k RPS
// ---------------------------------------------------------------------

/// Smallest cluster size at which `sys` sustains `rate` on `models`.
pub fn min_gpus_for(
    models: &[ModelSpec],
    sys: SystemKind,
    rate: f64,
    max_gpus: usize,
) -> Option<usize> {
    let mut lo = 1usize;
    let mut hi = max_gpus;
    let feasible = |n: usize| {
        let exp = GoodputExperiment::new(models.to_vec(), n).sim_secs(3.0);
        let m = exp.run_at(rate, &|e: &GoodputExperiment| {
            sys.build(&e.models, e.num_gpus, Micros::ZERO)
        });
        m.slo_satisfied(0.01)
    };
    if !feasible(hi) {
        return None;
    }
    while lo < hi {
        let mid = (lo + hi) / 2;
        if feasible(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Some(hi)
}

/// Fig 10: min #GPUs to serve 15k RPS — single ResNet50 (25 ms SLO) and
/// the 37-model mix, A100 profiles.
pub fn fig10_min_gpus() -> Table {
    let mut r50 = model_zoo::by_name(GpuKind::A100, "ResNet50").unwrap();
    r50.slo = Micros::from_millis_f64(25.0);
    let single = vec![r50];
    let mixed = model_zoo::zoo(GpuKind::A100);
    let mut table = Table::new(vec!["workload", "system", "min_gpus"]);
    let mut jobs = Vec::new();
    for sys in SystemKind::HEADLINE {
        jobs.push(("resnet50_25ms", single.clone(), sys, 64usize));
        jobs.push(("mixed37", mixed.clone(), sys, 256usize));
    }
    let rows = par_map(jobs, |(wl, models, sys, cap)| {
        let n = min_gpus_for(models, *sys, 15_000.0, *cap);
        (wl.to_string(), sys.label(), n)
    });
    for (wl, label, n) in rows {
        table.row(vec![
            wl,
            label,
            n.map(|v| v.to_string()).unwrap_or_else(|| ">cap".into()),
        ]);
    }
    table
}

// ---------------------------------------------------------------------
// Figure 11 — workload characteristics
// ---------------------------------------------------------------------

/// Fig 11: 20 ResNet50-like models on 32 GPUs; SLO sweep × popularity
/// (equal / Zipf 0.9) × arrival (Poisson / Γ(0.05)).
pub fn fig11_workload_chars() -> Table {
    let slos = [15.0, 20.0, 25.0, 30.0, 50.0, 75.0, 100.0];
    let mut table = Table::new(vec![
        "slo_ms", "popularity", "arrival", "system", "goodput",
    ]);
    let mut jobs = Vec::new();
    for &slo in &slos {
        for (pop_name, pop) in [("equal", Popularity::Equal), ("zipf0.9", Popularity::Zipf(0.9))]
        {
            for (arr_name, shape) in [("poisson", 1.0), ("gamma0.05", 0.05)] {
                for sys in SystemKind::HEADLINE {
                    jobs.push((slo, pop_name, pop, arr_name, shape, sys));
                }
            }
        }
    }
    let rows = par_map(jobs, |&(slo, pop_name, pop, arr_name, shape, sys)| {
        let models = model_zoo::resnet_like_variants(20, slo, GpuKind::Gtx1080Ti);
        let exp = GoodputExperiment::new(models, 32)
            .popularity(pop)
            .gamma_shape(shape)
            .sim_secs(3.0);
        (
            slo,
            pop_name,
            arr_name,
            sys.label(),
            goodput_of(&exp, sys),
        )
    });
    for (slo, pop, arr, label, g) in rows {
        table.row(vec![
            f1(slo),
            pop.to_string(),
            arr.to_string(),
            label,
            f1(g),
        ]);
    }
    table
}

// ---------------------------------------------------------------------
// Table 2 — analytical vs measured
// ---------------------------------------------------------------------

/// Table 2: analytical batch size + throughput for no-coordination and
/// staggered execution, and measured goodput for the four systems.
pub fn table2_analytical() -> Table {
    let cases = [
        (model_zoo::resnet50_table2(), "ResNet50"),
        (model_zoo::inception_resnet_v2_table2(), "InceptionResNetV2"),
    ];
    let mut table = Table::new(vec![
        "model", "nocoord_bs", "nocoord_tput", "staggered_bs", "staggered_tput",
        "symphony", "clockwork", "nexus", "shepherd",
    ]);
    for (model, name) in cases {
        let nc = analytical::no_coordination(&model.profile, model.slo, 8);
        let st = analytical::staggered(&model.profile, model.slo, 8);
        let exp = GoodputExperiment::new(vec![model.clone()], 8).sim_secs(8.0);
        let g: Vec<f64> = par_map(SystemKind::HEADLINE.to_vec(), |&sys| {
            goodput_of(&exp, sys)
        });
        table.row(vec![
            name.to_string(),
            nc.batch_size.to_string(),
            f1(nc.throughput),
            st.batch_size.to_string(),
            f1(st.throughput),
            f1(g[0]),
            f1(g[1]),
            f1(g[2]),
            f1(g[3]),
        ]);
    }
    table
}

// ---------------------------------------------------------------------
// Figure 12 — queueing delay
// ---------------------------------------------------------------------

/// Fig 12: queueing-delay quantiles per system at each system's goodput
/// (ResNet50 & InceptionResNetV2, 8 GPUs).
pub fn fig12_queueing() -> Table {
    let cases = [
        model_zoo::resnet50_table2(),
        model_zoo::inception_resnet_v2_table2(),
    ];
    let mut table = Table::new(vec![
        "model", "system", "q50_ms", "q90_ms", "q99_ms", "max_ms",
    ]);
    for model in cases {
        let exp = GoodputExperiment::new(vec![model.clone()], 8).sim_secs(8.0);
        let rows = par_map(SystemKind::HEADLINE.to_vec(), |&sys| {
            let res = exp.goodput(|e| sys.build(&e.models, e.num_gpus, Micros::ZERO));
            // Re-run at the frontier with samples on.
            let m = {
                let spec =
                    WorkloadSpec::new(exp.models.clone(), res.offered.max(100.0)).seed(exp.seed);
                let cfg = SimConfig::new(exp.num_gpus, Micros::from_secs_f64(exp.sim_secs))
                    .warmup(Micros::from_secs_f64(exp.warmup_secs));
                Engine::new(
                    spec.build(),
                    sys.build(&exp.models, exp.num_gpus, Micros::ZERO),
                    cfg,
                )
                .run()
                .metrics
            };
            let q = m.queueing_all();
            (
                sys.label(),
                percentile(&q, 50.0),
                percentile(&q, 90.0),
                percentile(&q, 99.0),
                q.iter().cloned().fold(0.0, f64::max),
            )
        });
        for (label, q50, q90, q99, qmax) in rows {
            table.row(vec![
                model.name.clone(),
                label,
                f2(q50),
                f2(q90),
                f2(q99),
                f2(qmax),
            ]);
        }
    }
    table
}

// ---------------------------------------------------------------------
// Figure 13 right — goodput vs cluster size
// ---------------------------------------------------------------------

/// Fig 13 (right): 20 equally popular ResNet-like models, 100 ms SLO;
/// goodput vs number of emulated GPUs.
pub fn fig13_goodput_vs_gpus() -> Table {
    let sizes = [8usize, 16, 32, 64, 128];
    let mut table = Table::new(vec!["gpus", "system", "goodput", "goodput_per_gpu"]);
    let mut jobs = Vec::new();
    for &n in &sizes {
        for sys in [SystemKind::Symphony, SystemKind::Clockwork] {
            jobs.push((n, sys));
        }
    }
    let rows = par_map(jobs, |&(n, sys)| {
        let models = model_zoo::resnet_like_variants(20, 100.0, GpuKind::Gtx1080Ti);
        let exp = GoodputExperiment::new(models, n).sim_secs(4.0);
        let g = goodput_of(&exp, sys);
        (n, sys.label(), g)
    });
    for (n, label, g) in rows {
        table.row(vec![
            n.to_string(),
            label,
            f1(g),
            f1(g / n as f64),
        ]);
    }
    table
}

// ---------------------------------------------------------------------
// Figure 14 — network latency sensitivity
// ---------------------------------------------------------------------

/// Fig 14: 20 similar models, 32 GPUs, SLO ∈ {20,25,50,100} ms; goodput
/// vs injected constant network latency — the RDMA range (≤ 200 µs) and
/// the TCP range (≤ 40 ms).
pub fn fig14_network() -> Table {
    let slos = [20.0, 25.0, 50.0, 100.0];
    let rdma_range: Vec<u64> = vec![0, 25, 50, 100, 200];
    let tcp_range: Vec<u64> = vec![1_000, 3_000, 10_000, 20_000, 40_000];
    let mut table = Table::new(vec!["range", "latency_us", "slo_ms", "goodput"]);
    let mut jobs = Vec::new();
    for &slo in &slos {
        for &us in rdma_range.iter().chain(&tcp_range) {
            jobs.push((slo, us));
        }
    }
    let rows = par_map(jobs, |&(slo, us)| {
        let models = model_zoo::resnet_like_variants(20, slo, GpuKind::Gtx1080Ti);
        let exp = GoodputExperiment::new(models, 32)
            .network(NetworkModel::Constant {
                latency: Micros(us),
            })
            .sim_secs(4.0);
        (slo, us, goodput_of(&exp, SystemKind::Symphony))
    });
    for (slo, us, g) in rows {
        let range = if us <= 200 { "rdma" } else { "tcp" };
        table.row(vec![range.to_string(), us.to_string(), f1(slo), f1(g)]);
    }
    table
}

// ---------------------------------------------------------------------
// Figure 15 — large cluster, changing workload, autoscaling
// ---------------------------------------------------------------------

/// Engine driver implementing the §3.5 autoscaler over epoch windows.
struct AutoscaleDriver {
    ctl: AutoscaleController,
    epoch: Micros,
    last_good: u64,
    last_bad: u64,
    last_busy: std::collections::HashMap<u32, Micros>,
    last_t: Micros,
    /// (time_s, offered_window_rps, active_gpus, bad_rate, advice)
    pub log: Vec<(f64, f64, usize, f64, i64)>,
}

impl AutoscaleDriver {
    fn new(cfg: AutoscaleConfig) -> Self {
        AutoscaleDriver {
            ctl: AutoscaleController::new(cfg),
            epoch: cfg.epoch,
            last_good: 0,
            last_bad: 0,
            last_busy: Default::default(),
            last_t: Micros::ZERO,
            log: Vec::new(),
        }
    }
}

impl EngineDriver for AutoscaleDriver {
    fn on_tick(&mut self, _tag: u64, now: Micros, cluster: &mut ClusterOps) -> Option<Micros> {
        let m = cluster.metrics;
        let good: u64 = m.per_model.iter().map(|pm| pm.good).sum();
        let bad: u64 = m.per_model.iter().map(|pm| pm.late + pm.dropped).sum();
        let dgood = good - self.last_good;
        let dbad = bad - self.last_bad;
        self.last_good = good;
        self.last_bad = bad;

        // Busy fraction this window across active GPUs.
        let window = (now - self.last_t).as_secs_f64().max(1e-9);
        let mut busy_sum = 0.0;
        let mut active = 0usize;
        for (i, g) in cluster.gpus.iter().enumerate() {
            if g.retired {
                continue;
            }
            active += 1;
            let prev = self
                .last_busy
                .get(&(i as u32))
                .copied()
                .unwrap_or(Micros::ZERO);
            let mut cur = g.busy;
            if let Some(f) = &g.in_flight {
                if now > f.start {
                    cur += now.min(f.end) - f.start;
                }
            }
            busy_sum += (cur.saturating_sub(prev)).as_secs_f64() / window;
            self.last_busy.insert(i as u32, cur);
        }
        self.last_t = now;
        let stats = WindowStats {
            good: dgood,
            bad: dbad,
            busy_fraction: if active > 0 { busy_sum / active as f64 } else { 0.0 },
            active_gpus: active,
            // The sim-side driver has no worker-pool probe; the busy
            // fraction is exact here, so the backlog veto is moot.
            queue_depth: 0,
        };
        let advice = self.ctl.advise(&stats);
        let mut delta: i64 = 0;
        match advice {
            Advice::Allocate(n) => {
                for _ in 0..n {
                    cluster.add_gpu();
                    delta += 1;
                }
            }
            Advice::Deallocate(n) => {
                // Remove idle GPUs from the highest id down (Symphony's
                // min-id dispatch keeps those idle).
                let mut removed = 0;
                for i in (0..cluster.gpus.len()).rev() {
                    if removed == n {
                        break;
                    }
                    if cluster.remove_gpu(crate::core::types::GpuId(i as u32)) {
                        removed += 1;
                        delta -= 1;
                    }
                }
            }
            Advice::Hold => {}
        }
        let offered = (dgood + dbad) as f64 / window;
        self.log.push((
            now.as_secs_f64(),
            offered,
            cluster.active_gpus(),
            stats.bad_rate(),
            delta,
        ));
        Some(now + self.epoch)
    }
}

/// Fig 15: a changing workload (24 models, synthetic diurnal+burst
/// traces) on a cluster that autoscaled from 512 GPUs. Reports the
/// time series.
pub fn fig15_autoscale(duration_s: f64, start_gpus: usize) -> Table {
    let n_models = 24;
    let mut rng = Rng::new(1234);
    let duration = Micros::from_secs_f64(duration_s);
    // Models with varying batching characteristics (drawn from Table 4).
    let zoo = model_zoo::zoo(GpuKind::A100);
    let models: Vec<ModelSpec> = (0..n_models).map(|i| zoo[i % zoo.len()].clone()).collect();
    // Per-model rate traces; aggregate mean sized to ~60% of cluster peak.
    let per_model_mean = 15_000.0 / n_models as f64;
    let streams: Vec<ArrivalStream> = (0..n_models)
        .map(|i| {
            let spec = TraceSpec::new(duration, per_model_mean)
                .phase(i as f64 / n_models as f64);
            let segments = spec.generate(&mut rng);
            ArrivalStream::new(
                ArrivalKind::PiecewiseRate {
                    segments,
                    shape: 1.0,
                },
                rng.fork(i as u64),
            )
        })
        .collect();
    let workload = Workload::from_streams(models.clone(), streams);
    let scheduler = SystemKind::Symphony.build(&models, start_gpus, Micros::ZERO);
    let cfg = SimConfig::new(start_gpus, duration).samples(false);
    let driver = AutoscaleDriver::new(AutoscaleConfig {
        min_gpus: 8,
        max_gpus: start_gpus * 2,
        ..Default::default()
    });
    let mut engine = Engine::with_driver(workload, scheduler, driver, cfg);
    engine.arm_external(0, Micros::from_secs_f64(10.0));
    let res = engine.run();

    let mut table = Table::new(vec![
        "t_s", "offered_rps", "active_gpus", "bad_rate", "scale_delta",
    ]);
    for &(t, offered, gpus, bad, delta) in &res.driver.log {
        table.row(vec![
            f1(t),
            f1(offered),
            gpus.to_string(),
            pct(bad),
            delta.to_string(),
        ]);
    }
    table
}

// ---------------------------------------------------------------------
// Figure 16 — partitioning quality
// ---------------------------------------------------------------------

/// Fig 16: CDF of imbalance factors for the MILP-style solver vs random
/// search, 800 models / 20 partitions, many instances.
pub fn fig16_partition(instances: usize, budget_ms: u64) -> Table {
    let jobs: Vec<u64> = (0..instances as u64).collect();
    let results = par_map(jobs, |&seed| {
        let mut rng = Rng::new(9000 + seed);
        let p = partition::random_instance(800, 20, &mut rng);
        let budget = Duration::from_millis(budget_ms);
        let ours = partition::solve(&p, budget, &mut rng);
        let rand = partition::random_search(&p, budget, &mut rng);
        let (or, os) = ours.map(|a| p.imbalance(&a)).unwrap_or((f64::NAN, f64::NAN));
        let (rr, rs) = rand.map(|a| p.imbalance(&a)).unwrap_or((f64::NAN, f64::NAN));
        (or, os, rr, rs)
    });
    let mut table = Table::new(vec![
        "metric", "solver_p50", "solver_p90", "random_p50", "random_p90",
    ]);
    let col = |f: &dyn Fn(&(f64, f64, f64, f64)) -> f64| -> Vec<f64> {
        results.iter().map(f).filter(|v| v.is_finite()).collect()
    };
    let ours_rate = col(&|r| r.0);
    let ours_mem = col(&|r| r.1);
    let rand_rate = col(&|r| r.2);
    let rand_mem = col(&|r| r.3);
    table.row(vec![
        "rate_imbalance".to_string(),
        f2(percentile(&ours_rate, 50.0)),
        f2(percentile(&ours_rate, 90.0)),
        f2(percentile(&rand_rate, 50.0)),
        f2(percentile(&rand_rate, 90.0)),
    ]);
    table.row(vec![
        "mem_imbalance".to_string(),
        f2(percentile(&ours_mem, 50.0)),
        f2(percentile(&ours_mem, 90.0)),
        f2(percentile(&rand_mem, 50.0)),
        f2(percentile(&rand_mem, 90.0)),
    ]);
    table
}

// ---------------------------------------------------------------------
// Figure 17 — RDMA vs TCP incast latency
// ---------------------------------------------------------------------

/// Fig 17: quantiles of the modeled incast latency distributions.
pub fn fig17_incast(samples: usize) -> Table {
    let mut table = Table::new(vec![
        "network", "min_us", "p50_us", "p99_us", "p9999_us", "tail_over_median",
    ]);
    for net in [NetworkModel::Rdma, NetworkModel::Tcp] {
        let mut rng = Rng::new(0xF17);
        let mut xs: Vec<f64> = (0..samples).map(|_| net.sample(&mut rng).0 as f64).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = percentile(&xs, 50.0);
        let p9999 = percentile(&xs, 99.99);
        table.row(vec![
            net.name(),
            f1(xs[0]),
            f1(med),
            f1(percentile(&xs, 99.0)),
            f1(p9999),
            f2(p9999 / med),
        ]);
    }
    table
}

// ---------------------------------------------------------------------
// Batch-size CDFs (Fig 1 supplement)
// ---------------------------------------------------------------------

/// Dump the full batch-size CDF per system (Fig 1's actual curves).
pub fn fig01_cdfs() -> Table {
    let model = model_zoo::resnet50_table2();
    let mut table = Table::new(vec!["system", "batch_size", "cdf"]);
    for sys in SystemKind::HEADLINE {
        let exp = GoodputExperiment::new(vec![model.clone()], 8).sim_secs(6.0);
        let res = exp.goodput(|e| sys.build(&e.models, e.num_gpus, Micros::ZERO));
        let hist: Histogram = res.metrics.batch_hist_all();
        for (b, c) in hist.cdf() {
            table.row(vec![sys.label(), b.to_string(), f2(c)]);
        }
    }
    table
}
