//! Goodput measurement (§2.1, §3.4): "Goodput is found by a binary
//! search over sending a fixed request rate" — the highest offered rate
//! at which every model's p99 latency stays within its SLO (with
//! drop-based schedulers: per-model bad fraction ≤ 1%).

use crate::core::profile::ModelSpec;
use crate::core::time::Micros;
use crate::metrics::Metrics;
use crate::scheduler::Scheduler;
use crate::sim::{Engine, NetworkModel, SimConfig};
use crate::workload::{Popularity, WorkloadSpec};

/// Default SLO-violation budget for feasibility.
pub const BAD_THRESHOLD: f64 = 0.01;

/// One goodput experiment: how to build a scheduler for a given cluster,
/// and the workload shape.
#[derive(Clone)]
pub struct GoodputExperiment {
    pub models: Vec<ModelSpec>,
    pub num_gpus: usize,
    pub popularity: Popularity,
    pub gamma_shape: f64,
    pub network: NetworkModel,
    pub sim_secs: f64,
    pub warmup_secs: f64,
    pub seed: u64,
    pub bad_threshold: f64,
}

impl GoodputExperiment {
    pub fn new(models: Vec<ModelSpec>, num_gpus: usize) -> Self {
        GoodputExperiment {
            models,
            num_gpus,
            popularity: Popularity::Equal,
            gamma_shape: 1.0,
            network: NetworkModel::Ideal,
            sim_secs: 10.0,
            warmup_secs: 2.0,
            seed: 42,
            bad_threshold: BAD_THRESHOLD,
        }
    }

    pub fn popularity(mut self, p: Popularity) -> Self {
        self.popularity = p;
        self
    }

    pub fn gamma_shape(mut self, s: f64) -> Self {
        self.gamma_shape = s;
        self
    }

    pub fn network(mut self, n: NetworkModel) -> Self {
        self.network = n;
        self
    }

    pub fn sim_secs(mut self, s: f64) -> Self {
        self.sim_secs = s;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn bad_threshold(mut self, t: f64) -> Self {
        self.bad_threshold = t;
        self
    }

    /// Run one simulation at `rate` with the scheduler produced by `mk`.
    pub fn run_at<S, F>(&self, rate: f64, mk: &F) -> Metrics
    where
        S: Scheduler,
        F: Fn(&Self) -> S,
    {
        let spec = WorkloadSpec::new(self.models.clone(), rate)
            .popularity(self.popularity)
            .gamma_shape(self.gamma_shape)
            .seed(self.seed);
        let cfg = SimConfig::new(self.num_gpus, Micros::from_secs_f64(self.sim_secs))
            .network(self.network)
            .warmup(Micros::from_secs_f64(self.warmup_secs))
            .samples(false)
            .seed(self.seed ^ 0x9E37);
        Engine::new(spec.build(), mk(self), cfg).run().metrics
    }

    /// Upper bound for the search: aggregate peak throughput if every
    /// GPU ran its max-SLO batch continuously, padded 2x.
    pub fn rate_upper_bound(&self) -> f64 {
        let per_gpu_best: f64 = self
            .models
            .iter()
            .map(|m| m.profile.throughput(m.profile.max_batch_within(m.slo)))
            .fold(0.0, f64::max);
        (per_gpu_best * self.num_gpus as f64 * 2.0).max(100.0)
    }

    /// Binary-search goodput. Returns (goodput, feasible_rate).
    pub fn goodput<S, F>(&self, mk: F) -> GoodputResult
    where
        S: Scheduler,
        F: Fn(&Self) -> S,
    {
        let mut lo = 0.0f64;
        let mut hi = self.rate_upper_bound();
        let mut best_metrics: Option<Metrics> = None;
        let mut best_rate = 0.0;
        // Expand hi if somehow feasible at the bound (cheap check).
        for _ in 0..14 {
            let mid = 0.5 * (lo + hi);
            if mid < 1.0 {
                break;
            }
            let m = self.run_at(mid, &mk);
            if m.slo_satisfied(self.bad_threshold) {
                best_rate = mid;
                best_metrics = Some(m);
                lo = mid;
            } else {
                hi = mid;
            }
        }
        match best_metrics {
            Some(m) => GoodputResult {
                goodput: m.goodput(),
                offered: best_rate,
                metrics: m,
            },
            None => {
                let m = self.run_at(1.0, &mk);
                GoodputResult {
                    goodput: 0.0,
                    offered: 0.0,
                    metrics: m,
                }
            }
        }
    }
}

/// Outcome of a goodput search.
pub struct GoodputResult {
    /// Good requests/second at the highest feasible offered rate.
    pub goodput: f64,
    /// That offered rate.
    pub offered: f64,
    /// Metrics of the run at the frontier.
    pub metrics: Metrics,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::deferred::{DeferredConfig, DeferredScheduler};
    use crate::scheduler::timeout::{TimeoutConfig, TimeoutScheduler};

    fn resnet50() -> ModelSpec {
        ModelSpec::new("ResNet50", 1.053, 5.072, 25.0)
    }

    #[test]
    fn deferred_goodput_close_to_staggered_analysis() {
        // Table 2: Symphony measured 5264 r/s on 8 GPUs (staggered
        // analytical bound 5839). Accept the 4800..5900 band.
        let exp = GoodputExperiment::new(vec![resnet50()], 8).sim_secs(6.0);
        let res = exp.goodput(|e| {
            DeferredScheduler::new(
                e.models.iter().map(|m| m.profile).collect(),
                e.num_gpus,
                DeferredConfig::default(),
            )
        });
        assert!(
            (4600.0..5900.0).contains(&res.goodput),
            "deferred goodput {}",
            res.goodput
        );
    }

    #[test]
    fn deferred_beats_eager_on_strong_batching() {
        let exp = GoodputExperiment::new(vec![resnet50()], 8).sim_secs(5.0);
        let def = exp
            .goodput(|e| {
                DeferredScheduler::new(
                    e.models.iter().map(|m| m.profile).collect(),
                    e.num_gpus,
                    DeferredConfig::default(),
                )
            })
            .goodput;
        let eager = exp
            .goodput(|e| {
                TimeoutScheduler::new(
                    e.models.iter().map(|m| m.profile).collect(),
                    e.num_gpus,
                    TimeoutConfig::eager(),
                )
            })
            .goodput;
        assert!(def > eager, "deferred {def} vs eager {eager}");
    }

    #[test]
    fn infeasible_workload_reports_zero() {
        // 1 GPU, SLO so tight nothing fits: goodput ~0.
        let model = ModelSpec::new("impossible", 10.0, 50.0, 20.0);
        let exp = GoodputExperiment::new(vec![model], 1).sim_secs(2.0);
        let res = exp.goodput(|e| {
            DeferredScheduler::new(
                e.models.iter().map(|m| m.profile).collect(),
                e.num_gpus,
                DeferredConfig::default(),
            )
        });
        assert_eq!(res.goodput, 0.0);
    }
}
