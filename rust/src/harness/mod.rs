//! Experiment harness: the goodput search, uniform system construction,
//! and one entry point per paper table/figure (shared by `cargo bench`
//! targets and the `symphony` CLI).

pub mod experiments;
pub mod goodput;
pub mod systems;

pub use goodput::{GoodputExperiment, GoodputResult, BAD_THRESHOLD};
pub use systems::SystemKind;
