//! Uniform construction of all five systems under test, so every
//! experiment compares them through one interface.

use crate::core::profile::ModelSpec;
use crate::core::time::Micros;
use crate::scheduler::clockwork::ClockworkScheduler;
use crate::scheduler::deferred::{DeferredConfig, DeferredScheduler};
use crate::scheduler::nexus::NexusScheduler;
use crate::scheduler::shepherd::ShepherdScheduler;
use crate::scheduler::timeout::{TimeoutConfig, TimeoutScheduler};
use crate::scheduler::Scheduler;

/// The systems compared throughout the evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SystemKind {
    /// Symphony's deferred batch scheduling (Algorithm 1).
    Symphony,
    /// Clockwork-style: eager, earliest latest-executable-moment.
    Clockwork,
    /// Nexus-style: distributed epoch planning, k frontends.
    Nexus { frontends: usize },
    /// Shepherd Flex: eager biggest-batch + 3x preemption.
    Shepherd,
    /// Pure eager (timeout k = 0).
    Eager,
    /// Timeout-based with fixed k.
    Timeout { k: Micros },
}

impl SystemKind {
    pub const BASELINES: [SystemKind; 4] = [
        SystemKind::Clockwork,
        SystemKind::Nexus { frontends: 1 },
        SystemKind::Shepherd,
        SystemKind::Eager,
    ];

    /// The paper's four headline systems (Figs 1, 2, 9-12).
    pub const HEADLINE: [SystemKind; 4] = [
        SystemKind::Symphony,
        SystemKind::Clockwork,
        SystemKind::Nexus { frontends: 1 },
        SystemKind::Shepherd,
    ];

    pub fn label(&self) -> String {
        match self {
            SystemKind::Symphony => "symphony".into(),
            SystemKind::Clockwork => "clockwork".into(),
            SystemKind::Nexus { frontends: 1 } => "nexus".into(),
            SystemKind::Nexus { frontends } => format!("nexus{frontends}fe"),
            SystemKind::Shepherd => "shepherd".into(),
            SystemKind::Eager => "eager".into(),
            SystemKind::Timeout { k } => format!("timeout({k})"),
        }
    }

    /// Build the scheduler for a cluster of `num_gpus` serving `models`.
    /// `net_bound` is the network-delay budget Symphony subtracts from
    /// its windows (§5.6).
    pub fn build(
        &self,
        models: &[ModelSpec],
        num_gpus: usize,
        net_bound: Micros,
    ) -> Box<dyn Scheduler> {
        let profiles: Vec<_> = models.iter().map(|m| m.profile).collect();
        match self {
            SystemKind::Symphony => Box::new(DeferredScheduler::new(
                profiles,
                num_gpus,
                DeferredConfig {
                    net_bound,
                    max_batch: 0,
                    shed: true,
                },
            )),
            SystemKind::Clockwork => Box::new(ClockworkScheduler::new(profiles, num_gpus)),
            SystemKind::Nexus { frontends } => Box::new(NexusScheduler::new(
                models.iter().map(|m| (m.profile, m.slo)).collect(),
                num_gpus,
                *frontends,
            )),
            SystemKind::Shepherd => Box::new(ShepherdScheduler::new(profiles, num_gpus)),
            SystemKind::Eager => Box::new(TimeoutScheduler::new(
                profiles,
                num_gpus,
                TimeoutConfig {
                    timeout: Micros::ZERO,
                    max_batch: 0,
                    net_bound,
                },
            )),
            SystemKind::Timeout { k } => Box::new(TimeoutScheduler::new(
                profiles,
                num_gpus,
                TimeoutConfig {
                    timeout: *k,
                    max_batch: 0,
                    net_bound,
                },
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_unique() {
        let labels: Vec<String> = SystemKind::HEADLINE.iter().map(|s| s.label()).collect();
        let mut dedup = labels.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(labels.len(), dedup.len());
    }

    #[test]
    fn builds_all() {
        let models = vec![ModelSpec::new("m", 1.0, 5.0, 25.0)];
        for sys in [
            SystemKind::Symphony,
            SystemKind::Clockwork,
            SystemKind::Nexus { frontends: 8 },
            SystemKind::Shepherd,
            SystemKind::Eager,
            SystemKind::Timeout {
                k: Micros::from_millis_f64(5.0),
            },
        ] {
            let s = sys.build(&models, 4, Micros::ZERO);
            assert!(!s.name().is_empty());
        }
    }
}
