//! # Symphony — deferred batch scheduling for DNN model serving
//!
//! A full reproduction of *"Symphony: Optimized DNN Model Serving using
//! Deferred Batch Scheduling"* (cs.DC 2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's contribution: the deferred
//!   batch scheduler ([`scheduler::deferred`]), four baselines
//!   (Clockwork / Nexus / Shepherd / timeout-eager), the discrete-event
//!   cluster emulator ([`sim`]), the multithreaded
//!   ingest-shard/model-worker/rank-shard coordinator ([`coordinator`]),
//!   the wire-level distributed rank tier ([`net`]: `symphony
//!   rank-server` / `serve --remote-ranks`), the autoscaling controller
//!   ([`autoscale`]), and the sub-cluster partitioner ([`partition`]).
//! * **Layer 2 (JAX, build-time)** — `python/compile/model.py`, lowered
//!   to HLO text once per batch size.
//! * **Layer 1 (Pallas, build-time)** — the fused dense kernels in
//!   `python/compile/kernels/`, validated against `ref.py`.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT (the
//! `xla` crate) and [`serve`] runs them behind the coordinator in real
//! time — Python never executes on the request path.
//!
//! Start with `examples/quickstart.rs`; every table and figure of the
//! paper regenerates via `cargo bench` (see DESIGN.md §5).

pub mod autoscale;
pub mod check;
pub mod coordinator;
pub mod core;
pub mod harness;
pub mod lint;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod partition;
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod sim;
pub mod util;
pub mod workload;

pub use crate::core::model_zoo::GpuKind;
pub use crate::core::profile::{LatencyProfile, ModelSpec};
pub use crate::core::time::Micros;
pub use crate::core::types::{GpuId, ModelId, Request, RequestId};
