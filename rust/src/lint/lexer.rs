//! A lightweight Rust tokenizer for the lint pass.
//!
//! This is deliberately *not* a full lexer: it only needs to be precise
//! about the things the rules care about — where comments and string
//! literals begin and end (so nothing inside them is mistaken for code),
//! whether a numeric literal is a float, brace nesting depth, and line
//! numbers. It is std-only; no `syn`, no `regex`.
//!
//! Known simplifications (all safe for linting this repo):
//! - Keywords are emitted as `Ident` tokens; rules match on the text.
//! - Token text is stored as a byte range into the original source.
//! - Shebang lines and `b'..'` byte literals are handled; frontmatter,
//!   macros 2.0 and exotic literal suffixes are not special-cased.

/// Token kinds the rules distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `Micros`, `unwrap`, ...).
    Ident,
    /// Integer literal (`42`, `0xFF`, `1_000u64`).
    Int,
    /// Float literal (`1.0`, `2.`, `1e9`, `3f64`).
    Float,
    /// String / raw-string / byte-string literal (content opaque).
    Str,
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`) or loop label.
    Lifetime,
    /// Line comment, text includes the leading `//`.
    LineComment,
    /// Block comment (possibly nested), text includes delimiters.
    BlockComment,
    /// Operator / punctuation, longest-match (`->`, `::`, `+=`, `+`, ...).
    Punct,
    /// `(` `[` `{`
    Open,
    /// `)` `]` `}`
    Close,
}

/// One token: kind, byte span into the source, 1-based line, and the
/// brace-nesting depth (`{}` only) *at the position of this token*.
#[derive(Debug, Clone, Copy)]
pub struct Token {
    pub kind: TokKind,
    pub start: usize,
    pub end: usize,
    pub line: usize,
    pub brace_depth: usize,
}

impl Token {
    pub fn text<'a>(&self, src: &'a str) -> &'a str {
        src.get(self.start..self.end).unwrap_or("")
    }
}

/// Tokenize `src`. Never panics on malformed input: unterminated
/// literals/comments simply extend to end-of-file.
pub fn tokenize(src: &str) -> Vec<Token> {
    let b = src.as_bytes();
    let mut toks = Vec::with_capacity(src.len() / 6 + 16);
    let mut i = 0usize;
    let mut line = 1usize;
    let mut depth = 0usize;

    // Count newlines in b[from..to) into `line`.
    fn advance_lines(b: &[u8], from: usize, to: usize, line: &mut usize) {
        let mut k = from;
        while k < to {
            if b[k] == b'\n' {
                *line += 1;
            }
            k += 1;
        }
    }

    while i < b.len() {
        let c = b[i];
        // Whitespace.
        if c.is_ascii_whitespace() {
            if c == b'\n' {
                line += 1;
            }
            i += 1;
            continue;
        }
        let start = i;
        let tok_line = line;

        // Comments.
        if c == b'/' && i + 1 < b.len() {
            if b[i + 1] == b'/' {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                toks.push(Token {
                    kind: TokKind::LineComment,
                    start,
                    end: i,
                    line: tok_line,
                    brace_depth: depth,
                });
                continue;
            }
            if b[i + 1] == b'*' {
                let mut nest = 1usize;
                i += 2;
                while i < b.len() && nest > 0 {
                    if i + 1 < b.len() && b[i] == b'/' && b[i + 1] == b'*' {
                        nest += 1;
                        i += 2;
                    } else if i + 1 < b.len() && b[i] == b'*' && b[i + 1] == b'/' {
                        nest -= 1;
                        i += 2;
                    } else {
                        if b[i] == b'\n' {
                            line += 1;
                        }
                        i += 1;
                    }
                }
                toks.push(Token {
                    kind: TokKind::BlockComment,
                    start,
                    end: i,
                    line: tok_line,
                    brace_depth: depth,
                });
                continue;
            }
        }

        // Raw strings and byte strings: r"..", r#".."#, br".."; b"..".
        if c == b'r' || c == b'b' {
            if let Some(end) = scan_raw_or_byte_string(b, i) {
                advance_lines(b, i, end, &mut line);
                toks.push(Token {
                    kind: TokKind::Str,
                    start,
                    end,
                    line: tok_line,
                    brace_depth: depth,
                });
                i = end;
                continue;
            }
            // b'x' byte char.
            if c == b'b' && i + 1 < b.len() && b[i + 1] == b'\'' {
                let end = scan_char_literal(b, i + 1);
                toks.push(Token {
                    kind: TokKind::Char,
                    start,
                    end,
                    line: tok_line,
                    brace_depth: depth,
                });
                i = end;
                continue;
            }
        }

        // Plain string.
        if c == b'"' {
            i += 1;
            while i < b.len() {
                if b[i] == b'\\' {
                    i = (i + 2).min(b.len());
                } else if b[i] == b'"' {
                    i += 1;
                    break;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            toks.push(Token {
                kind: TokKind::Str,
                start,
                end: i,
                line: tok_line,
                brace_depth: depth,
            });
            continue;
        }

        // Char literal vs lifetime.
        if c == b'\'' {
            if is_char_literal(b, i) {
                let end = scan_char_literal(b, i);
                toks.push(Token {
                    kind: TokKind::Char,
                    start,
                    end,
                    line: tok_line,
                    brace_depth: depth,
                });
                i = end;
            } else {
                i += 1;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
                toks.push(Token {
                    kind: TokKind::Lifetime,
                    start,
                    end: i,
                    line: tok_line,
                    brace_depth: depth,
                });
            }
            continue;
        }

        // Identifier / keyword.
        if c == b'_' || c.is_ascii_alphabetic() {
            i += 1;
            while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                i += 1;
            }
            toks.push(Token {
                kind: TokKind::Ident,
                start,
                end: i,
                line: tok_line,
                brace_depth: depth,
            });
            continue;
        }

        // Numeric literal.
        if c.is_ascii_digit() {
            let (end, is_float) = scan_number(b, i);
            toks.push(Token {
                kind: if is_float { TokKind::Float } else { TokKind::Int },
                start,
                end,
                line: tok_line,
                brace_depth: depth,
            });
            i = end;
            continue;
        }

        // Brackets.
        match c {
            b'{' => {
                toks.push(Token {
                    kind: TokKind::Open,
                    start,
                    end: i + 1,
                    line: tok_line,
                    brace_depth: depth,
                });
                depth += 1;
                i += 1;
                continue;
            }
            b'}' => {
                depth = depth.saturating_sub(1);
                toks.push(Token {
                    kind: TokKind::Close,
                    start,
                    end: i + 1,
                    line: tok_line,
                    brace_depth: depth,
                });
                i += 1;
                continue;
            }
            b'(' | b'[' => {
                toks.push(Token {
                    kind: TokKind::Open,
                    start,
                    end: i + 1,
                    line: tok_line,
                    brace_depth: depth,
                });
                i += 1;
                continue;
            }
            b')' | b']' => {
                toks.push(Token {
                    kind: TokKind::Close,
                    start,
                    end: i + 1,
                    line: tok_line,
                    brace_depth: depth,
                });
                i += 1;
                continue;
            }
            _ => {}
        }

        // Punctuation, longest match first.
        let rest = &src[i..];
        const PUNCTS: &[&str] = &[
            "<<=", ">>=", "..=", "...", "->", "=>", "::", "..", "<<", ">>", "<=", ">=", "==",
            "!=", "&&", "||", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=",
        ];
        let mut matched = 1usize;
        for p in PUNCTS {
            if rest.starts_with(p) {
                matched = p.len();
                break;
            }
        }
        toks.push(Token {
            kind: TokKind::Punct,
            start,
            end: i + matched,
            line: tok_line,
            brace_depth: depth,
        });
        i += matched;
    }
    toks
}

/// Does the `'` at `b[i]` open a char literal (vs a lifetime)?
fn is_char_literal(b: &[u8], i: usize) -> bool {
    // 'x' / '\n' / '\'' — a closing quote within a few bytes, or an
    // escape right after the opening quote.
    if i + 1 >= b.len() {
        return false;
    }
    if b[i + 1] == b'\\' {
        return true;
    }
    // 'a' -> char only if followed by closing quote; 'a  -> lifetime.
    i + 2 < b.len() && b[i + 1] != b'\'' && b[i + 2] == b'\''
}

/// Scan a char literal starting at the `'` in `b[i]`; returns end index.
fn scan_char_literal(b: &[u8], i: usize) -> usize {
    let mut k = i + 1;
    if k < b.len() && b[k] == b'\\' {
        k += 2;
        // \u{...}
        while k < b.len() && b[k] != b'\'' {
            k += 1;
        }
    } else if k < b.len() {
        k += 1;
    }
    if k < b.len() && b[k] == b'\'' {
        k += 1;
    }
    k
}

/// Scan r"..", r#"..."#, br#"..."#, b".." starting at `b[i]` (which is
/// `r` or `b`). Returns `Some(end)` if this really is such a literal.
fn scan_raw_or_byte_string(b: &[u8], i: usize) -> Option<usize> {
    let mut k = i;
    if b[k] == b'b' {
        k += 1;
        if k >= b.len() {
            return None;
        }
        if b[k] == b'"' {
            // b"..": plain byte string with escapes.
            k += 1;
            while k < b.len() {
                if b[k] == b'\\' {
                    k = (k + 2).min(b.len());
                } else if b[k] == b'"' {
                    return Some(k + 1);
                } else {
                    k += 1;
                }
            }
            return Some(k);
        }
        if b[k] != b'r' {
            return None;
        }
    }
    // Now at `r`.
    if b[k] != b'r' {
        return None;
    }
    k += 1;
    let mut hashes = 0usize;
    while k < b.len() && b[k] == b'#' {
        hashes += 1;
        k += 1;
    }
    if k >= b.len() || b[k] != b'"' {
        return None;
    }
    k += 1;
    // Scan for `"` followed by `hashes` hash marks.
    while k < b.len() {
        if b[k] == b'"' {
            let mut h = 0usize;
            while h < hashes && k + 1 + h < b.len() && b[k + 1 + h] == b'#' {
                h += 1;
            }
            if h == hashes {
                return Some(k + 1 + hashes);
            }
        }
        k += 1;
    }
    Some(k)
}

/// Scan a numeric literal starting at digit `b[i]`.
/// Returns (end, is_float). Careful cases:
/// - `0..2` is two ints and a range, not a float
/// - `slo.0` / `x.1` tuple access never reaches here (starts at ident)
/// - `1.max(2)` is an int then a method call
/// - `1.0`, `2.`, `1e9`, `1_000.5e-3`, `3f64` are floats
fn scan_number(b: &[u8], i: usize) -> (usize, bool) {
    let mut k = i;
    let hex = k + 1 < b.len() && b[k] == b'0' && (b[k + 1] == b'x' || b[k + 1] == b'X');
    let bin_oct =
        k + 1 < b.len() && b[k] == b'0' && matches!(b[k + 1], b'b' | b'B' | b'o' | b'O');
    // Integer part (also consumes type suffixes and hex digits).
    let mut saw_exp = false;
    let mut float_suffix = false;
    while k < b.len() && (b[k] == b'_' || b[k].is_ascii_alphanumeric()) {
        if !hex && !bin_oct && (b[k] == b'e' || b[k] == b'E') {
            // Exponent only if followed by digit or sign+digit.
            let n1 = k + 1;
            if n1 < b.len()
                && (b[n1].is_ascii_digit()
                    || ((b[n1] == b'+' || b[n1] == b'-')
                        && n1 + 1 < b.len()
                        && b[n1 + 1].is_ascii_digit()))
            {
                saw_exp = true;
                k = if b[n1].is_ascii_digit() { n1 } else { n1 + 1 };
                continue;
            }
        }
        k += 1;
    }
    // f32/f64 suffix on the integer run (`3f64`).
    if !hex {
        let run = &b[i..k];
        if run.ends_with(b"f32") || run.ends_with(b"f64") {
            float_suffix = true;
        }
    }
    // Fractional part.
    let mut is_float = (saw_exp && !hex) || float_suffix;
    if k < b.len() && b[k] == b'.' && !hex && !bin_oct {
        let n1 = k + 1;
        let next_is_digit = n1 < b.len() && b[n1].is_ascii_digit();
        let next_is_range_or_field = n1 < b.len()
            && (b[n1] == b'.' || b[n1] == b'_' || b[n1].is_ascii_alphabetic());
        if next_is_digit {
            is_float = true;
            k = n1;
            while k < b.len() && (b[k] == b'_' || b[k].is_ascii_alphanumeric()) {
                if b[k] == b'e' || b[k] == b'E' {
                    let m = k + 1;
                    if m < b.len()
                        && ((b[m] == b'+' || b[m] == b'-') && m + 1 < b.len()
                            && b[m + 1].is_ascii_digit())
                    {
                        k = m + 1;
                        continue;
                    }
                }
                k += 1;
            }
        } else if !next_is_range_or_field {
            // `2.` trailing-dot float (followed by `)` `,` `;` etc).
            is_float = true;
            k = n1;
        }
    }
    (k, is_float)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        tokenize(src)
            .iter()
            .map(|t| (t.kind, t.text(src).to_string()))
            .collect()
    }

    #[test]
    fn floats_vs_ints_vs_ranges() {
        let ks = kinds("let a = 1.0; let b = 0..2; let c = slo.0; let d = 1e9; let e = 3f64;");
        let floats: Vec<&str> = ks
            .iter()
            .filter(|(k, _)| *k == TokKind::Float)
            .map(|(_, s)| s.as_str())
            .collect();
        assert_eq!(floats, vec!["1.0", "1e9", "3f64"]);
        // `0..2` produced two Ints and a `..` punct.
        assert!(ks.iter().any(|(k, s)| *k == TokKind::Punct && s == ".."));
        // `slo.0` tuple access: ident, dot, int.
        assert!(ks.iter().any(|(k, s)| *k == TokKind::Int && s == "0"));
    }

    #[test]
    fn int_method_call_is_not_float() {
        let ks = kinds("let x = 1.max(2);");
        assert!(!ks.iter().any(|(k, _)| *k == TokKind::Float));
    }

    #[test]
    fn comments_and_strings_are_opaque() {
        let src = r##"
            // a float 1.0 in a comment
            /* nested /* 2.0 */ still comment */
            let s = "3.0 + unwrap()";
            let r = r#"4.0 "quoted" .unwrap()"#;
        "##;
        let ks = kinds(src);
        assert!(!ks.iter().any(|(k, _)| *k == TokKind::Float));
        assert_eq!(
            ks.iter().filter(|(k, _)| *k == TokKind::Str).count(),
            2
        );
        assert_eq!(
            ks.iter().filter(|(k, _)| *k == TokKind::LineComment).count(),
            1
        );
        assert_eq!(
            ks.iter()
                .filter(|(k, _)| *k == TokKind::BlockComment)
                .count(),
            1
        );
    }

    #[test]
    fn char_vs_lifetime() {
        let ks = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        assert_eq!(
            ks.iter().filter(|(k, _)| *k == TokKind::Char).count(),
            2
        );
        assert_eq!(
            ks.iter().filter(|(k, _)| *k == TokKind::Lifetime).count(),
            2
        );
    }

    #[test]
    fn brace_depth_tracks() {
        let src = "fn f() { if x { y(); } }";
        let toks = tokenize(src);
        let y = toks
            .iter()
            .find(|t| t.text(src) == "y")
            .expect("y token");
        assert_eq!(y.brace_depth, 2);
    }

    #[test]
    fn line_numbers() {
        let src = "a\nb\n  c";
        let toks = tokenize(src);
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2);
        assert_eq!(toks[2].line, 3);
    }

    #[test]
    fn multiline_string_advances_lines() {
        let src = "let s = \"one\ntwo\";\nnext";
        let toks = tokenize(src);
        let next = toks.iter().find(|t| t.text(src) == "next").unwrap();
        assert_eq!(next.line, 3);
    }
}
