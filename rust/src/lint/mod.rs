//! `symphony lint` — a std-only invariant checker for this repo.
//!
//! Seven PRs of desk-checked review discipline, turned into machine
//! rules (see `LINTS.md` at the repo root for the full catalogue and
//! the past bug motivating each rule):
//!
//! - `wire-schema-drift` — `coordinator/messages.rs` ⇄ `net/codec.rs`
//!   must stay a bijection modulo the documented exceptions.
//! - `float-free-hot-path` — integer-signature functions in the
//!   scheduling hot path must not grow float arithmetic.
//! - `unchecked-micros-arith` — no bare `+`/`-` on [`crate::core::time::Micros`]
//!   in wall-clock/wire-facing modules.
//! - `panic-free-wire-surface` — hostile input may kill a session,
//!   never the process.
//! - `lock-across-send` — no `Mutex`/`RwLock` guard live across a
//!   blocking channel/thread operation.
//! - `hot-path-channel` — no `std::sync::mpsc` channel construction
//!   inside `coordinator/` (hot hops ride `util::ring`).
//! - `unsafe-needs-safety` — every `unsafe` carries a `// SAFETY:`
//!   comment stating the invariant that makes it sound.
//! - `relaxed-ordering-reason` — every `Ordering::Relaxed` on the
//!   lock-free fabric states inline why no happens-before edge is
//!   needed (`// relaxed:` comment).
//! - `no-bare-eprintln` — no raw `eprintln!`/`println!` in
//!   `coordinator/` or `net/`; diagnostics go through the rate-limited
//!   logger (`obs/log.rs`).
//!
//! Findings can be silenced inline with
//! `// lint:allow(rule-name): reason` — on the offending line, or on a
//! line of its own directly above it. A suppression without a reason
//! does not suppress and is itself reported (rule `suppression`).
//!
//! Constraint inherited from the build environment: the registry is
//! offline, so there is no `syn`, no `regex`, no `clippy` — the lexer
//! and the structural scans are hand-rolled on `std` alone.

pub mod lexer;
pub mod rules;
pub mod source;

use std::fmt;
use std::io;
use std::path::Path;

use source::SourceTree;

/// One diagnostic: `file:line rule-name: message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{} {}: {}",
            self.file, self.line, self.rule, self.message
        )
    }
}

/// The meta-rule name under which bad suppressions are reported.
pub const SUPPRESSION_RULE: &str = "suppression";

/// Every rule name the checker knows, including the suppression
/// meta-rule (valid as a `--rule` filter and in `lint:allow(..)`).
pub fn rule_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = rules::all().iter().map(|r| r.name()).collect();
    names.push(SUPPRESSION_RULE);
    names
}

/// Lint an already-loaded tree. `only` restricts to a single rule name.
pub fn lint_tree(tree: &SourceTree, only: Option<&str>) -> Vec<Finding> {
    let mut raw = Vec::new();
    for rule in rules::all() {
        if let Some(o) = only {
            if o != rule.name() {
                continue;
            }
        }
        rule.check(tree, &mut raw);
    }

    // Suppression hygiene: a `lint:allow` with no reason or an unknown
    // rule name is itself a finding — and never suppresses anything.
    let known = rule_names();
    let mut out = Vec::new();
    if only.is_none() || only == Some(SUPPRESSION_RULE) {
        for f in &tree.files {
            for a in &f.allows {
                if a.rule.is_empty() {
                    out.push(Finding {
                        file: f.path.clone(),
                        line: a.line,
                        rule: SUPPRESSION_RULE,
                        message: "malformed lint:allow — expected lint:allow(rule-name): reason"
                            .to_string(),
                    });
                } else if !known.contains(&a.rule.as_str()) {
                    out.push(Finding {
                        file: f.path.clone(),
                        line: a.line,
                        rule: SUPPRESSION_RULE,
                        message: format!(
                            "lint:allow names unknown rule `{}` (known: {})",
                            a.rule,
                            known.join(", ")
                        ),
                    });
                } else if !a.has_reason {
                    out.push(Finding {
                        file: f.path.clone(),
                        line: a.line,
                        rule: SUPPRESSION_RULE,
                        message: format!(
                            "lint:allow({}) has no reason — write lint:allow({}): why it is safe",
                            a.rule, a.rule
                        ),
                    });
                }
            }
        }
    }

    // Apply (reasoned) suppressions to the rule findings.
    raw.retain(|fd| {
        let Some(file) = tree.file(&fd.file) else {
            return true;
        };
        !file.allows.iter().any(|a| {
            a.has_reason
                && a.rule == fd.rule
                && fd.line >= a.covers.0
                && fd.line <= a.covers.1
        })
    });
    out.extend(raw);
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}

/// Load `.rs` files under `root` and lint them.
pub fn run(root: &Path, only: Option<&str>) -> io::Result<Vec<Finding>> {
    let tree = SourceTree::load(root)?;
    Ok(lint_tree(&tree, only))
}

/// Lint in-memory `(path, source)` pairs — the fixture-test entry point.
pub fn lint_sources(sources: &[(&str, &str)], only: Option<&str>) -> Vec<Finding> {
    lint_tree(&SourceTree::from_memory(sources), only)
}
