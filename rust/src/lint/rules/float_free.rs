//! `float-free-hot-path` — integer-signature functions in the
//! scheduling hot path must not grow float arithmetic.
//!
//! PR 2 rebuilt the deferred scheduler's per-event path on integer
//! `Micros` math (floats live only in `core::profile::reference`, the
//! readable float mirror that property tests pin the integer path
//! against). The bug class this guards: a future change "fixes" an
//! integer rounding discrepancy by sneaking an `as f64` round-trip into
//! `latency()` or a matchmaking loop, silently reintroducing
//! per-event float cost and cross-platform rounding drift.
//!
//! Mechanics: inside the target files, any float literal or `f32`/`f64`
//! token is a finding when it appears in the body of a function whose
//! signature is float-free. Functions that declare floats in their
//! signature (`throughput(..) -> f64`) are visibly float and exempt, as
//! are item-level declarations (struct fields), `#[cfg(test)]` modules,
//! and the `reference` submodule.

use super::super::lexer::TokKind;
use super::super::source::{SourceFile, SourceTree};
use super::super::Finding;
use super::{path_matches, Rule};

pub struct FloatFreeHotPath;

const RULE: &str = "float-free-hot-path";

/// The hot-path files PR 2's invariant covers.
const TARGETS: &[&str] = &[
    "scheduler/deferred.rs",
    "scheduler/batch_policy.rs",
    "coordinator/rank_shard.rs",
    "core/profile.rs",
];

impl Rule for FloatFreeHotPath {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Finding>) {
        for f in &tree.files {
            if !TARGETS.iter().any(|t| path_matches(&f.path, t)) {
                continue;
            }
            check_file(f, out);
        }
    }
}

fn is_float_tok(f: &SourceFile, ci: usize) -> bool {
    match f.ckind(ci) {
        Some(TokKind::Float) => true,
        Some(TokKind::Ident) => {
            let t = f.ctext(ci);
            t == "f32" || t == "f64"
        }
        _ => false,
    }
}

fn check_file(f: &SourceFile, out: &mut Vec<Finding>) {
    for ci in 0..f.clen() {
        if !is_float_tok(f, ci) || f.in_test(ci) || f.in_mod("reference", ci) {
            continue;
        }
        let Some(func) = f.enclosing_fn(ci) else {
            // Item-level float declarations (struct fields, consts) are
            // visible API, not hot-path creep.
            continue;
        };
        // A function that declares floats in its signature is visibly
        // float — the rule only guards integer-by-signature functions.
        let sig_has_float = (func.sig_start..func.body_open).any(|si| is_float_tok(f, si));
        if sig_has_float {
            continue;
        }
        out.push(Finding {
            file: f.path.clone(),
            line: f.cline(ci),
            rule: RULE,
            message: format!(
                "float `{}` in integer-signature hot-path fn `{}` — keep the per-event path \
                 integer-only (PR 2); float math belongs in core::profile::reference or a \
                 float-signature helper",
                f.ctext(ci),
                func.name
            ),
        });
    }
}
