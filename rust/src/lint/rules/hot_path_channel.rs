//! `hot-path-channel` — no `std::sync::mpsc` channel construction
//! inside `coordinator/`.
//!
//! PR 7 moved every steady-state inter-thread hop (ingest inbox, model
//! worker inbox, rank-shard inbox) onto the bounded lock-free rings in
//! [`crate::util::ring`]: cache-padded Vyukov slots, adaptive
//! spin→yield→park drains, a documented full-queue policy per call
//! site. The bug class this guards: a later change quietly rebuilds a
//! coordinator queue on `std::sync::mpsc` — unbounded, mutex-backed on
//! contention, invisible to the `--busy-poll` and `--pin-cores`
//! machinery — and the fabric's latency and backpressure guarantees
//! silently regress.
//!
//! Mechanics: a call to `channel(..)` or `sync_channel(..)` (free or
//! path-qualified, including turbofish) in any file under
//! `coordinator/` is a finding, except in `#[cfg(test)]` code. The few
//! legitimate survivors — one-shot control-rate traffic like drain
//! acks — carry a named `// lint:allow(hot-path-channel): reason`
//! suppression.

use super::super::lexer::TokKind;
use super::super::source::{SourceFile, SourceTree};
use super::super::Finding;
use super::{is_method_call, Rule};

pub struct HotPathChannel;

const RULE: &str = "hot-path-channel";

impl Rule for HotPathChannel {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Finding>) {
        for f in &tree.files {
            if !in_coordinator(&f.path) {
                continue;
            }
            check_file(f, out);
        }
    }
}

/// Is `path` inside a `coordinator/` directory component?
fn in_coordinator(path: &str) -> bool {
    path.starts_with("coordinator/") || path.contains("/coordinator/")
}

/// Is the ident at `ci` a *construction* call — `channel(`,
/// `channel::<T>(`, `sync_channel(` — rather than an import, a method
/// of the same name, or a definition?
fn is_construction(f: &SourceFile, ci: usize) -> bool {
    if is_method_call(f, ci) {
        return false;
    }
    if ci > 0 && f.ctext(ci - 1) == "fn" {
        return false;
    }
    f.ctext(ci + 1) == "(" || (f.ctext(ci + 1) == "::" && f.ctext(ci + 2) == "<")
}

fn check_file(f: &SourceFile, out: &mut Vec<Finding>) {
    for ci in 0..f.clen() {
        if f.ckind(ci) != Some(TokKind::Ident) {
            continue;
        }
        let t = f.ctext(ci);
        if t != "channel" && t != "sync_channel" {
            continue;
        }
        if f.in_test(ci) || !is_construction(f, ci) {
            continue;
        }
        out.push(Finding {
            file: f.path.clone(),
            line: f.cline(ci),
            rule: RULE,
            message: format!(
                "`{t}(..)` constructs a std::sync::mpsc channel inside coordinator/ — \
                 hot inter-thread hops ride the bounded lock-free rings \
                 (util::ring, PR 7); if this queue really is one-shot \
                 control-rate traffic, say so with a named lint:allow"
            ),
        });
    }
}
