//! `lock-across-send` — no `Mutex`/`RwLock` guard live across a
//! blocking channel/thread operation.
//!
//! PR 3's `SleepWorkers` deadlock: shutdown took the worker-handle
//! mutex and called `.join()` while still holding it; a worker draining
//! its queue hit the same mutex and neither side could make progress.
//! The subtle variant this rule exists for is edition-2021 temporary
//! lifetime extension: in
//!
//! ```text
//! if let Some(h) = self.h.lock().unwrap().take() { h.join(); }
//! ```
//!
//! the guard temporary lives through the *whole* `if let` body, so the
//! join happens with the mutex held even though no guard is named.
//! (`let .. else` does NOT extend — scrutinee temporaries drop at the
//! end of the statement — so the rule leaves it alone.)
//!
//! What counts as acquiring a guard: `.lock()`, zero-argument
//! `.read()`/`.write()` (RwLock — io `read`/`write` always take a
//! buffer), and the poison-recovering [`crate::util::sync::relock`]
//! helper. What counts as blocking: `.send(`/`.recv(`/`.join(` (plus
//! the `_timeout` forms) while a guard binding is in scope or inside an
//! `if let`/`while let`/`match`/`for` whose scrutinee acquired the
//! guard. `Condvar::wait` is exempt — it releases the mutex it is
//! handed. A `drop(guard)` ends the guarded region.

use super::super::lexer::TokKind;
use super::super::source::{SourceFile, SourceTree};
use super::super::Finding;
use super::Rule;

pub struct LockAcrossSend;

const RULE: &str = "lock-across-send";

/// Blocking while holding a guard *binding* (scoped to end of block).
const BLOCKING: &[&str] = &["send", "recv", "join", "send_timeout", "recv_timeout"];
/// Blocking long enough to matter within a single statement's
/// temporary (`m.lock().unwrap().recv()`): `send` on std mpsc never
/// blocks, so only these are statement-local findings.
const BLOCKING_STMT: &[&str] = &["recv", "join", "recv_timeout"];

impl Rule for LockAcrossSend {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Finding>) {
        for f in &tree.files {
            check_file(f, out);
        }
    }
}

/// Brace depth of code token `ci`.
fn cdepth(f: &SourceFile, ci: usize) -> usize {
    match f.code.get(ci) {
        Some(&ti) => f.toks[ti].brace_depth,
        None => 0,
    }
}

/// If code token `ci` begins a guard-acquiring call, return the code
/// index of its closing `)`.
fn guard_call_end(f: &SourceFile, ci: usize) -> Option<usize> {
    if f.ckind(ci) != Some(TokKind::Ident) {
        return None;
    }
    let t = f.ctext(ci);
    let method = ci > 0 && f.ctext(ci - 1) == ".";
    if (t == "lock" || t == "read" || t == "write")
        && method
        && f.ctext(ci + 1) == "("
        && f.ctext(ci + 2) == ")"
    {
        return Some(ci + 2);
    }
    if t == "relock" && !method && f.ctext(ci + 1) == "(" {
        return Some(f.matching_close(ci + 1));
    }
    None
}

/// Scan backwards from `ci` to the start of the enclosing statement.
fn stmt_start(f: &SourceFile, ci: usize) -> usize {
    let mut depth = 0usize;
    let mut k = ci;
    while k > 0 {
        let prev = k - 1;
        match f.ckind(prev) {
            Some(TokKind::Close) if f.ctext(prev) != "}" => depth += 1,
            Some(TokKind::Open) if f.ctext(prev) != "{" => {
                if depth == 0 {
                    return k;
                }
                depth -= 1;
            }
            _ => {
                if depth == 0 {
                    let t = f.ctext(prev);
                    if t == ";" || t == "{" || t == "}" || t == "=>" || t == "," {
                        return k;
                    }
                }
            }
        }
        k = prev;
    }
    0
}

/// Scan forward from `from` for the end of the statement (`;`/`,` at
/// relative depth 0, or the token that closes the enclosing group).
fn stmt_end(f: &SourceFile, from: usize) -> usize {
    let mut depth = 0isize;
    let mut k = from;
    while k < f.clen() {
        match f.ckind(k) {
            Some(TokKind::Open) => depth += 1,
            Some(TokKind::Close) => {
                depth -= 1;
                if depth < 0 {
                    return k;
                }
            }
            _ => {
                if depth == 0 {
                    let t = f.ctext(k);
                    if t == ";" || t == "," {
                        return k;
                    }
                }
            }
        }
        k += 1;
    }
    f.clen().saturating_sub(1)
}

/// First blocking call in `[a, b]` drawn from `ops`.
fn blocking_in(f: &SourceFile, a: usize, b: usize, ops: &[&str]) -> Option<usize> {
    for ci in a..=b.min(f.clen().saturating_sub(1)) {
        if f.ckind(ci) == Some(TokKind::Ident)
            && ops.contains(&f.ctext(ci))
            && ci > 0
            && f.ctext(ci - 1) == "."
            && f.ctext(ci + 1) == "("
        {
            return Some(ci);
        }
    }
    None
}

fn finding(f: &SourceFile, guard_ci: usize, op_ci: usize, ctx: &str) -> Finding {
    Finding {
        file: f.path.clone(),
        line: f.cline(guard_ci),
        rule: RULE,
        message: format!(
            "guard acquired here is live across `.{}(` on line {} ({ctx}) — hoist the \
             locked access into its own statement so the guard drops first (PR 3 \
             SleepWorkers deadlock class)",
            f.ctext(op_ci),
            f.cline(op_ci),
        ),
    }
}

fn check_file(f: &SourceFile, out: &mut Vec<Finding>) {
    for ci in 0..f.clen() {
        let Some(call_end) = guard_call_end(f, ci) else {
            continue;
        };
        if f.in_test(ci) {
            continue;
        }
        let start = stmt_start(f, ci);
        let t0 = f.ctext(start);
        let t1 = f.ctext(start + 1);
        let is_scrutinee = matches!(t0, "match" | "for")
            || ((t0 == "if" || t0 == "while") && t1 == "let");

        if is_scrutinee {
            // Edition-2021: the scrutinee's guard temporary lives
            // through the whole body (and any else-chain).
            let Some(open) = body_open_after(f, call_end) else {
                continue;
            };
            let mut close = f.matching_close(open);
            if let Some(op) = blocking_in(f, open, close, BLOCKING) {
                out.push(finding(f, ci, op, "scrutinee temporary lives through the body"));
                continue;
            }
            // else-chain extension.
            while f.ctext(close + 1) == "else" {
                let Some(next_open) = body_open_after(f, close + 1) else {
                    break;
                };
                close = f.matching_close(next_open);
                if let Some(op) = blocking_in(f, next_open, close, BLOCKING) {
                    out.push(finding(
                        f,
                        ci,
                        op,
                        "scrutinee temporary lives through the else branch",
                    ));
                    break;
                }
            }
            continue;
        }

        if t0 == "let" {
            // `let .. else` drops scrutinee temporaries at statement
            // end — never an extended guard.
            let end = stmt_end(f, start);
            let mut has_else = false;
            let mut d = 0isize;
            for k in start..end {
                match f.ckind(k) {
                    Some(TokKind::Open) => d += 1,
                    Some(TokKind::Close) => d -= 1,
                    _ => {
                        if d == 0 && f.ctext(k) == "else" {
                            has_else = true;
                        }
                    }
                }
            }
            if has_else {
                continue;
            }
            if let Some(bind_end) = guard_tail_end(f, call_end) {
                // The binding IS a guard: live until end of block,
                // `drop(name)`, or end of file.
                let name = if f.ctext(start + 1) == "mut" {
                    f.ctext(start + 2).to_string()
                } else {
                    f.ctext(start + 1).to_string()
                };
                let depth = cdepth(f, start);
                let mut scope_end = f.clen().saturating_sub(1);
                for k in bind_end..f.clen() {
                    if f.ckind(k) == Some(TokKind::Close)
                        && f.ctext(k) == "}"
                        && cdepth(f, k) + 1 == depth
                    {
                        scope_end = k;
                        break;
                    }
                    if f.ctext(k) == "drop"
                        && f.ctext(k + 1) == "("
                        && f.ctext(k + 2) == name
                        && f.ctext(k + 3) == ")"
                    {
                        scope_end = k;
                        break;
                    }
                }
                if let Some(op) = blocking_in(f, bind_end, scope_end, BLOCKING) {
                    out.push(finding(f, ci, op, "guard binding still in scope"));
                }
            } else {
                // Temporary guard inside a larger let statement: only
                // blocking calls before the `;` run under it.
                let end = stmt_end(f, call_end);
                if let Some(op) = blocking_in(f, call_end + 1, end, BLOCKING_STMT) {
                    out.push(finding(f, ci, op, "temporary guard within this statement"));
                }
            }
            continue;
        }

        // Expression statement (or plain `if`/`while` condition): the
        // temporary dies at the statement/condition boundary.
        let end = stmt_end(f, call_end);
        if let Some(op) = blocking_in(f, call_end + 1, end, BLOCKING_STMT) {
            out.push(finding(f, ci, op, "temporary guard within this statement"));
        }
    }
}

/// First `{` after `from` with intervening parens balanced.
fn body_open_after(f: &SourceFile, from: usize) -> Option<usize> {
    let mut depth = 0isize;
    let mut k = from + 1;
    while k < f.clen() {
        match f.ckind(k) {
            Some(TokKind::Open) => {
                if f.ctext(k) == "{" && depth == 0 {
                    return Some(k);
                }
                depth += 1;
            }
            Some(TokKind::Close) => depth -= 1,
            _ => {}
        }
        k += 1;
    }
    None
}

/// If the call chain after a guard call keeps returning the guard
/// (`.unwrap()`, `.expect("..")`, `?`, `.unwrap_or_else(..)`) all the
/// way to a `;`, return the code index just past the `;`.
fn guard_tail_end(f: &SourceFile, call_end: usize) -> Option<usize> {
    let mut j = call_end + 1;
    loop {
        match f.ctext(j) {
            ";" => return Some(j + 1),
            "?" => j += 1,
            "." => {
                let m = f.ctext(j + 1);
                if matches!(m, "unwrap" | "expect" | "unwrap_or_else") && f.ctext(j + 2) == "(" {
                    j = f.matching_close(j + 2) + 1;
                } else {
                    return None;
                }
            }
            _ => return None,
        }
    }
}
