//! `unchecked-micros-arith` — no bare `+`/`-` on `Micros` in
//! wall-clock/wire-facing modules.
//!
//! PR 1's bug class: `Micros` wraps `u64`, and `Debug`-profile overflow
//! checks vanish in release, so `deadline - now` on a past deadline
//! silently wrapped to ~584 000 years of slack and a request that
//! should have shed was scheduled. `Sub` now panics in every profile
//! and `Add` is overflow-checked, but a panic on the serving path is
//! still an outage — code handling wall-clock or wire-supplied times
//! must use `saturating_sub`/`saturating_add` (or `checked_*`) and
//! decide the edge case explicitly.
//!
//! Scope: the serving-path modules where times come from a real clock
//! or a (possibly hostile) wire peer. Simulation/harness/baseline
//! files, where virtual time starts at zero and is bounded by the
//! experiment horizon, are deliberately outside the target list —
//! that is the rule's allowlist, documented in `LINTS.md`.
//!
//! Operand typing is heuristic (std-only lint, no type checker): an
//! operand is `Micros` if it is an identifier ascribed `: Micros`
//! anywhere in the file, one of the well-known time names below, a
//! `Micros(..)`/`Micros::..` constructor, or a call to a known
//! `Micros`-returning method. Either operand matching flags the op.

use super::super::lexer::TokKind;
use super::super::source::{SourceFile, SourceTree};
use super::super::Finding;
use super::{matching_open, path_matches, Rule};

pub struct UncheckedMicrosArith;

const RULE: &str = "unchecked-micros-arith";

/// Directories (trailing `/`) and files on the serving path.
const TARGET_DIRS: &[&str] = &["coordinator/", "net/", "serve/", "autoscale/"];
const TARGET_FILES: &[&str] = &[
    "scheduler/deferred.rs",
    "scheduler/timeout.rs",
    "core/types.rs",
];
/// The operator/helper definition site — `impl Add for Micros` et al.
/// live here by design.
const EXEMPT_FILES: &[&str] = &["core/time.rs"];

/// Names that are always `Micros` in this codebase, covering
/// pattern-destructured and wire-decoded bindings that carry no `:
/// Micros` ascription in the file using them.
const BUILTIN_MICROS_NAMES: &[&str] = &[
    "now",
    "deadline",
    "arrival",
    "free_at",
    "exec",
    "latest",
    "slack",
    "net_bound",
    "budget",
    "slo",
    "frontrun",
    "busy_until",
];

/// Methods known to return `Micros`.
const MICROS_METHODS: &[&str] = &["latency", "now", "saturating_add", "saturating_sub"];

impl Rule for UncheckedMicrosArith {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Finding>) {
        for f in &tree.files {
            let targeted = TARGET_DIRS
                .iter()
                .any(|d| f.path.contains(d) || f.path.starts_with(d.trim_end_matches('/')))
                || TARGET_FILES.iter().any(|t| path_matches(&f.path, t));
            let exempt = EXEMPT_FILES.iter().any(|t| path_matches(&f.path, t));
            if !targeted || exempt {
                continue;
            }
            check_file(f, out);
        }
    }
}

/// `std::time` types whose arithmetic is not ours to police.
fn is_std_time(name: &str) -> bool {
    matches!(name, "Instant" | "Duration" | "SystemTime")
}

fn in_set(f: &SourceFile, name: &str) -> bool {
    BUILTIN_MICROS_NAMES.contains(&name) || f.micros_idents.iter().any(|m| m == name)
}

/// Is the expression *ending* at code index `ci` (exclusive of the
/// operator) a `Micros` value?
fn left_is_micros(f: &SourceFile, op_ci: usize) -> bool {
    if op_ci == 0 {
        return false;
    }
    let p = op_ci - 1;
    match f.ckind(p) {
        Some(TokKind::Ident) => in_set(f, f.ctext(p)),
        Some(TokKind::Close) if f.ctext(p) == ")" => {
            // `callee(..) + x` — find the callee just before `(`.
            let open = matching_open(f, p);
            if open == 0 || open == p {
                return false;
            }
            let callee = open - 1;
            if f.ckind(callee) != Some(TokKind::Ident) {
                return false;
            }
            let name = f.ctext(callee);
            // `Instant::now() + timeout` is std time, not ours.
            if callee >= 2 && f.ctext(callee - 1) == "::" && is_std_time(f.ctext(callee - 2)) {
                return false;
            }
            name == "Micros" || MICROS_METHODS.contains(&name)
        }
        _ => false,
    }
}

/// Is the expression *starting* right after the operator a `Micros`
/// value? Walks a `a.b.c(..)`/`Micros::..` chain.
fn right_is_micros(f: &SourceFile, op_ci: usize) -> bool {
    let mut j = op_ci + 1;
    while matches!(f.ctext(j), "&" | "*" | "mut") {
        j += 1;
    }
    loop {
        if f.ckind(j) != Some(TokKind::Ident) {
            return false;
        }
        let t = f.ctext(j);
        if t == "Micros" {
            return true;
        }
        if is_std_time(t) {
            // `x + Duration::from_secs(..)` / `y - Instant::now()` are
            // std-time expressions with their own checked semantics.
            return false;
        }
        let next = f.ctext(j + 1);
        if next == "(" {
            if MICROS_METHODS.contains(&t) {
                return true;
            }
            // Skip the call, keep walking the chain.
            let close = f.matching_close(j + 1);
            if f.ctext(close + 1) == "." {
                j = close + 2;
                continue;
            }
            return false;
        }
        // A set ident decides the type only when it *ends* the chain:
        // `x + deadline` is Micros, but `x + last.0` is the u64 inside,
        // so a `.` continuation must be walked, not short-circuited.
        if in_set(f, t) && next != "::" && next != "." {
            return true;
        }
        if next == "." {
            j += 2;
            continue;
        }
        if next == "::" {
            j += 2;
            continue;
        }
        return false;
    }
}

fn check_file(f: &SourceFile, out: &mut Vec<Finding>) {
    for ci in 0..f.clen() {
        if f.ckind(ci) != Some(TokKind::Punct) {
            continue;
        }
        let op = f.ctext(ci);
        let assign = matches!(op, "+=" | "-=");
        if !matches!(op, "+" | "-") && !assign {
            continue;
        }
        if f.in_test(ci) {
            continue;
        }
        // Binary use only: `-x` and `&-` etc. are unary contexts.
        let prev_kind = if ci > 0 { f.ckind(ci - 1) } else { None };
        let binary = matches!(
            prev_kind,
            Some(TokKind::Ident) | Some(TokKind::Int) | Some(TokKind::Float) | Some(TokKind::Close)
        );
        if !binary {
            continue;
        }
        let micros = if assign {
            // `x += dur` — only the left side identifies the type.
            ci > 0
                && f.ckind(ci - 1) == Some(TokKind::Ident)
                && in_set(f, f.ctext(ci - 1))
        } else {
            left_is_micros(f, ci) || right_is_micros(f, ci)
        };
        if !micros {
            continue;
        }
        let (fix, why) = if op.starts_with('+') {
            ("saturating_add", "wraps on overflow in release")
        } else {
            ("saturating_sub", "panics on underflow")
        };
        out.push(Finding {
            file: f.path.clone(),
            line: f.cline(ci),
            rule: RULE,
            message: format!(
                "bare `{op}` on Micros ({why}) — use {fix}/checked_* and decide the edge \
                 case explicitly (PR 1 wrap class)"
            ),
        });
    }
}
