//! Rule registry and the shared token-query helpers rules lean on.

pub mod float_free;
pub mod hot_path_channel;
pub mod lock_send;
pub mod micros_arith;
pub mod no_bare_eprintln;
pub mod panic_free;
pub mod relaxed_reason;
pub mod unsafe_safety;
pub mod wire_drift;

use super::source::{SourceFile, SourceTree};
use super::Finding;

pub trait Rule {
    fn name(&self) -> &'static str;
    /// Append findings for `tree` to `out`. Rules see the whole tree so
    /// cross-file rules (wire-schema-drift) fit the same shape.
    fn check(&self, tree: &SourceTree, out: &mut Vec<Finding>);
}

/// All rules, in reporting-name order.
pub fn all() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(wire_drift::WireSchemaDrift),
        Box::new(float_free::FloatFreeHotPath),
        Box::new(micros_arith::UncheckedMicrosArith),
        Box::new(panic_free::PanicFreeWireSurface),
        Box::new(lock_send::LockAcrossSend),
        Box::new(hot_path_channel::HotPathChannel),
        Box::new(unsafe_safety::UnsafeNeedsSafety),
        Box::new(relaxed_reason::RelaxedOrderingReason),
        Box::new(no_bare_eprintln::NoBareEprintln),
    ]
}

/// Does `path` end with `suffix` on a path-component boundary?
/// (`net/codec.rs` matches `rust/src/net/codec.rs` but not
/// `mynet/codec.rs`.)
pub(crate) fn path_matches(path: &str, suffix: &str) -> bool {
    if path == suffix {
        return true;
    }
    path.ends_with(suffix)
        && path[..path.len() - suffix.len()].ends_with('/')
}

/// Is the code token at `ci` a method call `.name(`?
pub(crate) fn is_method_call(f: &SourceFile, ci: usize) -> bool {
    ci > 0 && f.ctext(ci - 1) == "." && f.ctext(ci + 1) == "("
}

/// For a `Close` token at code index `ci`, find its matching `Open`
/// going backwards. Returns `ci` itself on unbalanced input.
pub(crate) fn matching_open(f: &SourceFile, close_ci: usize) -> usize {
    use super::lexer::TokKind;
    let mut depth = 0usize;
    let mut ci = close_ci;
    loop {
        match f.ckind(ci) {
            Some(TokKind::Close) => depth += 1,
            Some(TokKind::Open) => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    return ci;
                }
            }
            _ => {}
        }
        if ci == 0 {
            return close_ci;
        }
        ci -= 1;
    }
}
