//! `no-bare-eprintln` — no raw `eprintln!`/`println!` in
//! `coordinator/` or `net/` outside `#[cfg(test)]` code.
//!
//! PR 10 routed every diagnostic on the serving path through the
//! rate-limited leveled logger (`obs/log.rs`): `SYMPHONY_LOG` level
//! filtering plus a per-call-site token bucket, so a reconnect storm
//! or a flapping peer emits a bounded number of lines instead of
//! filling the disk at wire rate. The bug class this guards: a later
//! change drops a bare `eprintln!` into a per-frame or per-session
//! path and the next fault injection run turns the log into the
//! bottleneck (stderr writes serialize on a lock, so a hot print site
//! is also a hidden synchronization point).
//!
//! Mechanics: an `eprintln` or `println` ident immediately followed by
//! `!` in any file under `coordinator/` or `net/` is a finding, except
//! in `#[cfg(test)]` code. Use `log_error!`/`log_warn!`/`log_info!`/
//! `log_debug!` instead; a deliberate raw print (e.g. machine-parsed
//! stdout) carries a named `// lint:allow(no-bare-eprintln): reason`
//! suppression.

use super::super::lexer::TokKind;
use super::super::source::{SourceFile, SourceTree};
use super::super::Finding;
use super::Rule;

pub struct NoBareEprintln;

const RULE: &str = "no-bare-eprintln";

impl Rule for NoBareEprintln {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Finding>) {
        for f in &tree.files {
            if !in_scope(&f.path) {
                continue;
            }
            check_file(f, out);
        }
    }
}

/// Is `path` inside a `coordinator/` or `net/` directory component?
fn in_scope(path: &str) -> bool {
    for dir in ["coordinator/", "net/"] {
        if path.starts_with(dir) || path.contains(&format!("/{dir}")) {
            return true;
        }
    }
    false
}

fn check_file(f: &SourceFile, out: &mut Vec<Finding>) {
    for ci in 0..f.clen() {
        if f.ckind(ci) != Some(TokKind::Ident) {
            continue;
        }
        let t = f.ctext(ci);
        if t != "eprintln" && t != "println" {
            continue;
        }
        // Only the macro invocation `name!(..)` — an ident that merely
        // shares the name (a local, a doc mention) is not a print.
        if f.ctext(ci + 1) != "!" {
            continue;
        }
        if f.in_test(ci) {
            continue;
        }
        out.push(Finding {
            file: f.path.clone(),
            line: f.cline(ci),
            rule: RULE,
            message: format!(
                "bare `{t}!` on the serving path — diagnostics in coordinator/ and \
                 net/ go through the rate-limited logger (log_error!/log_warn!/\
                 log_info!/log_debug!, obs/log.rs); a deliberate raw print needs \
                 a named lint:allow"
            ),
        });
    }
}
