//! `panic-free-wire-surface` — hostile input may kill a session, never
//! the process.
//!
//! PR 5's discipline: a rank server and its client talk over TCP to a
//! peer that may be malformed or malicious, and every decode failure
//! must surface as a clean `Err`/drop of that one session. A stray
//! `unwrap`, an `assert!`, or a direct slice index on these paths turns
//! a bad frame into a dead process — the difference between one
//! misbehaving peer and a fleet-wide outage.
//!
//! Scope: `net/server.rs`, `net/client.rs`, `net/transport.rs`, and
//! the decode half of `net/codec.rs` (functions named `encode_*` take
//! process-local input and are exempt by design). `debug_assert!` is
//! allowed — it compiles out of release builds. Setup-time failures
//! that cannot be driven by a peer (spawning the writer thread,
//! reading the bound listener's address) are annotated in place with
//! `lint:allow`.

use super::super::lexer::TokKind;
use super::super::source::{SourceFile, SourceTree};
use super::super::Finding;
use super::{is_method_call, path_matches, Rule};

pub struct PanicFreeWireSurface;

const RULE: &str = "panic-free-wire-surface";

const TARGETS: &[&str] = &[
    "net/server.rs",
    "net/client.rs",
    "net/codec.rs",
    "net/transport.rs",
];

/// Macros that panic in release builds.
const PANIC_MACROS: &[&str] = &[
    "panic",
    "unreachable",
    "todo",
    "unimplemented",
    "assert",
    "assert_eq",
    "assert_ne",
];

impl Rule for PanicFreeWireSurface {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Finding>) {
        for f in &tree.files {
            if !TARGETS.iter().any(|t| path_matches(&f.path, t)) {
                continue;
            }
            let codec = path_matches(&f.path, "net/codec.rs");
            check_file(f, codec, out);
        }
    }
}

fn finding(f: &SourceFile, ci: usize, message: String) -> Finding {
    Finding {
        file: f.path.clone(),
        line: f.cline(ci),
        rule: RULE,
        message,
    }
}

fn check_file(f: &SourceFile, codec: bool, out: &mut Vec<Finding>) {
    for ci in 0..f.clen() {
        if f.in_test(ci) {
            continue;
        }
        // In codec.rs only the decode half faces the wire.
        if codec {
            match f.enclosing_fn(ci) {
                Some(func) if func.name.starts_with("encode_") => continue,
                _ => {}
            }
        }
        match f.ckind(ci) {
            Some(TokKind::Ident) => {
                let t = f.ctext(ci);
                if (t == "unwrap" || t == "expect") && is_method_call(f, ci) {
                    out.push(finding(
                        f,
                        ci,
                        format!(
                            ".{t}() on the wire surface — a hostile frame must kill the \
                             session, not the process; handle the Err/None (PR 5)"
                        ),
                    ));
                } else if PANIC_MACROS.contains(&t) && f.ctext(ci + 1) == "!" {
                    out.push(finding(
                        f,
                        ci,
                        format!(
                            "{t}! on the wire surface — panics in release; return an error \
                             or drop the session (debug_assert! is allowed)"
                        ),
                    ));
                }
            }
            Some(TokKind::Open) if f.ctext(ci) == "[" => {
                // Indexing: `expr[..]` — the token before `[` ends an
                // expression. `#[attr]`, `&[u8]`, `vec![..]` etc. do not.
                let prev = if ci > 0 { f.ckind(ci - 1) } else { None };
                let indexing = matches!(prev, Some(TokKind::Ident) | Some(TokKind::Close));
                if indexing {
                    out.push(finding(
                        f,
                        ci,
                        "direct slice index on the wire surface — panics on out-of-bounds; \
                         use .get()/.get_mut() and handle None"
                            .to_string(),
                    ));
                }
            }
            _ => {}
        }
    }
}
