//! `relaxed-ordering-reason` — every `Ordering::Relaxed` on the
//! lock-free fabric carries an inline justification.
//!
//! `Relaxed` is the ordering you reach for when a counter is advisory
//! — and the ordering that silently breaks a publication protocol when
//! a later edit starts handing payloads over the same atomic (exactly
//! the seeded bug `symphony check` demonstrates: downgrade the ring's
//! slot-publish to Relaxed and the consumer reads an unsynchronized
//! payload). The fabric's desk-checks argued each Relaxed site by hand;
//! this rule makes the argument load-bearing: each use states *why* no
//! ordering is needed, so weakening a protocol edge requires deleting
//! a written claim, not just editing an enum variant.
//!
//! Scope: the fabric files only — `util/ring.rs`, `util/shim.rs`,
//! `coordinator/router.rs`. Plain statistics counters elsewhere
//! (`coordinator/ingest.rs` drop counts etc.) are not protocol edges.
//!
//! Grammar: a comment containing `relaxed:` trailing any line of the
//! statement, or an own-line comment run directly above the
//! statement's first line (a multi-line `fetch_update` call is one
//! statement — its orderings sit on continuation lines, covered by the
//! comment above the statement). `#[cfg(test)]` modules are exempt;
//! `// lint:allow(relaxed-ordering-reason): reason` also works.

use std::collections::HashSet;

use super::super::lexer::TokKind;
use super::super::source::{SourceFile, SourceTree};
use super::super::Finding;
use super::{path_matches, Rule};

pub struct RelaxedOrderingReason;

const RULE: &str = "relaxed-ordering-reason";

const TARGETS: &[&str] = &["util/ring.rs", "util/shim.rs", "coordinator/router.rs"];

impl Rule for RelaxedOrderingReason {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Finding>) {
        for f in &tree.files {
            if TARGETS.iter().any(|t| path_matches(&f.path, t)) {
                check_file(f, out);
            }
        }
    }
}

struct Lines {
    code: HashSet<usize>,
    comment: HashSet<usize>,
    /// Lines bearing a comment that contains `relaxed:`.
    reason: HashSet<usize>,
}

fn scan_lines(f: &SourceFile) -> Lines {
    let mut l = Lines {
        code: HashSet::new(),
        comment: HashSet::new(),
        reason: HashSet::new(),
    };
    for t in &f.toks {
        let text = t.text(&f.text);
        if matches!(t.kind, TokKind::LineComment | TokKind::BlockComment) {
            let span = text.matches('\n').count();
            for line in t.line..=t.line + span {
                l.comment.insert(line);
                if text.contains("relaxed:") {
                    l.reason.insert(line);
                }
            }
        } else {
            l.code.insert(t.line);
        }
    }
    l
}

/// First line of the statement containing code token `ci`: walk code
/// tokens backwards to the nearest `;` / `{` / `}` (comments don't
/// count — a justifying comment block may sit mid-walk).
fn stmt_first_line(f: &SourceFile, ci: usize) -> usize {
    let mut j = ci;
    while j > 0 {
        let t = f.ctext(j - 1);
        if t == ";" || t == "{" || t == "}" {
            break;
        }
        j -= 1;
    }
    f.cline(j)
}

fn justified(l: &Lines, first_line: usize, use_line: usize) -> bool {
    // Trailing comment on any line of the (possibly multi-line)
    // statement.
    if (first_line..=use_line).any(|ln| l.reason.contains(&ln)) {
        return true;
    }
    // Own-line comment run directly above the statement.
    let mut k = first_line;
    while k > 1 {
        k -= 1;
        if l.code.contains(&k) {
            return false;
        }
        if l.comment.contains(&k) {
            if l.reason.contains(&k) {
                return true;
            }
            continue;
        }
        return false; // blank line breaks adjacency
    }
    false
}

fn check_file(f: &SourceFile, out: &mut Vec<Finding>) {
    let lines = scan_lines(f);
    let mut flagged: HashSet<usize> = HashSet::new();
    for ci in 0..f.clen() {
        if f.ckind(ci) != Some(TokKind::Ident) || f.ctext(ci) != "Relaxed" {
            continue;
        }
        if f.in_test(ci) {
            continue;
        }
        let use_line = f.cline(ci);
        let first_line = stmt_first_line(f, ci);
        if justified(&lines, first_line, use_line) || !flagged.insert(use_line) {
            continue;
        }
        out.push(Finding {
            file: f.path.clone(),
            line: use_line,
            rule: RULE,
            message: "Ordering::Relaxed on a fabric atomic without a `// relaxed:` \
                      justification — state why no happens-before edge is needed \
                      here (see the seeded-ring-relaxed-publish model for what a \
                      missing edge costs)"
                .to_string(),
        });
    }
}
