//! `unsafe-needs-safety` — every `unsafe` carries a `// SAFETY:`
//! comment stating the invariant that makes it sound.
//!
//! PR 7's fabric concentrated all of this repo's `unsafe` into the
//! ring's slot protocol, and the desk-check that landed it found one
//! live gap: the `sched_setaffinity` FFI call in `util/affinity.rs`
//! shipped with no written argument for why the raw pointer and byte
//! size were right. The argument existed — in the PR discussion, not
//! the file. This rule pins the discipline: the soundness argument
//! lives next to the `unsafe` it justifies, where the next edit to
//! that code must confront it.
//!
//! Grammar: a comment containing `SAFETY:` on the same line as the
//! `unsafe` token, or an own-line comment run directly above it. A run
//! of consecutive `unsafe impl` lines (Send + Sync pairs) may share
//! one comment — the walk skips upward over code lines that contain
//! another `unsafe`. `#[cfg(test)]` modules are exempt.

use std::collections::HashSet;

use super::super::lexer::TokKind;
use super::super::source::{SourceFile, SourceTree};
use super::super::Finding;
use super::Rule;

pub struct UnsafeNeedsSafety;

const RULE: &str = "unsafe-needs-safety";

impl Rule for UnsafeNeedsSafety {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Finding>) {
        for f in &tree.files {
            check_file(f, out);
        }
    }
}

/// Per-line facts the justification walk consults.
struct Lines {
    code: HashSet<usize>,
    comment: HashSet<usize>,
    /// Lines bearing a comment that contains `SAFETY:`.
    safety: HashSet<usize>,
    /// Lines bearing an `unsafe` code token.
    has_unsafe: HashSet<usize>,
}

fn scan_lines(f: &SourceFile) -> Lines {
    let mut l = Lines {
        code: HashSet::new(),
        comment: HashSet::new(),
        safety: HashSet::new(),
        has_unsafe: HashSet::new(),
    };
    for t in &f.toks {
        let text = t.text(&f.text);
        if matches!(t.kind, TokKind::LineComment | TokKind::BlockComment) {
            // A block comment spans lines; credit every line it covers.
            let span = text.matches('\n').count();
            for line in t.line..=t.line + span {
                l.comment.insert(line);
                if text.contains("SAFETY:") {
                    l.safety.insert(line);
                }
            }
        } else {
            l.code.insert(t.line);
            if t.kind == TokKind::Ident && text == "unsafe" {
                l.has_unsafe.insert(t.line);
            }
        }
    }
    l
}

/// Does the `unsafe` on `line` have a SAFETY comment — same line, or
/// an own-line comment run directly above (skipping over sibling
/// `unsafe` code lines so a Send/Sync impl pair can share one)?
fn justified(l: &Lines, line: usize) -> bool {
    if l.safety.contains(&line) {
        return true;
    }
    let mut k = line;
    while k > 1 {
        k -= 1;
        if l.code.contains(&k) {
            if l.safety.contains(&k) {
                return true; // trailing SAFETY comment on the line above
            }
            if l.has_unsafe.contains(&k) {
                continue; // sibling unsafe; the shared comment is higher up
            }
            return false;
        }
        if l.comment.contains(&k) {
            if l.safety.contains(&k) {
                return true;
            }
            continue; // earlier line of a multi-line comment run
        }
        return false; // blank line breaks adjacency
    }
    false
}

fn check_file(f: &SourceFile, out: &mut Vec<Finding>) {
    let lines = scan_lines(f);
    let mut flagged: HashSet<usize> = HashSet::new();
    for ci in 0..f.clen() {
        if f.ckind(ci) != Some(TokKind::Ident) || f.ctext(ci) != "unsafe" {
            continue;
        }
        if f.in_test(ci) {
            continue;
        }
        let line = f.cline(ci);
        if justified(&lines, line) || !flagged.insert(line) {
            continue;
        }
        out.push(Finding {
            file: f.path.clone(),
            line,
            rule: RULE,
            message: "`unsafe` without a `// SAFETY:` comment — state the invariant \
                      that makes this sound, on the line above or at the end of this \
                      line (PR 7's affinity FFI shipped without one)"
                .to_string(),
        });
    }
}
