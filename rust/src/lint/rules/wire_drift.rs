//! `wire-schema-drift` — the wire codec must mirror the in-process
//! control enums.
//!
//! PR 5 put the rank tier behind a real wire and left the two
//! vocabularies synchronized by a comment ("keep the two in sync").
//! This rule replaces that discipline. It extracts `ToRank`/`ToModel`
//! from `coordinator/messages.rs` and `WireToRank`/`WireFromRank` from
//! `net/codec.rs` and verifies the bijection, modulo the exceptions the
//! design documents:
//!
//! - `ToRank::Shutdown` never crosses the wire (a remote shutdown is a
//!   connection close).
//! - `ToRank::Drain` drops its in-process `ack: Sender<GpuId>` field;
//!   the ack returns as the extra `WireFromRank::DrainAck` frame.
//! - `ToModel::{Request, Requests, Shutdown}` are frontend-originated
//!   and never shard-originated, so they have no down-frame.
//! - `ToModel::Reregister` is the client-side reconnect nudge — minted
//!   by the wire client when a session heals, never by a shard, so it
//!   too has no down-frame.
//!
//! It also checks that every wire variant appears in all four
//! encode/decode bodies. The decode half is the valuable one: decode
//! dispatches on an integer tag, so a forgotten decode arm is *not* a
//! compile error — it is a runtime `BadTag` on a perfectly valid frame.
//!
//! Finally it mirrors the *handshake*: every field of `ServerPreamble`
//! and `ClientHello` must be touched by both its encode and its decode
//! function. The handshake is fixed-offset (no per-frame tags), so a
//! field added to the struct and encoded but not decoded — or decoded
//! but never written — silently skews every later offset (the reconnect
//! epoch/session pair was added exactly this way; this check keeps the
//! two sides honest).

use super::super::source::{EnumDecl, SourceFile, SourceTree};
use super::super::Finding;
use super::{path_matches, Rule};

pub struct WireSchemaDrift;

const RULE: &str = "wire-schema-drift";
const MESSAGES_PATH: &str = "coordinator/messages.rs";
const CODEC_PATH: &str = "net/codec.rs";

/// `ToRank` variants that never cross the wire.
const TO_RANK_LOCAL_ONLY: &[&str] = &["Shutdown"];
/// `ToModel` variants originated by the frontend/ingest side (or by
/// the wire client itself — `Reregister` is the reconnect nudge), not
/// by a rank shard — they have no down-frame.
const TO_MODEL_FRONTEND_ONLY: &[&str] = &["Request", "Requests", "Shutdown", "Reregister"];
/// Wire-only down variants (in-process delivery uses another channel).
const FROM_RANK_WIRE_ONLY: &[&str] = &["DrainAck"];
/// Per-variant fields dropped on the wire: (variant, field, why).
const DROPPED_FIELDS: &[(&str, &str)] = &[("Drain", "ack")];

impl Rule for WireSchemaDrift {
    fn name(&self) -> &'static str {
        RULE
    }

    fn check(&self, tree: &SourceTree, out: &mut Vec<Finding>) {
        let msgs = tree.files.iter().find(|f| path_matches(&f.path, MESSAGES_PATH));
        let codec = tree.files.iter().find(|f| path_matches(&f.path, CODEC_PATH));
        let (Some(msgs), Some(codec)) = (msgs, codec) else {
            // Nothing to cross-check in this tree (e.g. rule fixtures
            // for other rules).
            return;
        };
        let Some(to_rank) = find_enum(msgs, "ToRank", out) else {
            return;
        };
        let Some(to_model) = find_enum(msgs, "ToModel", out) else {
            return;
        };
        let Some(wire_up) = find_enum(codec, "WireToRank", out) else {
            return;
        };
        let Some(wire_down) = find_enum(codec, "WireFromRank", out) else {
            return;
        };

        // Up direction: ToRank minus local-only == WireToRank.
        for (v, fields) in &to_rank.variants {
            if TO_RANK_LOCAL_ONLY.contains(&v.as_str()) {
                continue;
            }
            match variant(wire_up, v) {
                None => out.push(finding(
                    codec,
                    wire_up.line,
                    format!(
                        "WireToRank is missing `{v}` — ToRank::{v} cannot reach a remote shard \
                         (add the wire variant + tag + encode/decode arms, or document it in \
                         the drift rule's exception table)"
                    ),
                )),
                Some(wf) => {
                    let mut expect = fields.clone();
                    expect.retain(|fname| {
                        !DROPPED_FIELDS
                            .iter()
                            .any(|(dv, df)| dv == v && df == fname)
                    });
                    check_fields(codec, wire_up.line, "WireToRank", v, wf, &expect, out);
                }
            }
        }
        for (v, _) in &wire_up.variants {
            if variant(to_rank, v).is_none() {
                out.push(finding(
                    msgs,
                    to_rank.line,
                    format!("WireToRank::{v} has no ToRank counterpart — dead wire vocabulary"),
                ));
            }
        }

        // Down direction: shard-originated ToModel verdicts ==
        // WireFromRank minus wire-only.
        for (v, fields) in &to_model.variants {
            if TO_MODEL_FRONTEND_ONLY.contains(&v.as_str()) {
                continue;
            }
            match variant(wire_down, v) {
                None => out.push(finding(
                    codec,
                    wire_down.line,
                    format!(
                        "WireFromRank is missing shard-originated verdict `{v}` — a remote \
                         shard cannot deliver ToModel::{v} (add the wire variant, or add {v} \
                         to the frontend-originated allowlist in the drift rule)"
                    ),
                )),
                Some(wf) => {
                    check_fields(codec, wire_down.line, "WireFromRank", v, wf, fields, out)
                }
            }
        }
        for (v, _) in &wire_down.variants {
            if FROM_RANK_WIRE_ONLY.contains(&v.as_str()) {
                continue;
            }
            if variant(to_model, v).is_none() {
                out.push(finding(
                    msgs,
                    to_model.line,
                    format!("WireFromRank::{v} has no ToModel counterpart — dead wire vocabulary"),
                ));
            }
        }

        // Encode/decode arm presence for every wire variant.
        check_arms(codec, "encode_up", "WireToRank", wire_up, out);
        check_arms(codec, "decode_up", "WireToRank", wire_up, out);
        check_arms(codec, "encode_down", "WireFromRank", wire_down, out);
        check_arms(codec, "decode_down", "WireFromRank", wire_down, out);

        // Handshake mirroring: both sides of each fixed-offset struct.
        for (sname, enc, dec) in HANDSHAKE_STRUCTS {
            check_handshake(codec, sname, enc, dec, out);
        }
    }
}

/// Fixed-offset handshake structs and their encode/decode pairs.
const HANDSHAKE_STRUCTS: &[(&str, &str, &str)] = &[
    ("ServerPreamble", "encode_preamble", "decode_preamble"),
    ("ClientHello", "encode_hello", "decode_hello"),
];

/// Every field of handshake struct `sname` must be named inside both
/// `enc`'s and `dec`'s body. Handshake frames carry no per-field tags,
/// so a one-sided edit shifts every later byte offset at runtime
/// without any compile-time complaint.
fn check_handshake(
    codec: &SourceFile,
    sname: &str,
    enc: &str,
    dec: &str,
    out: &mut Vec<Finding>,
) {
    let parsed = struct_fields(codec, sname);
    let has_enc = codec.fns.iter().any(|f| f.name == enc);
    let has_dec = codec.fns.iter().any(|f| f.name == dec);
    if parsed.is_none() && !has_enc && !has_dec {
        // A codec with no handshake at all (rule fixtures) is not
        // drift; a *partial* rename below is.
        return;
    }
    let Some((line, fields)) = parsed else {
        out.push(finding(
            codec,
            1,
            format!("expected handshake struct `{sname}` not found — the drift rule mirrors it"),
        ));
        return;
    };
    for fn_name in [enc, dec] {
        let Some(f) = codec.fns.iter().find(|f| f.name == fn_name) else {
            out.push(finding(
                codec,
                1,
                format!("expected `fn {fn_name}` not found — the drift rule mirrors {sname}"),
            ));
            continue;
        };
        for field in &fields {
            let present = (f.body_open..=f.body_close).any(|ci| codec.ctext(ci) == field);
            if !present {
                out.push(finding(
                    codec,
                    line,
                    format!(
                        "`{fn_name}` never touches {sname}::{field} — handshake frames are \
                         fixed-offset, so a field {} on one side only silently skews every \
                         later offset",
                        if fn_name.starts_with("encode") {
                            "decoded but never encoded"
                        } else {
                            "encoded but never decoded"
                        }
                    ),
                ));
            }
        }
    }
}

/// Field names of `struct name { .. }` in `f`, with the decl line.
/// Same token discipline as the enum scanner: idents directly followed
/// by a single `:` at the struct's own brace depth.
fn struct_fields(f: &SourceFile, name: &str) -> Option<(usize, Vec<String>)> {
    for ci in 0..f.clen() {
        if f.ctext(ci) != "struct" || f.ctext(ci + 1) != name || f.ctext(ci + 2) != "{" {
            continue;
        }
        let line = f.cline(ci);
        let close = f.matching_close(ci + 2);
        let mut fields = Vec::new();
        let mut depth = 0usize;
        let mut m = ci + 3;
        while m < close {
            match f.ckind(m) {
                Some(super::super::lexer::TokKind::Open) => depth += 1,
                Some(super::super::lexer::TokKind::Close) => depth = depth.saturating_sub(1),
                Some(super::super::lexer::TokKind::Ident)
                    if depth == 0 && f.ctext(m + 1) == ":" && f.ctext(m + 2) != ":" =>
                {
                    fields.push(f.ctext(m).to_string());
                }
                _ => {}
            }
            m += 1;
        }
        return Some((line, fields));
    }
    None
}

fn finding(f: &SourceFile, line: usize, message: String) -> Finding {
    Finding {
        file: f.path.clone(),
        line,
        rule: RULE,
        message,
    }
}

fn find_enum<'a>(f: &'a SourceFile, name: &str, out: &mut Vec<Finding>) -> Option<&'a EnumDecl> {
    let e = f.enums.iter().find(|e| e.name == name);
    if e.is_none() {
        out.push(finding(
            f,
            1,
            format!("expected enum `{name}` not found — the drift rule tracks it"),
        ));
    }
    e
}

fn variant<'a>(e: &'a EnumDecl, name: &str) -> Option<&'a Vec<String>> {
    e.variants
        .iter()
        .find(|(v, _)| v == name)
        .map(|(_, fields)| fields)
}

fn check_fields(
    codec: &SourceFile,
    line: usize,
    enum_name: &str,
    variant: &str,
    wire_fields: &[String],
    expect: &[String],
    out: &mut Vec<Finding>,
) {
    let mut a: Vec<&str> = wire_fields.iter().map(|s| s.as_str()).collect();
    let mut b: Vec<&str> = expect.iter().map(|s| s.as_str()).collect();
    a.sort_unstable();
    b.sort_unstable();
    if a != b {
        out.push(finding(
            codec,
            line,
            format!(
                "{enum_name}::{variant} fields {{{}}} drift from the in-process message's \
                 {{{}}} (modulo documented dropped fields)",
                a.join(", "),
                b.join(", "),
            ),
        ));
    }
}

/// Every wire variant must be named (as `Enum::Variant`) inside the
/// body of `fn_name`.
fn check_arms(
    codec: &SourceFile,
    fn_name: &str,
    enum_name: &str,
    e: &EnumDecl,
    out: &mut Vec<Finding>,
) {
    let Some(f) = codec.fns.iter().find(|f| f.name == fn_name) else {
        out.push(finding(
            codec,
            1,
            format!("expected `fn {fn_name}` not found — the drift rule checks its arms"),
        ));
        return;
    };
    for (v, _) in &e.variants {
        let mut present = false;
        for ci in f.body_open..=f.body_close {
            if codec.ctext(ci) == v
                && ci >= 2
                && codec.ctext(ci - 1) == "::"
                && codec.ctext(ci - 2) == enum_name
            {
                present = true;
                break;
            }
        }
        if !present {
            out.push(finding(
                codec,
                f.line,
                format!(
                    "`{fn_name}` has no arm for {enum_name}::{v}\
                     {}",
                    if fn_name.starts_with("decode") {
                        " — a forgotten decode arm is not a compile error, it is a runtime \
                         BadTag on a valid frame"
                    } else {
                        ""
                    }
                ),
            ));
        }
    }
}
