//! Source model for the lint pass: a parsed file (token stream plus the
//! structural facts rules need) and a tree of them.
//!
//! "Parsed" is generous — we extract only what the rules consume:
//! - inline module spans (`mod name { .. }`), used for `#[cfg(test)]`
//!   exclusion and the `core::profile::reference` carve-out,
//! - function spans (name, signature token range, body token range),
//! - enum declarations (variant names + field names), for the
//!   wire-schema-drift rule,
//! - identifiers ascribed `: Micros`, for the arithmetic rule,
//! - `// lint:allow(rule): reason` suppressions.

use std::fs;
use std::io;
use std::path::Path;

use super::lexer::{tokenize, TokKind, Token};

/// A `// lint:allow(rule): reason` comment.
#[derive(Debug, Clone)]
pub struct Suppression {
    /// Line the comment sits on.
    pub line: usize,
    /// Line(s) the suppression covers: its own line, and — when the
    /// comment stands alone — the next line holding code.
    pub covers: (usize, usize),
    /// Rule name inside the parentheses.
    pub rule: String,
    /// Whether a non-empty `: reason` followed the closing paren.
    pub has_reason: bool,
}

/// An inline `mod name { .. }` item.
#[derive(Debug, Clone)]
pub struct ModSpan {
    pub name: String,
    /// `true` if the mod carries a `#[cfg(test)]` attribute.
    pub cfg_test: bool,
    /// Code-token index range of the body, inclusive of both braces.
    pub body: (usize, usize),
}

/// A `fn` item (or nested fn).
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub line: usize,
    /// Code-token index of the `fn` keyword.
    pub sig_start: usize,
    /// Code-token index of the body `{` (== sig end + 1).
    pub body_open: usize,
    /// Code-token index of the matching `}`.
    pub body_close: usize,
}

/// An enum declaration: name plus (variant, field-names) pairs. Tuple
/// variants get synthesized positional names `"0"`, `"1"`, ...
#[derive(Debug, Clone)]
pub struct EnumDecl {
    pub name: String,
    pub line: usize,
    pub variants: Vec<(String, Vec<String>)>,
}

pub struct SourceFile {
    /// Path as shown in diagnostics (relative to the lint root).
    pub path: String,
    pub text: String,
    /// Full token stream including comments.
    pub toks: Vec<Token>,
    /// Indices into `toks` of code tokens (comments stripped).
    pub code: Vec<usize>,
    pub mods: Vec<ModSpan>,
    pub fns: Vec<FnSpan>,
    pub enums: Vec<EnumDecl>,
    pub allows: Vec<Suppression>,
    /// Identifiers ascribed `: Micros` anywhere in the file (params,
    /// lets, struct fields) — the arithmetic rule's local type facts.
    pub micros_idents: Vec<String>,
}

impl SourceFile {
    pub fn parse(path: String, text: String) -> SourceFile {
        let toks = tokenize(&text);
        let code: Vec<usize> = toks
            .iter()
            .enumerate()
            .filter(|(_, t)| {
                !matches!(t.kind, TokKind::LineComment | TokKind::BlockComment)
            })
            .map(|(i, _)| i)
            .collect();
        let mut f = SourceFile {
            path,
            text,
            toks,
            code,
            mods: Vec::new(),
            fns: Vec::new(),
            enums: Vec::new(),
            allows: Vec::new(),
            micros_idents: Vec::new(),
        };
        f.scan_mods();
        f.scan_fns();
        f.scan_enums();
        f.scan_allows();
        f.scan_micros_idents();
        f
    }

    /// Text of the code token at code-index `ci` ("" past the end).
    pub fn ctext(&self, ci: usize) -> &str {
        match self.code.get(ci) {
            Some(&ti) => self.toks[ti].text(&self.text),
            None => "",
        }
    }

    /// Kind of the code token at code-index `ci`.
    pub fn ckind(&self, ci: usize) -> Option<TokKind> {
        self.code.get(ci).map(|&ti| self.toks[ti].kind)
    }

    /// Line of the code token at code-index `ci`.
    pub fn cline(&self, ci: usize) -> usize {
        match self.code.get(ci) {
            Some(&ti) => self.toks[ti].line,
            None => 0,
        }
    }

    /// Number of code tokens.
    pub fn clen(&self) -> usize {
        self.code.len()
    }

    /// Is code token `ci` inside a `#[cfg(test)]` mod body?
    pub fn in_test(&self, ci: usize) -> bool {
        self.mods
            .iter()
            .any(|m| m.cfg_test && ci >= m.body.0 && ci <= m.body.1)
    }

    /// Is code token `ci` inside a mod named `name`?
    pub fn in_mod(&self, name: &str, ci: usize) -> bool {
        self.mods
            .iter()
            .any(|m| m.name == name && ci >= m.body.0 && ci <= m.body.1)
    }

    /// The innermost fn whose body contains code token `ci`.
    pub fn enclosing_fn(&self, ci: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| ci > f.body_open && ci < f.body_close)
            .min_by_key(|f| f.body_close - f.body_open)
    }

    /// Given the code index of an `Open` token, find its matching
    /// `Close` (same bracket family by nesting count). Returns the last
    /// code index on unbalanced input rather than panicking.
    pub fn matching_close(&self, open_ci: usize) -> usize {
        let mut depth = 0usize;
        let mut ci = open_ci;
        while ci < self.code.len() {
            match self.ckind(ci) {
                Some(TokKind::Open) => depth += 1,
                Some(TokKind::Close) => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        return ci;
                    }
                }
                _ => {}
            }
            ci += 1;
        }
        self.code.len().saturating_sub(1)
    }

    fn scan_mods(&mut self) {
        let mut found = Vec::new();
        for ci in 0..self.code.len() {
            if self.ctext(ci) != "mod" {
                continue;
            }
            // `mod name {` — skip `mod name;` declarations.
            if self.ckind(ci + 1) != Some(TokKind::Ident) || self.ctext(ci + 2) != "{" {
                continue;
            }
            let name = self.ctext(ci + 1).to_string();
            // Look back for a `#[cfg(test)]` attribute: `#` `[` `cfg`
            // `(` `test` `)` `]` possibly with other attributes between
            // it and the mod keyword.
            let cfg_test = self.has_cfg_test_attr(ci);
            let close = self.matching_close(ci + 2);
            found.push(ModSpan {
                name,
                cfg_test,
                body: (ci + 2, close),
            });
        }
        self.mods = found;
    }

    /// Walk attributes immediately preceding code index `item_ci`
    /// looking for `#[cfg(test)]`.
    fn has_cfg_test_attr(&self, item_ci: usize) -> bool {
        let mut ci = item_ci;
        // Skip leading visibility / keywords back to the attrs:
        // attributes end with `]`, so walk back over `pub`, `(crate)` etc.
        while ci > 0 {
            let prev = self.ctext(ci - 1);
            if prev == "pub" || prev == "crate" || prev == ")" || prev == "(" {
                ci -= 1;
                continue;
            }
            break;
        }
        // Now repeatedly match a trailing `... ]` attribute.
        while ci >= 2 && self.ctext(ci - 1) == "]" {
            // Find the matching `[` going backwards.
            let mut depth = 0usize;
            let mut k = ci - 1;
            loop {
                match self.ctext(k) {
                    "]" => depth += 1,
                    "[" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                if k == 0 {
                    return false;
                }
                k -= 1;
            }
            if k == 0 || self.ctext(k - 1) != "#" {
                return false;
            }
            // Attribute tokens are code[k..ci-1]; check for cfg(test).
            let mut j = k + 1;
            let mut is_cfg = false;
            while j < ci - 1 {
                if self.ctext(j) == "cfg" && self.ctext(j + 1) == "(" {
                    is_cfg = true;
                }
                if is_cfg && self.ctext(j) == "test" {
                    return true;
                }
                j += 1;
            }
            ci = k - 1; // step over this attribute, try the one before
        }
        false
    }

    fn scan_fns(&mut self) {
        let mut found = Vec::new();
        for ci in 0..self.code.len() {
            if self.ctext(ci) != "fn" {
                continue;
            }
            // `fn` in fn-pointer types (`fn(u32) -> u32`) has no name.
            if self.ckind(ci + 1) != Some(TokKind::Ident) {
                continue;
            }
            let name = self.ctext(ci + 1).to_string();
            let line = self.cline(ci);
            // Scan forward for the body `{` with all parens closed.
            // A `;` at paren depth 0 means a bodyless declaration.
            let mut paren = 0isize;
            let mut k = ci + 2;
            let mut body_open = None;
            while k < self.code.len() {
                match self.ctext(k) {
                    "(" | "[" => paren += 1,
                    ")" | "]" => paren -= 1,
                    "{" if paren == 0 => {
                        body_open = Some(k);
                        break;
                    }
                    ";" if paren == 0 => break,
                    _ => {}
                }
                k += 1;
            }
            let Some(open) = body_open else { continue };
            let close = self.matching_close(open);
            found.push(FnSpan {
                name,
                line,
                sig_start: ci,
                body_open: open,
                body_close: close,
            });
        }
        self.fns = found;
    }

    fn scan_enums(&mut self) {
        let mut found = Vec::new();
        for ci in 0..self.code.len() {
            if self.ctext(ci) != "enum" || self.ckind(ci + 1) != Some(TokKind::Ident) {
                continue;
            }
            let name = self.ctext(ci + 1).to_string();
            let line = self.cline(ci);
            // Generics between name and `{` are not used in this repo's
            // message enums; scan to the first `{`.
            let mut k = ci + 2;
            while k < self.code.len() && self.ctext(k) != "{" {
                if self.ctext(k) == ";" {
                    break;
                }
                k += 1;
            }
            if self.ctext(k) != "{" {
                continue;
            }
            let close = self.matching_close(k);
            let mut variants = Vec::new();
            let mut j = k + 1;
            while j < close {
                // Skip attributes on variants.
                while self.ctext(j) == "#" && self.ctext(j + 1) == "[" {
                    j = self.matching_close(j + 1) + 1;
                }
                if j >= close || self.ckind(j) != Some(TokKind::Ident) {
                    j += 1;
                    continue;
                }
                let vname = self.ctext(j).to_string();
                let mut fields = Vec::new();
                j += 1;
                match self.ctext(j) {
                    "{" => {
                        let vclose = self.matching_close(j);
                        // Field names: idents directly followed by `:`
                        // at this brace level.
                        let mut d = 0usize;
                        let mut m = j + 1;
                        while m < vclose {
                            match self.ckind(m) {
                                Some(TokKind::Open) => d += 1,
                                Some(TokKind::Close) => d = d.saturating_sub(1),
                                Some(TokKind::Ident)
                                    if d == 0
                                        && self.ctext(m + 1) == ":"
                                        && self.ctext(m + 2) != ":" =>
                                {
                                    fields.push(self.ctext(m).to_string());
                                }
                                _ => {}
                            }
                            m += 1;
                        }
                        j = vclose + 1;
                    }
                    "(" => {
                        let vclose = self.matching_close(j);
                        // Count top-level commas for positional arity.
                        let mut d = 0usize;
                        let mut arity = 1usize;
                        let mut m = j + 1;
                        let mut any = false;
                        while m < vclose {
                            match self.ckind(m) {
                                Some(TokKind::Open) => d += 1,
                                Some(TokKind::Close) => d = d.saturating_sub(1),
                                _ => {
                                    any = true;
                                    if d == 0 && self.ctext(m) == "," {
                                        arity += 1;
                                    }
                                }
                            }
                            m += 1;
                        }
                        if any {
                            for p in 0..arity {
                                fields.push(p.to_string());
                            }
                        }
                        j = vclose + 1;
                    }
                    _ => {}
                }
                // Skip to past the separating comma.
                while j < close && self.ctext(j) != "," {
                    j += 1;
                }
                j += 1;
                variants.push((vname, fields));
            }
            found.push(EnumDecl {
                name,
                line,
                variants,
            });
        }
        self.enums = found;
    }

    fn scan_allows(&mut self) {
        let mut found = Vec::new();
        for (ti, t) in self.toks.iter().enumerate() {
            if t.kind != TokKind::LineComment {
                continue;
            }
            let body = t.text(&self.text);
            let Some(pos) = body.find("lint:allow") else {
                continue;
            };
            let after = &body[pos + "lint:allow".len()..];
            let (rule, has_reason) = match after.strip_prefix('(') {
                Some(rest) => match rest.find(')') {
                    Some(close) => {
                        let rule = rest[..close].trim().to_string();
                        let tail = rest[close + 1..].trim_start();
                        let has_reason = tail
                            .strip_prefix(':')
                            .map(|r| !r.trim().is_empty())
                            .unwrap_or(false);
                        (rule, has_reason)
                    }
                    None => (String::new(), false),
                },
                None => (String::new(), false),
            };
            // Own-line comment (nothing but whitespace before it on the
            // line) covers the next code line; trailing comment covers
            // its own line.
            let line_start = self.text[..t.start].rfind('\n').map(|p| p + 1).unwrap_or(0);
            let own_line = self.text[line_start..t.start].trim().is_empty();
            let next_code_line = if own_line {
                self.toks[ti + 1..]
                    .iter()
                    .find(|n| {
                        !matches!(n.kind, TokKind::LineComment | TokKind::BlockComment)
                    })
                    .map(|n| n.line)
                    .unwrap_or(t.line)
            } else {
                t.line
            };
            found.push(Suppression {
                line: t.line,
                covers: (t.line, next_code_line),
                rule,
                has_reason,
            });
        }
        self.allows = found;
    }

    fn scan_micros_idents(&mut self) {
        let mut set = Vec::new();
        for ci in 0..self.code.len() {
            if self.ckind(ci) != Some(TokKind::Ident) || self.ctext(ci + 1) != ":" {
                continue;
            }
            // `x: Micros` / `x: &Micros` / `x: &mut Micros`.
            let mut k = ci + 2;
            while self.ctext(k) == "&" || self.ctext(k) == "mut" {
                k += 1;
            }
            if self.ctext(k) == "Micros" && self.ctext(k + 1) != ":" {
                let name = self.ctext(ci).to_string();
                if !set.contains(&name) {
                    set.push(name);
                }
            }
        }
        self.micros_idents = set;
    }
}

pub struct SourceTree {
    pub files: Vec<SourceFile>,
}

impl SourceTree {
    /// Load every `.rs` file under `root` (recursively), paths sorted
    /// for deterministic output.
    pub fn load(root: &Path) -> io::Result<SourceTree> {
        let mut paths = Vec::new();
        collect_rs(root, &mut paths)?;
        paths.sort();
        let mut files = Vec::new();
        for p in paths {
            let text = fs::read_to_string(&p)?;
            let display = p
                .strip_prefix(root)
                .map(|r| r.to_string_lossy().into_owned())
                .unwrap_or_else(|_| p.to_string_lossy().into_owned());
            files.push(SourceFile::parse(display, text));
        }
        Ok(SourceTree { files })
    }

    /// Build a tree from in-memory (path, source) pairs — fixture tests.
    pub fn from_memory(sources: &[(&str, &str)]) -> SourceTree {
        SourceTree {
            files: sources
                .iter()
                .map(|(p, s)| SourceFile::parse(p.to_string(), s.to_string()))
                .collect(),
        }
    }

    pub fn file(&self, path: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path == path)
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_cfg_test_mod_span() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { bad(); }\n}\n";
        let f = SourceFile::parse("x.rs".into(), src.into());
        assert_eq!(f.mods.len(), 1);
        assert!(f.mods[0].cfg_test);
        // The `bad` call is inside the test span.
        let bad_ci = (0..f.clen()).find(|&ci| f.ctext(ci) == "bad").unwrap();
        assert!(f.in_test(bad_ci));
        let live_ci = (0..f.clen()).find(|&ci| f.ctext(ci) == "live").unwrap();
        assert!(!f.in_test(live_ci));
    }

    #[test]
    fn extracts_enum_variants_and_fields() {
        let src = "pub enum E { A, B { x: u32, y: Micros }, C(u8, u16), }";
        let f = SourceFile::parse("x.rs".into(), src.into());
        assert_eq!(f.enums.len(), 1);
        let e = &f.enums[0];
        assert_eq!(e.name, "E");
        assert_eq!(e.variants[0], ("A".into(), vec![]));
        assert_eq!(e.variants[1], ("B".into(), vec!["x".into(), "y".into()]));
        assert_eq!(e.variants[2], ("C".into(), vec!["0".into(), "1".into()]));
    }

    #[test]
    fn suppression_parsing() {
        let src = "\
// lint:allow(some-rule): standalone with reason
let a = 1;
let b = 2; // lint:allow(other-rule): trailing
// lint:allow(bare-rule)
let c = 3;
";
        let f = SourceFile::parse("x.rs".into(), src.into());
        assert_eq!(f.allows.len(), 3);
        assert_eq!(f.allows[0].rule, "some-rule");
        assert!(f.allows[0].has_reason);
        assert_eq!(f.allows[0].covers, (1, 2));
        assert_eq!(f.allows[1].rule, "other-rule");
        assert_eq!(f.allows[1].covers, (3, 3));
        assert_eq!(f.allows[2].rule, "bare-rule");
        assert!(!f.allows[2].has_reason);
        assert_eq!(f.allows[2].covers, (4, 5));
    }

    #[test]
    fn micros_ident_ascriptions() {
        let src = "fn f(now: Micros, n: usize) { let slack: Micros = now; let r: &Micros = &slack; }";
        let f = SourceFile::parse("x.rs".into(), src.into());
        assert!(f.micros_idents.contains(&"now".to_string()));
        assert!(f.micros_idents.contains(&"slack".to_string()));
        assert!(f.micros_idents.contains(&"r".to_string()));
        assert!(!f.micros_idents.contains(&"n".to_string()));
    }

    #[test]
    fn fn_spans_and_enclosing() {
        let src = "fn outer() { inner_call(); fn inner() { deep(); } }";
        let f = SourceFile::parse("x.rs".into(), src.into());
        assert_eq!(f.fns.len(), 2);
        let deep_ci = (0..f.clen()).find(|&ci| f.ctext(ci) == "deep").unwrap();
        assert_eq!(f.enclosing_fn(deep_ci).unwrap().name, "inner");
        let call_ci = (0..f.clen())
            .find(|&ci| f.ctext(ci) == "inner_call")
            .unwrap();
        assert_eq!(f.enclosing_fn(call_ci).unwrap().name, "outer");
    }
}
