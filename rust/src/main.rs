//! `symphony` — CLI for the Symphony reproduction.
//!
//! ```text
//! symphony fig <id>              regenerate a paper figure/table
//! symphony simulate [opts]       one simulation run, printed summary
//! symphony serve [opts]          real-time serving (sleep or PJRT backend)
//! symphony rank-server [opts]    host rank shards for a remote serve
//! symphony zoo [1080ti|a100]     print the model zoo
//! symphony analytic <model> <slo_ms> <gpus>
//! symphony partition [models] [parts] [budget_ms]
//! symphony lint [--root rust/src] [--rule NAME]
//! symphony check [--all|--model NAME|--list] [--preempt N]
//! ```
//!
//! (The offline registry has no clap; this is a deliberate, small,
//! hand-rolled parser.)

use std::collections::HashMap;
use std::time::Duration;

use symphony::core::model_zoo::{self, GpuKind};
use symphony::core::time::Micros;
use symphony::harness::{experiments, GoodputExperiment, SystemKind};
use symphony::partition;
use symphony::scheduler::analytical;
use symphony::serve::{serve, BackendKind, ServeConfig};
use symphony::util::rng::Rng;
use symphony::util::table::banner;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            usage();
            return;
        }
    };
    match cmd {
        "fig" => cmd_fig(&rest),
        "simulate" => cmd_simulate(&rest),
        "serve" => cmd_serve(&rest),
        "rank-server" => cmd_rank_server(&rest),
        "zoo" => cmd_zoo(&rest),
        "analytic" => cmd_analytic(&rest),
        "partition" => cmd_partition(&rest),
        "lint" => cmd_lint(&rest),
        "check" => cmd_check(&rest),
        "-h" | "--help" | "help" => usage(),
        other => {
            eprintln!("unknown command {other:?}\n");
            usage();
            std::process::exit(2);
        }
    }
}

fn usage() {
    println!(
        "symphony — deferred batch scheduling (paper reproduction)\n\n\
         USAGE:\n  symphony fig <1|2|4|6a|6b|7|9|10|11|12|13|14|15|16|17|table2|all>\n  \
         symphony simulate [--system S] [--gpus N] [--models N] [--rate R] [--slo MS] [--secs S]\n  \
         symphony serve [--pjrt DIR] [--gpus N] [--rank-shards R] [--ingest-shards F]\n  \
                 [--model-workers W] [--rate R] [--secs S]\n  \
                 [--remote-ranks host:port,..] [--assert-grants]\n  \
                 [--busy-poll] [--pin-cores]\n  \
                 [--fault-plan SPEC] [--expect-disconnects N]\n  \
                 [--trace-out FILE] [--trace-sample N] [--metrics-listen ADDR]\n  \
         symphony serve --autoscale [--initial-gpus N] [--min-gpus N] [--max-gpus N]\n  \
                 [--epoch-ms E] [--backlog-per-gpu B] [--rates R1,R2,..] [--assert-scale]\n  \
         symphony rank-server [--listen ADDR] [--shards R] [--gpu-range LO..HI]\n  \
                 [--max-sessions N] [--busy-poll] [--pin-cores] [--fault-plan SPEC]\n  \
                 [--metrics-listen ADDR]\n  \
         symphony zoo [1080ti|a100]\n  symphony analytic <model> <slo_ms> <gpus>\n  \
         symphony partition [n_models] [parts] [budget_ms]\n  \
         symphony lint [--root rust/src] [--rule NAME]\n  \
         symphony check [--all|--model NAME|--list] [--preempt N]\n  \
                 [--schedules N --seed S] [--max-schedules M]\n\n\
         systems: symphony clockwork nexus shepherd eager"
    );
}

/// Parse `--key value` flags. A `--key` directly followed by another
/// `--flag` (or by nothing) is boolean `true` — so `--autoscale --gpus 8`
/// parses as `autoscale=true, gpus=8` instead of swallowing `--gpus`.
fn flags(rest: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < rest.len() {
        if let Some(k) = rest[i].strip_prefix("--") {
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                out.insert(k.to_string(), rest[i + 1].clone());
                i += 2;
                continue;
            }
            out.insert(k.to_string(), "true".to_string());
        }
        i += 1;
    }
    out
}

fn getf(f: &HashMap<String, String>, k: &str, d: f64) -> f64 {
    f.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn getu(f: &HashMap<String, String>, k: &str, d: usize) -> usize {
    f.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
}

/// Parse `--fault-plan SPEC` (see `net::faults` for the grammar); an
/// absent flag is the inert plan.
fn parse_fault_plan(
    f: &HashMap<String, String>,
) -> std::sync::Arc<symphony::net::faults::FaultPlan> {
    match f.get("fault-plan") {
        Some(spec) => match symphony::net::faults::FaultPlan::parse(spec) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("bad --fault-plan: {e:#}");
                std::process::exit(2);
            }
        },
        None => symphony::net::faults::FaultPlan::none(),
    }
}

fn parse_system(name: &str) -> SystemKind {
    match name {
        "symphony" => SystemKind::Symphony,
        "clockwork" => SystemKind::Clockwork,
        "nexus" => SystemKind::Nexus { frontends: 1 },
        "shepherd" => SystemKind::Shepherd,
        "eager" => SystemKind::Eager,
        other => {
            eprintln!("unknown system {other:?}");
            std::process::exit(2);
        }
    }
}

fn cmd_fig(rest: &[String]) {
    let Some(id) = rest.first() else {
        eprintln!("fig: which one? (1 2 4 6a 6b 7 9 10 11 12 13 14 15 16 17 table2 all)");
        std::process::exit(2);
    };
    run_fig(id);
}

pub fn run_fig(id: &str) {
    match id {
        "1" => {
            banner("Figure 1: batch size distribution");
            experiments::fig01_batch_sizes().emit("fig01_batch_sizes");
            experiments::fig01_cdfs().emit("fig01_cdfs");
        }
        "2" => {
            banner("Figure 2: goodput + GPU utilization vs offered load");
            experiments::fig02_flattop().emit("fig02_flattop");
        }
        "4" | "5" => {
            banner("Figures 4/5: worked-example traces");
            experiments::fig04_05_traces().emit("fig04_05_traces");
        }
        "6a" => {
            banner("Figure 6a: batching-effect strength");
            experiments::fig06a_betaalpha().emit("fig06a_betaalpha");
        }
        "6b" => {
            banner("Figure 6b: timeout-based scheduling");
            experiments::fig06b_timeout().emit("fig06b_timeout");
        }
        "7" => {
            banner("Figure 7: synthetic workload sweep");
            experiments::fig07_sweep().emit("fig07_sweep");
        }
        "9" => {
            banner("Figure 9: end-to-end goodput (model zoo)");
            experiments::fig09_e2e_goodput().emit("fig09_e2e_goodput");
        }
        "10" => {
            banner("Figure 10: minimum GPUs for 15k RPS");
            experiments::fig10_min_gpus().emit("fig10_min_gpus");
        }
        "11" => {
            banner("Figure 11: workload characteristics");
            experiments::fig11_workload_chars().emit("fig11_workload_chars");
        }
        "12" => {
            banner("Figure 12: queueing delay");
            experiments::fig12_queueing().emit("fig12_queueing");
        }
        "13" => {
            banner("Figure 13 (right): goodput vs #GPUs");
            experiments::fig13_goodput_vs_gpus().emit("fig13_gpus");
            println!(
                "(Figure 13 left is the multithreaded-coordinator bench: \
                 cargo bench --bench fig13_scalability)"
            );
        }
        "14" => {
            banner("Figure 14: network latency sensitivity");
            experiments::fig14_network().emit("fig14_network");
        }
        "15" => {
            banner("Figure 15: changing workload + autoscaling (512 GPUs)");
            experiments::fig15_autoscale(180.0, 512).emit("fig15_autoscale");
        }
        "16" => {
            banner("Figure 16: partitioning quality");
            experiments::fig16_partition(20, 300).emit("fig16_partition");
        }
        "17" => {
            banner("Figure 17: RDMA vs TCP incast latency");
            experiments::fig17_incast(200_000).emit("fig17_incast");
        }
        "table2" => {
            banner("Table 2: analytical vs measured");
            experiments::table2_analytical().emit("table2_analytical");
        }
        "all" => {
            for id in [
                "1", "2", "4", "6a", "6b", "7", "9", "10", "11", "12", "13", "14",
                "15", "16", "17", "table2",
            ] {
                run_fig(id);
            }
        }
        other => {
            eprintln!("unknown figure {other:?}");
            std::process::exit(2);
        }
    }
}

fn cmd_simulate(rest: &[String]) {
    let f = flags(rest);
    let sys = parse_system(f.get("system").map(String::as_str).unwrap_or("symphony"));
    let gpus = getu(&f, "gpus", 8);
    let n_models = getu(&f, "models", 1);
    let slo = getf(&f, "slo", 25.0);
    let rate = getf(&f, "rate", 0.0);
    let secs = getf(&f, "secs", 8.0);
    let models = model_zoo::resnet_like_variants(n_models, slo, GpuKind::Gtx1080Ti);
    let exp = GoodputExperiment::new(models, gpus).sim_secs(secs);
    if rate > 0.0 {
        let m = exp.run_at(rate, &|e: &GoodputExperiment| {
            sys.build(&e.models, e.num_gpus, Micros::ZERO)
        });
        println!(
            "{} @ {rate} rps on {gpus} GPUs: goodput={:.0} bad={:.3} util={:.2} median_batch={}",
            sys.label(),
            m.goodput(),
            m.bad_fraction(),
            m.utilization(gpus),
            m.batch_hist_all().median()
        );
    } else {
        let res = exp.goodput(|e| sys.build(&e.models, e.num_gpus, Micros::ZERO));
        println!(
            "{} on {gpus} GPUs x {n_models} models (SLO {slo}ms): goodput={:.0} (offered {:.0})",
            sys.label(),
            res.goodput,
            res.offered
        );
    }
}

fn cmd_serve(rest: &[String]) {
    let f = flags(rest);
    let gpus = getu(&f, "gpus", 2);
    let rank_shards = getu(&f, "rank-shards", 1);
    let ingest_shards = getu(&f, "ingest-shards", 1);
    // `None` = min(models, cores) — the ModelWorkerPool default.
    let model_workers: Option<usize> = f.get("model-workers").and_then(|v| v.parse().ok());
    let rate = getf(&f, "rate", 300.0);
    let secs = getf(&f, "secs", 3.0);
    let backend = match f.get("pjrt") {
        Some(dir) => BackendKind::Pjrt {
            artifacts_dir: dir.into(),
        },
        None => BackendKind::Sleep,
    };
    let autoscale_on = f.contains_key("autoscale");
    let initial_gpus = match f.get("initial-gpus").and_then(|v| v.parse().ok()) {
        Some(n) => Some(n),
        // Autoscale runs default to a quarter of capacity attached so
        // both the allocate and the drain path get exercised.
        None if autoscale_on => Some((gpus / 4).max(1)),
        None => None,
    };
    let autoscale = autoscale_on.then(|| symphony::autoscale::AutoscaleConfig {
        bad_rate_threshold: getf(&f, "bad-threshold", 0.05),
        idle_threshold: getf(&f, "idle-threshold", 0.30),
        min_gpus: getu(&f, "min-gpus", 1),
        max_gpus: getu(&f, "max-gpus", gpus),
        epoch: Micros::from_millis_f64(getf(&f, "epoch-ms", 500.0)),
        backlog_per_gpu: getf(&f, "backlog-per-gpu", 4.0),
    });
    // `--remote-ranks host:port,..`: replace the in-process rank tier
    // with running `symphony rank-server` processes (their GPU ranges
    // must tile 0..gpus in list order).
    let remote_ranks: Vec<String> = f
        .get("remote-ranks")
        .map(|spec| {
            spec.split(',')
                .map(|a| a.trim().to_string())
                .filter(|a| !a.is_empty())
                .collect()
        })
        .unwrap_or_default();
    // `--rates r1,r2,...` splits the duration into equal phases — the
    // Fig 15-style changing workload (low→high→low exercises both the
    // allocate and the drain path).
    let rate_phases: Vec<(f64, f64)> = f
        .get("rates")
        .map(|spec| {
            let rs: Vec<f64> = spec
                .split(',')
                .filter_map(|v| v.trim().parse().ok())
                .collect();
            let phase_secs = secs / rs.len().max(1) as f64;
            rs.into_iter().map(|r| (phase_secs, r)).collect()
        })
        .unwrap_or_default();
    // Model shape: ℓ(b) = alpha·b + beta (ms). The defaults are light;
    // autoscale smokes pass heavier models so a small GPU count
    // saturates at driveable rates.
    let alpha = getf(&f, "alpha-ms", 0.2);
    let beta = getf(&f, "beta-ms", 2.0);
    let slo = getf(&f, "slo-ms", 50.0);
    let models = vec![
        symphony::core::profile::ModelSpec::new("svc-a", alpha, beta, slo),
        symphony::core::profile::ModelSpec::new("svc-b", alpha, beta, slo),
    ];
    let report = match serve(ServeConfig {
        models,
        num_gpus: gpus,
        initial_gpus,
        rank_shards,
        ingest_shards,
        model_workers,
        remote_ranks,
        total_rate: rate,
        rate_phases,
        duration: Duration::from_secs_f64(secs),
        backend,
        autoscale,
        busy_poll: f.contains_key("busy-poll"),
        pin_cores: f.contains_key("pin-cores"),
        seed: 7,
        fault_plan: parse_fault_plan(&f),
        trace_sample: getu(&f, "trace-sample", 0) as u64,
        trace_out: f.get("trace-out").map(std::path::PathBuf::from),
        metrics_listen: f.get("metrics-listen").cloned(),
    }) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serve failed: {e:#}");
            std::process::exit(1);
        }
    };
    println!("{report:#?}");
    if !report.hop_breakdown.is_empty() {
        let mut t = symphony::util::table::Table::new(vec!["hop", "count", "p50_us", "p99_us"]);
        for h in &report.hop_breakdown {
            t.row(vec![
                h.hop.clone(),
                h.count.to_string(),
                h.p50_us.to_string(),
                h.p99_us.to_string(),
            ]);
        }
        print!("{}", t.render());
    }
    if !report.timeline.is_empty() {
        let mut t = symphony::util::table::Table::new(vec![
            "t_s", "offered_rps", "active_gpus", "bad_rate", "busy", "delta",
        ]);
        for p in &report.timeline {
            t.row(vec![
                format!("{:.1}", p.t_s),
                format!("{:.0}", p.offered_rps),
                p.active_gpus.to_string(),
                format!("{:.3}", p.bad_rate),
                format!("{:.2}", p.busy_fraction),
                p.delta.to_string(),
            ]);
        }
        print!("{}", t.render());
    }
    // CI smoke assertion: the active-GPU count must rise under the high
    // phase and fall back in the final trough (load-proportionality).
    if f.contains_key("assert-scale") {
        let Some((first, peak, last)) = symphony::metrics::timeline_extent(&report.timeline)
        else {
            eprintln!("assert-scale: no autoscale timeline (pass --autoscale)");
            std::process::exit(1);
        };
        let initial = initial_gpus.unwrap_or(gpus);
        if peak <= initial || last >= peak {
            eprintln!(
                "assert-scale FAILED: initial={initial} first={first} peak={peak} last={last} \
                 — GPU count must go up then back down"
            );
            std::process::exit(1);
        }
        println!(
            "assert-scale OK: initial={initial} peak={peak} last={last} \
             (mis_steers={})",
            report.mis_steers
        );
    }
    // CI smoke assertion for the wire path: the run must have been
    // scheduled (grants flowed back over the rank tier) and the
    // session count must match expectations. Without
    // `--expect-disconnects` no rank server may have dropped the
    // session; with it (the fault-recovery smoke) at least N sessions
    // must have died AND each death must have healed into a reconnect.
    if f.contains_key("assert-grants") {
        let expect = getu(&f, "expect-disconnects", 0) as u64;
        let ok = report.grants > 0
            && if expect == 0 {
                report.rank_disconnects == 0
            } else {
                report.rank_disconnects >= expect && report.rank_reconnects >= expect
            };
        if !ok {
            eprintln!(
                "assert-grants FAILED: grants={} rank_disconnects={} rank_reconnects={} \
                 (expected {} disconnect(s), causes {:?})",
                report.grants,
                report.rank_disconnects,
                report.rank_reconnects,
                expect,
                report.rank_disconnect_causes
            );
            std::process::exit(1);
        }
        println!(
            "assert-grants OK: grants={} completed={} rank_disconnects={} rank_reconnects={}",
            report.grants, report.completed, report.rank_disconnects, report.rank_reconnects
        );
    }
}

/// `symphony rank-server --listen ADDR --shards R --gpu-range LO..HI`:
/// host real rank shards for a `serve --remote-ranks` coordinator in
/// another process (see `net/server.rs`).
fn cmd_rank_server(rest: &[String]) {
    let f = flags(rest);
    let listen = f
        .get("listen")
        .cloned()
        .unwrap_or_else(|| "127.0.0.1:7811".to_string());
    let shards = getu(&f, "shards", 1);
    let gpus = match f.get("gpu-range") {
        Some(spec) => {
            let parts: Vec<&str> = spec.split("..").collect();
            let parsed = match parts[..] {
                [lo, hi] => lo.trim().parse::<u32>().ok().zip(hi.trim().parse::<u32>().ok()),
                _ => None,
            };
            match parsed {
                Some((lo, hi)) if lo < hi => lo..hi,
                _ => {
                    eprintln!("--gpu-range wants LO..HI with LO < HI, got {spec:?}");
                    std::process::exit(2);
                }
            }
        }
        None => 0..2,
    };
    let max_sessions = f.get("max-sessions").and_then(|v| v.parse().ok());
    let server = match symphony::net::server::RankServer::bind(
        symphony::net::server::RankServerConfig {
            listen,
            shards,
            gpus,
            max_sessions,
            busy_poll: f.contains_key("busy-poll"),
            pin_cores: f.contains_key("pin-cores"),
            fault_plan: parse_fault_plan(&f),
            metrics_listen: f.get("metrics-listen").cloned(),
        },
    ) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("rank-server failed to bind: {e:#}");
            std::process::exit(1);
        }
    };
    if let Err(e) = server.run() {
        eprintln!("rank-server failed: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_zoo(rest: &[String]) {
    let kind = match rest.first().map(String::as_str) {
        Some("a100") => GpuKind::A100,
        _ => GpuKind::Gtx1080Ti,
    };
    let mut t = symphony::util::table::Table::new(vec![
        "model", "alpha_ms", "beta_ms", "beta/alpha", "slo_ms", "maxbatch@slo",
    ]);
    for m in model_zoo::zoo(kind) {
        t.row(vec![
            m.name.clone(),
            format!("{:.3}", m.profile.alpha_ms),
            format!("{:.3}", m.profile.beta_ms),
            format!("{:.2}", m.profile.batch_effect()),
            format!("{:.0}", m.slo.as_millis_f64()),
            m.profile.max_batch_within(m.slo).to_string(),
        ]);
    }
    print!("{}", t.render());
}

fn cmd_analytic(rest: &[String]) {
    if rest.len() < 3 {
        eprintln!("usage: symphony analytic <model> <slo_ms> <gpus>");
        std::process::exit(2);
    }
    let Some(m) = model_zoo::by_name(GpuKind::Gtx1080Ti, &rest[0]) else {
        eprintln!("model {} not in zoo (try `symphony zoo`)", rest[0]);
        std::process::exit(2);
    };
    let slo = Micros::from_millis_f64(rest[1].parse().expect("slo_ms"));
    let gpus: u32 = rest[2].parse().expect("gpus");
    let st = analytical::staggered(&m.profile, slo, gpus);
    let nc = analytical::no_coordination(&m.profile, slo, gpus);
    println!(
        "{}: staggered b={} tput={:.0} r/s | no-coordination b={} tput={:.0} r/s",
        m.name, st.batch_size, st.throughput, nc.batch_size, nc.throughput
    );
}

fn cmd_partition(rest: &[String]) {
    let n: usize = rest.first().and_then(|v| v.parse().ok()).unwrap_or(800);
    let parts: usize = rest.get(1).and_then(|v| v.parse().ok()).unwrap_or(20);
    let budget: u64 = rest.get(2).and_then(|v| v.parse().ok()).unwrap_or(1_000);
    let mut rng = Rng::new(1);
    let p = partition::random_instance(n, parts, &mut rng);
    let ours = partition::solve(&p, Duration::from_millis(budget), &mut rng);
    let rand = partition::random_search(&p, Duration::from_millis(budget), &mut rng);
    match (ours, rand) {
        (Some(a), Some(b)) => {
            let (ra, sa) = p.imbalance(&a);
            let (rb, sb) = p.imbalance(&b);
            println!(
                "solver: obj={:.2} imbalance rate={ra:.3} mem={sa:.3}\n\
                 random: obj={:.2} imbalance rate={rb:.3} mem={sb:.3}",
                p.objective(&a),
                p.objective(&b)
            );
        }
        _ => println!("no feasible assignment found within budget"),
    }
}

/// `symphony check [--all|--model NAME|--list]` — run the deterministic
/// concurrency model checker over the lock-free fabric (see
/// `check::models` for the model set). Exit 1 when any model misses
/// its contract: real models must be failure-free, seeded-bug models
/// must produce at least one failing schedule.
fn cmd_check(rest: &[String]) {
    use symphony::check::{all_models, check_model, find_model, ExploreConfig};
    let f = flags(rest);
    if f.contains_key("list") {
        for m in all_models() {
            println!(
                "{:28} {}{}",
                m.name,
                if m.expect_fail { "[seeded bug] " } else { "" },
                m.about
            );
        }
        return;
    }
    let defaults = ExploreConfig::default();
    let cfg = ExploreConfig {
        preempt: getu(&f, "preempt", defaults.preempt as usize) as u32,
        max_schedules: getu(&f, "max-schedules", defaults.max_schedules),
        // `--schedules N [--seed S]`: N random walks instead of DFS.
        random: f
            .get("schedules")
            .and_then(|v| v.parse().ok())
            .map(|n| (n, getu(&f, "seed", 1) as u64)),
    };
    let selected: Vec<&symphony::check::Model> = match f.get("model") {
        Some(name) => match find_model(name) {
            Some(m) => vec![m],
            None => {
                eprintln!(
                    "unknown model {name:?} (known: {})",
                    all_models()
                        .iter()
                        .map(|m| m.name)
                        .collect::<Vec<_>>()
                        .join(", ")
                );
                std::process::exit(2);
            }
        },
        // `--all` (and no selector at all) means every model — except
        // that a random sweep skips the seeded-bug models: a sample may
        // legitimately miss a planted bug, and only the exhaustive DFS
        // (and the tier-1 tests) hold the must-fail contract.
        None => all_models()
            .iter()
            .filter(|m| !(cfg.random.is_some() && m.expect_fail))
            .collect(),
    };
    let mut all_ok = true;
    for m in selected {
        let r = check_model(m, cfg);
        all_ok &= r.ok;
        let verdict = match (r.ok, r.expect_fail) {
            (true, false) => "ok".to_string(),
            (true, true) => "ok (seeded bug caught)".to_string(),
            (false, false) => format!(
                "FAIL: {}",
                r.report.failure.as_deref().unwrap_or("(no failure message)")
            ),
            (false, true) => "FAIL: seeded bug NOT caught".to_string(),
        };
        // Random walks are a sample by construction; only the DFS mode
        // distinguishes "finished the tree" from "hit the cap".
        let capped = cfg.random.is_none() && !r.report.exhausted;
        println!(
            "{:28} schedules={:<6} pruned={:<6} {}ms{}  {}",
            r.name,
            r.report.schedules,
            r.report.pruned,
            r.report.millis,
            if capped { " (capped)" } else { "" },
            verdict
        );
    }
    if !all_ok {
        eprintln!("check: FAILED");
        std::process::exit(1);
    }
    println!("check: all models met their contracts");
}

/// `symphony lint [--root rust/src] [--rule NAME]` — run the std-only
/// invariant checker (see LINTS.md) and exit nonzero on findings.
fn cmd_lint(rest: &[String]) {
    let f = flags(rest);
    let root = f
        .get("root")
        .cloned()
        .unwrap_or_else(|| "rust/src".to_string());
    let only = f.get("rule").map(|s| s.as_str());
    if let Some(o) = only {
        if !symphony::lint::rule_names().contains(&o) {
            eprintln!(
                "unknown rule {o:?} (known: {})",
                symphony::lint::rule_names().join(", ")
            );
            std::process::exit(2);
        }
    }
    let findings = match symphony::lint::run(std::path::Path::new(&root), only) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("lint: cannot read {root}: {e}");
            std::process::exit(2);
        }
    };
    for fd in &findings {
        println!("{fd}");
    }
    if findings.is_empty() {
        println!("lint: clean ({root})");
    } else {
        eprintln!("lint: {} finding(s)", findings.len());
        std::process::exit(1);
    }
}
