//! Experiment metrics: goodput / bad rate, queueing delay, batch-size
//! distributions (Fig 1), GPU utilization (Fig 2) — collected by the
//! engine, summarized per model and per cluster.

use std::collections::HashMap;

use crate::core::time::Micros;
use crate::core::types::{ModelId, OutcomeKind};
use crate::util::stats::{percentile, Histogram};

/// What to record. Latency samples cost memory; the big sweeps turn the
/// sample vectors off and rely on counters.
#[derive(Clone, Copy, Debug)]
pub struct MetricsConfig {
    /// Ignore requests arriving before this time (warm-up).
    pub warmup: Micros,
    /// Keep per-request latency / queueing-delay samples.
    pub record_samples: bool,
}

impl Default for MetricsConfig {
    fn default() -> Self {
        MetricsConfig {
            warmup: Micros::ZERO,
            record_samples: true,
        }
    }
}

/// One epoch of the live-autoscale timeline (§3.5 / Fig 15): what the
/// windowed stats pipeline observed and what the controller did about
/// it. Produced by the serve-side autoscale loop; rendered by the
/// `serve --autoscale` report and the Fig 15-style drivers.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochPoint {
    /// Epoch end, seconds since the run started.
    pub t_s: f64,
    /// Completions (good + bad) per second over the epoch — the
    /// measured, not configured, offered load.
    pub offered_rps: f64,
    /// Attached GPUs after this epoch's scaling action.
    pub active_gpus: usize,
    /// Bad-rate `r` of the epoch window.
    pub bad_rate: f64,
    /// Mean busy fraction across active GPUs in the window.
    pub busy_fraction: f64,
    /// Net GPUs added (positive) or put into drain (negative).
    pub delta: i64,
}

/// Summary of an autoscale timeline: the Fig 15 "load-proportional"
/// shape in three numbers.
pub fn timeline_extent(points: &[EpochPoint]) -> Option<(usize, usize, usize)> {
    let first = points.first()?.active_gpus;
    let peak = points.iter().map(|p| p.active_gpus).max()?;
    let last = points.last()?.active_gpus;
    Some((first, peak, last))
}

/// Counters + samples for one model.
#[derive(Clone, Debug, Default)]
pub struct ModelMetrics {
    pub good: u64,
    pub late: u64,
    pub dropped: u64,
    pub unfinished: u64,
    /// End-to-end latency (arrival → completion) of completed requests, ms.
    pub latency_ms: Vec<f64>,
    /// Queueing delay (arrival → batch start) of executed requests, ms.
    pub queueing_ms: Vec<f64>,
    /// Batch sizes weighted by request (a request in a batch of 8 adds one
    /// count to bucket 8) — Fig 1's distribution.
    pub batch_hist: Histogram,
}

impl ModelMetrics {
    pub fn total(&self) -> u64 {
        self.good + self.late + self.dropped
    }

    /// Fraction of finished requests that violated their SLO.
    pub fn bad_fraction(&self) -> f64 {
        let t = self.total();
        if t == 0 {
            0.0
        } else {
            (self.late + self.dropped) as f64 / t as f64
        }
    }

    pub fn p99_latency_ms(&self) -> f64 {
        percentile(&self.latency_ms, 99.0)
    }

    pub fn median_batch(&self) -> usize {
        self.batch_hist.median()
    }
}

/// Whole-run metrics.
#[derive(Clone, Debug)]
pub struct Metrics {
    pub cfg: MetricsConfig,
    pub per_model: Vec<ModelMetrics>,
    /// Batches executed (count, size histogram — per batch, not weighted).
    pub batches: Histogram,
    /// Batches canceled by preemption.
    pub preempted_batches: u64,
    /// Requests' worth of GPU work thrown away by preemption.
    pub wasted_work: u64,
    /// Per-GPU busy time within the measurement window.
    pub gpu_busy: HashMap<u32, Micros>,
    /// Measurement window (set by the engine when the run ends).
    pub window: (Micros, Micros),
}

impl Metrics {
    pub fn new(models: usize, cfg: MetricsConfig) -> Self {
        Metrics {
            cfg,
            per_model: vec![ModelMetrics::default(); models],
            batches: Histogram::new(),
            preempted_batches: 0,
            wasted_work: 0,
            gpu_busy: HashMap::new(),
            window: (cfg.warmup, cfg.warmup),
        }
    }

    #[inline]
    pub fn in_window(&self, arrival: Micros) -> bool {
        arrival >= self.cfg.warmup
    }

    pub fn record_outcome(
        &mut self,
        model: ModelId,
        arrival: Micros,
        kind: OutcomeKind,
        start: Option<Micros>,
        end: Option<Micros>,
        batch_size: u32,
    ) {
        if !self.in_window(arrival) {
            return;
        }
        let m = &mut self.per_model[model.0 as usize];
        match kind {
            OutcomeKind::Good => m.good += 1,
            OutcomeKind::Late => m.late += 1,
            OutcomeKind::Dropped => m.dropped += 1,
            OutcomeKind::Unfinished => m.unfinished += 1,
        }
        if matches!(kind, OutcomeKind::Good | OutcomeKind::Late) {
            m.batch_hist.add(batch_size as usize);
            if self.cfg.record_samples {
                if let (Some(s), Some(e)) = (start, end) {
                    m.latency_ms.push((e - arrival).as_millis_f64());
                    m.queueing_ms.push((s - arrival).as_millis_f64());
                }
            }
        }
    }

    pub fn record_batch(&mut self, size: u32, start: Micros) {
        if self.in_window(start) {
            self.batches.add(size as usize);
        }
    }

    /// Duration of the measurement window in seconds.
    pub fn window_secs(&self) -> f64 {
        (self.window.1.saturating_sub(self.window.0)).as_secs_f64()
    }

    /// Good requests per second over the measurement window (the paper's
    /// goodput once the offered rate is at the feasibility frontier).
    pub fn goodput(&self) -> f64 {
        let good: u64 = self.per_model.iter().map(|m| m.good).sum();
        let secs = self.window_secs();
        if secs == 0.0 {
            0.0
        } else {
            good as f64 / secs
        }
    }

    pub fn total_finished(&self) -> u64 {
        self.per_model.iter().map(|m| m.total()).sum()
    }

    /// Aggregate SLO-violation fraction.
    pub fn bad_fraction(&self) -> f64 {
        let total: u64 = self.total_finished();
        if total == 0 {
            return 0.0;
        }
        let bad: u64 = self.per_model.iter().map(|m| m.late + m.dropped).sum();
        bad as f64 / total as f64
    }

    /// Does every model meet the goodput criterion (§2.1: p99 < SLO; with
    /// drop-based schedulers this is a ≤1% bad-fraction test)?
    /// Models with very few samples are judged on the aggregate instead.
    pub fn slo_satisfied(&self, bad_threshold: f64) -> bool {
        if self.bad_fraction() > bad_threshold {
            return false;
        }
        self.per_model
            .iter()
            .filter(|m| m.total() >= 100)
            .all(|m| m.bad_fraction() <= bad_threshold)
    }

    /// Mean GPU busy fraction over the window (Fig 2 right).
    pub fn utilization(&self, num_gpus: usize) -> f64 {
        let secs = self.window_secs();
        if secs == 0.0 || num_gpus == 0 {
            return 0.0;
        }
        let busy: f64 = self.gpu_busy.values().map(|b| b.as_secs_f64()).sum();
        busy / (secs * num_gpus as f64)
    }

    /// Number of GPUs that did any work in the window ("GPUs used").
    pub fn gpus_used(&self) -> usize {
        self.gpu_busy.values().filter(|b| b.0 > 0).count()
    }

    /// Request-weighted batch-size histogram across all models (Fig 1).
    pub fn batch_hist_all(&self) -> Histogram {
        let mut h = Histogram::new();
        for m in &self.per_model {
            h.merge(&m.batch_hist);
        }
        h
    }

    /// All queueing-delay samples pooled (Fig 12).
    pub fn queueing_all(&self) -> Vec<f64> {
        let mut v = Vec::new();
        for m in &self.per_model {
            v.extend_from_slice(&m.queueing_ms);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_excludes_early_requests() {
        let mut m = Metrics::new(
            1,
            MetricsConfig {
                warmup: Micros(1_000),
                record_samples: true,
            },
        );
        m.record_outcome(
            ModelId(0),
            Micros(500),
            OutcomeKind::Good,
            Some(Micros(600)),
            Some(Micros(700)),
            4,
        );
        assert_eq!(m.per_model[0].good, 0);
        m.record_outcome(
            ModelId(0),
            Micros(1_500),
            OutcomeKind::Good,
            Some(Micros(1_600)),
            Some(Micros(1_700)),
            4,
        );
        assert_eq!(m.per_model[0].good, 1);
        assert_eq!(m.per_model[0].latency_ms.len(), 1);
        assert!((m.per_model[0].latency_ms[0] - 0.2).abs() < 1e-9);
    }

    #[test]
    fn goodput_and_bad_fraction() {
        let mut m = Metrics::new(2, MetricsConfig::default());
        for i in 0..98u64 {
            m.record_outcome(
                ModelId((i % 2) as u32),
                Micros(i),
                OutcomeKind::Good,
                Some(Micros(100)),
                Some(Micros(200)),
                8,
            );
        }
        m.record_outcome(ModelId(0), Micros(1), OutcomeKind::Dropped, None, None, 0);
        m.record_outcome(
            ModelId(1),
            Micros(2),
            OutcomeKind::Late,
            Some(Micros(10)),
            Some(Micros(99)),
            2,
        );
        m.window = (Micros::ZERO, Micros::from_secs_f64(2.0));
        assert_eq!(m.total_finished(), 100);
        assert!((m.bad_fraction() - 0.02).abs() < 1e-12);
        assert!((m.goodput() - 49.0).abs() < 1e-9);
        assert!(!m.slo_satisfied(0.01));
        assert!(m.slo_satisfied(0.05));
    }

    #[test]
    fn timeline_extent_reports_fig15_shape() {
        assert_eq!(timeline_extent(&[]), None);
        let mk = |g: usize| EpochPoint {
            active_gpus: g,
            ..Default::default()
        };
        let pts: Vec<EpochPoint> = [2, 3, 5, 6, 4, 2, 1].iter().map(|&g| mk(g)).collect();
        assert_eq!(timeline_extent(&pts), Some((2, 6, 1)));
    }

    #[test]
    fn utilization_accounting() {
        let mut m = Metrics::new(1, MetricsConfig::default());
        m.window = (Micros::ZERO, Micros::from_secs_f64(10.0));
        m.gpu_busy.insert(0, Micros::from_secs_f64(5.0));
        m.gpu_busy.insert(1, Micros::from_secs_f64(0.0));
        assert!((m.utilization(2) - 0.25).abs() < 1e-12);
        assert_eq!(m.gpus_used(), 1);
    }
}
