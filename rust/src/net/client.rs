//! Client side of the rank-coordination wire: one [`RemoteRank`] per
//! `symphony rank-server` connection.
//!
//! A connection multiplexes every shard the server hosts. The write
//! side goes through the coalescing [`crate::net::transport`] writer
//! (one syscall per queued burst); a single reader thread decodes the
//! down-traffic and fans it out exactly like an in-process rank shard
//! would:
//!
//! * `Granted` / `Revalidate` / `Overflow` → the owning model worker's
//!   inbox (`Overflow::to_shard` is re-based from the server-local
//!   shard index into the client's global topology);
//! * `DrainAck` → the `Sender<GpuId>` parked in the ack table when the
//!   matching `Drain` was issued — the wire form of the in-process
//!   `ToRank::Drain { ack }` contract, so `ClusterCtl` and the live
//!   autoscaler work unchanged over the wire.
//!
//! ## The reconnect state machine
//!
//! A connection is `Live → (Reconnecting ⇄ Live)* → Closed`. An
//! unexpected disconnect is **surfaced, never swallowed** — counted by
//! cause in the shared [`DisconnectCounts`] — but with the
//! [`ReconnectPolicy`] enabled it no longer kills the rank tier:
//!
//! * the failing session's **epoch** is bumped (first detector wins a
//!   CAS, so a read error, a send error, and a backlog overflow racing
//!   on the same corpse count one disconnect, not three);
//! * the old socket is shut down and parked drain acks drop (waiters
//!   see `Disconnected`, like a dead in-process shard);
//! * a background dialer re-handshakes with capped exponential
//!   backoff, sending the bumped epoch in its [`ClientHello`];
//! * frames still in flight from the dead session are **fenced**: the
//!   reader thread captured its session epoch at spawn and drops (and
//!   counts) anything it reads once the epoch has moved on — a stale
//!   `Granted` can never lease a GPU in the new session;
//! * on re-handshake the client replays its *desired-detached* GPU set
//!   (fresh server sessions spawn fully attached) and nudges every
//!   model worker with `ToModel::Reregister` — the ModelThread is the
//!   single authority for its candidate, so recovery is a re-register,
//!   not a distributed transaction.
//!
//! While `Reconnecting`, candidate registrations and busy-until hints
//! are silently dropped (`Ok`): the post-reconnect replay re-derives
//! them all, and failing them would kill model workers over a blip.
//! Drain/attach return [`PortClosed`] instead — the autoscaler's
//! GPU-state machine must know its command did not happen. Past the
//! policy's `dead_after` deadline the dialer declares the server's
//! shard range dead in the shared [`ShardLiveness`], which makes the
//! routers migrate candidates to survivors and lets the autoscaler
//! re-tile the lost capacity; an eventual reconnect marks the range
//! live again and the `Reregister` nudge re-homes the models.

use std::collections::{BTreeSet, HashMap};
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::messages::ToModel;
use crate::coordinator::router::{PortClosed, ShardLiveness};
use crate::coordinator::Clock;
use crate::core::types::{GpuId, ModelId};
use crate::net::codec::{self, ClientHello, ServerPreamble, WireFromRank, WireToRank, PREAMBLE_LEN};
use crate::net::faults::FaultPlan;
use crate::net::transport::{
    connect_retry, spawn_writer_with, FrameReader, FrameSender, SendFail, WriterStats,
};
use crate::obs::trace::{self, Stage};
use crate::util::error::{Context, Result};
use crate::{log_error, log_info, log_warn};
use crate::util::ring::RingSender;
use crate::util::sync::relock;

/// How long the handshake may block before the peer is declared broken.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Per-attempt connect budget inside the reconnect dialer (kept short
/// so the dialer notices `close()` promptly between attempts).
const DIAL_ATTEMPT_TIMEOUT: Duration = Duration::from_millis(250);

/// Why a rank-server session ended without this process asking.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DisconnectCause {
    /// Torn read, reset, unexpected EOF — the transport died.
    Io,
    /// The peer spoke, but wrongly: bad frame, foreign GPU, unknown
    /// model.
    Protocol,
    /// A session died during (re-)handshake.
    Handshake,
    /// Our own writer backlog hit its cap against a stalled peer.
    BacklogOverflow,
}

impl std::fmt::Display for DisconnectCause {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DisconnectCause::Io => write!(f, "io"),
            DisconnectCause::Protocol => write!(f, "protocol"),
            DisconnectCause::Handshake => write!(f, "handshake"),
            DisconnectCause::BacklogOverflow => write!(f, "backlog-overflow"),
        }
    }
}

/// Per-cause disconnect counters, shared by every connection of a
/// coordinator (the satellite replacing the old single opaque count).
#[derive(Debug, Default)]
pub struct DisconnectCounts {
    io: AtomicU64,
    protocol: AtomicU64,
    handshake: AtomicU64,
    backlog_overflow: AtomicU64,
}

impl DisconnectCounts {
    pub fn count(&self, cause: DisconnectCause) {
        let c = match cause {
            DisconnectCause::Io => &self.io,
            DisconnectCause::Protocol => &self.protocol,
            DisconnectCause::Handshake => &self.handshake,
            DisconnectCause::BacklogOverflow => &self.backlog_overflow,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    pub fn io(&self) -> u64 {
        self.io.load(Ordering::Relaxed)
    }

    pub fn protocol(&self) -> u64 {
        self.protocol.load(Ordering::Relaxed)
    }

    pub fn handshake(&self) -> u64 {
        self.handshake.load(Ordering::Relaxed)
    }

    pub fn backlog_overflow(&self) -> u64 {
        self.backlog_overflow.load(Ordering::Relaxed)
    }

    pub fn total(&self) -> u64 {
        self.io() + self.protocol() + self.handshake() + self.backlog_overflow()
    }

    /// A plain-value copy for reports (`FrontendStats`, `ServeReport`).
    pub fn snapshot(&self) -> DisconnectBreakdown {
        DisconnectBreakdown {
            io: self.io(),
            protocol: self.protocol(),
            handshake: self.handshake(),
            backlog_overflow: self.backlog_overflow(),
        }
    }
}

/// Value snapshot of [`DisconnectCounts`] — what lands in run reports.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DisconnectBreakdown {
    pub io: u64,
    pub protocol: u64,
    pub handshake: u64,
    pub backlog_overflow: u64,
}

impl DisconnectBreakdown {
    pub fn total(&self) -> u64 {
        self.io + self.protocol + self.handshake + self.backlog_overflow
    }
}

/// How a [`RemoteRank`] behaves when its session dies unexpectedly.
#[derive(Clone, Copy, Debug)]
pub struct ReconnectPolicy {
    /// Reconnect at all? Off = the pre-reconnect fail-fast behavior
    /// (session death closes the ports for good).
    pub enabled: bool,
    /// First dialer backoff; doubles per failed attempt.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// How long a server may stay unreachable before its shard range
    /// is declared dead (routers migrate candidates off it, the
    /// autoscaler re-tiles its capacity onto survivors).
    pub dead_after: Duration,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        ReconnectPolicy {
            enabled: true,
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(1),
            dead_after: Duration::from_secs(3),
        }
    }
}

impl ReconnectPolicy {
    /// The legacy fail-fast behavior (what tests of the *counting* path
    /// want: one disconnect, ports closed, no background dialing).
    pub fn disabled() -> Self {
        ReconnectPolicy {
            enabled: false,
            ..ReconnectPolicy::default()
        }
    }
}

/// Everything the reader/dialer need to (re)wire a session into the
/// coordinator; captured once by [`RemoteRank::start_reader`].
struct Wiring {
    /// Model-worker inboxes, global model id order.
    model_txs: Vec<RingSender<ToModel>>,
    /// This server's first shard index in the client's global topology.
    shard_offset: usize,
    /// Shared per-cause disconnect counters.
    disconnects: Arc<DisconnectCounts>,
    /// Shared per-shard liveness (global shard indices).
    liveness: ShardLiveness,
}

impl Wiring {
    /// The global shard indices this connection covers.
    fn shard_range(&self, shards: u16) -> std::ops::Range<usize> {
        self.shard_offset..self.shard_offset + shards as usize
    }
}

/// The connection's lifecycle state (see the module docs).
enum ConnState {
    Live { sender: FrameSender, stream: TcpStream },
    Reconnecting,
    Closed,
}

/// One connection to a rank server, shared (via `Arc`) by every
/// [`crate::coordinator::router::RankPort`] that addresses one of its
/// shards, by the cluster controller, and by the reader/dialer threads.
pub struct RemoteRank {
    /// What the server advertised in its first preamble. Re-handshakes
    /// must advertise the same topology (shards and GPU range); only
    /// the per-session `session` counter may differ.
    pub info: ServerPreamble,
    /// The address we dialed (for log lines and re-dialing).
    pub peer: String,
    n_models: usize,
    clock: Clock,
    policy: ReconnectPolicy,
    faults: Arc<FaultPlan>,
    state: Mutex<ConnState>,
    /// Client-side session epoch: 0 for the first session, bumped by
    /// the winning [`RemoteRank::fail_session`] CAS on every death.
    /// Coherent with `state` — both only change under the state lock.
    epoch: AtomicU64,
    /// The server's session counter from the most recent preamble.
    last_session: AtomicU64,
    wiring: Mutex<Option<Arc<Wiring>>>,
    /// The *current* session's writer handle.
    writer: Mutex<Option<JoinHandle<std::io::Result<WriterStats>>>>,
    /// Reader and dialer threads across all sessions (joined at
    /// shutdown; dead sessions' threads exit promptly on their own).
    threads: Mutex<Vec<JoinHandle<()>>>,
    /// Outstanding drain acks by GPU id: parked at `Drain` issue time,
    /// released by the matching `DrainAck` frame. A second drain of the
    /// same GPU before the first acks replaces (and thereby cancels)
    /// the parked sender.
    acks: Mutex<HashMap<u32, Sender<GpuId>>>,
    /// GPUs this client wants detached (drained minus re-attached).
    /// Fresh server sessions spawn fully attached, so the dialer
    /// replays this set as `Drain` frames before the new session goes
    /// live — the server's grantable set matches client intent even
    /// when no autoscaler is running.
    desired_detached: Mutex<BTreeSet<u32>>,
    /// `Granted` frames delivered — the client-side grant count merged
    /// into `ShardStats` at shutdown.
    grants: AtomicU64,
    /// Successful re-handshakes.
    reconnects: AtomicU64,
    /// Down-frames read from an already-dead session and dropped by the
    /// epoch fence.
    fenced: AtomicU64,
    /// Set by [`RemoteRank::close`]: a subsequent EOF is the expected
    /// end of session, not a failure, and the dialer must stop.
    closing: AtomicBool,
}

impl RemoteRank {
    /// Dial `addr` (retrying until `timeout` — the server may still be
    /// binding) and run the handshake: read the server preamble,
    /// answer with the model count, our clock reading (the server
    /// hosts this session's shards in our clock domain), and session
    /// epoch 0.
    pub fn connect(
        addr: &str,
        n_models: usize,
        clock: Clock,
        timeout: Duration,
        policy: ReconnectPolicy,
        faults: Arc<FaultPlan>,
    ) -> Result<Self> {
        let (info, stream) = Self::handshake(addr, n_models, &clock, timeout, 0, &faults)?;
        let _ = faults.spawn_timed_killer(&stream);
        let (sender, writer) = spawn_writer_with(stream.try_clone()?, Some(faults.session()))?;
        Ok(RemoteRank {
            info,
            peer: addr.to_string(),
            n_models,
            clock,
            policy,
            faults,
            state: Mutex::new(ConnState::Live { sender, stream }),
            epoch: AtomicU64::new(0),
            last_session: AtomicU64::new(info.session),
            wiring: Mutex::new(None),
            writer: Mutex::new(Some(writer)),
            threads: Mutex::new(Vec::new()),
            acks: Mutex::new(HashMap::new()),
            desired_detached: Mutex::new(BTreeSet::new()),
            grants: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            fenced: AtomicU64::new(0),
            closing: AtomicBool::new(false),
        })
    }

    /// One TCP connect + preamble/hello exchange. Shared by the initial
    /// [`RemoteRank::connect`] and every dialer re-attempt.
    fn handshake(
        addr: &str,
        n_models: usize,
        clock: &Clock,
        timeout: Duration,
        epoch: u64,
        faults: &FaultPlan,
    ) -> Result<(ServerPreamble, TcpStream)> {
        let stream = connect_retry(addr, timeout)
            .with_context(|| format!("connecting to rank-server {addr}"))?;
        if faults.fail_this_handshake() {
            crate::bail!("fault-plan: injected handshake failure dialing {addr}");
        }
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
        let mut pre = [0u8; PREAMBLE_LEN];
        (&stream)
            .read_exact(&mut pre)
            .with_context(|| format!("reading preamble from rank-server {addr}"))?;
        let info = codec::decode_preamble(&pre)
            .with_context(|| format!("handshake with rank-server {addr}"))?;
        if info.shards == 0 || info.gpu_hi <= info.gpu_lo {
            crate::bail!(
                "rank-server {addr} advertises nothing: {} shards over GPUs {}..{}",
                info.shards,
                info.gpu_lo,
                info.gpu_hi
            );
        }
        let hello = codec::encode_hello(&ClientHello {
            n_models: n_models as u32,
            now_us: clock.now().0,
            epoch,
        });
        (&stream).write_all(&hello)?;
        stream.set_read_timeout(None)?;
        Ok((info, stream))
    }

    /// Start the down-traffic reader and arm the reconnect machinery.
    /// `model_txs` are the model-worker inboxes (global model id
    /// order); `shard_offset` is this server's first shard index in the
    /// client's global topology (re-bases `Overflow::to_shard`);
    /// `disconnects`/`liveness` are the coordinator-wide shared maps.
    /// Frames naming a model or GPU outside what this server may
    /// address fail the session as a counted `Protocol` disconnect (a
    /// worker must never index `backends` off a wire value, and a
    /// silently dropped grant would wedge capacity).
    pub fn start_reader(
        self: &Arc<Self>,
        model_txs: Vec<RingSender<ToModel>>,
        shard_offset: usize,
        disconnects: Arc<DisconnectCounts>,
        liveness: ShardLiveness,
    ) {
        let wiring = Arc::new(Wiring {
            model_txs,
            shard_offset,
            disconnects,
            liveness,
        });
        *relock(&self.wiring) = Some(Arc::clone(&wiring));
        let epoch = self.epoch.load(Ordering::SeqCst);
        self.spawn_reader(wiring, epoch);
    }

    /// Spawn the reader thread for the current session. The thread
    /// captures `session_epoch` and reports any unexpected end through
    /// [`RemoteRank::fail_session`], whose CAS makes duplicate reports
    /// from racing detectors benign.
    fn spawn_reader(self: &Arc<Self>, wiring: Arc<Wiring>, session_epoch: u64) {
        // fd exhaustion / thread-spawn failure below are resource
        // errors, not bugs: surface them exactly like an immediate
        // unexpected disconnect instead of panicking the caller.
        let stream = {
            let st = relock(&self.state);
            match &*st {
                ConnState::Live { stream, .. } => stream.try_clone(),
                // The session died between adoption and here; the
                // failing path already spawned the next dialer.
                _ => return,
            }
        };
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                log_error!("rank-server {}: cloning stream failed: {e}", self.peer);
                self.fail_session(DisconnectCause::Io, session_epoch);
                return;
            }
        };
        let conn = Arc::clone(self);
        let h = std::thread::Builder::new()
            .name("rank-wire-reader".into())
            .spawn(move || {
                if let Some(cause) = conn.read_loop(stream, &wiring, session_epoch) {
                    conn.fail_session(cause, session_epoch);
                }
            });
        match h {
            Ok(h) => relock(&self.threads).push(h),
            Err(e) => {
                log_error!("rank-server {}: spawning reader failed: {e}", self.peer);
                self.fail_session(DisconnectCause::Io, session_epoch);
            }
        }
    }

    /// The first detector of a dead session wins the epoch CAS and runs
    /// the teardown: count the cause, close the send queue, shut the
    /// socket down (unblocking a reader mid-`read`), drop parked drain
    /// acks, and either enter `Reconnecting` (spawning the dialer) or
    /// `Closed` (policy disabled / shutting down). Losers return
    /// immediately — a read error, a send error, and a backlog overflow
    /// racing on the same corpse count one disconnect, not three.
    fn fail_session(self: &Arc<Self>, cause: DisconnectCause, observed_epoch: u64) {
        let wiring = relock(&self.wiring).clone();
        let closing = self.closing.load(Ordering::SeqCst);
        let reconnect = self.policy.enabled && !closing && wiring.is_some();
        {
            let mut st = relock(&self.state);
            // Epoch and state change together under the state lock, so
            // a send that saw (Live, e) can always report against e.
            if self
                .epoch
                .compare_exchange(
                    observed_epoch,
                    observed_epoch + 1,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                )
                .is_err()
            {
                return;
            }
            if let ConnState::Live { sender, stream } = &*st {
                sender.close();
                let _ = stream.shutdown(Shutdown::Both);
            }
            *st = if reconnect {
                ConnState::Reconnecting
            } else {
                ConnState::Closed
            };
        }
        // Parked drain acks die with the session: a waiter blocked on
        // the ack sees `Disconnected` promptly, exactly like a dead
        // in-process shard dropping its ack sender.
        relock(&self.acks).clear();
        if !closing {
            if let Some(w) = &wiring {
                w.disconnects.count(cause);
            }
            log_warn!(
                "rank-server {}: session epoch {observed_epoch} failed ({cause}); {}",
                self.peer,
                if reconnect {
                    "reconnecting"
                } else {
                    "rank ports closed (candidates in flight are lost)"
                }
            );
        }
        if reconnect {
            self.spawn_dialer(observed_epoch + 1);
        }
    }

    fn spawn_dialer(self: &Arc<Self>, epoch: u64) {
        let conn = Arc::clone(self);
        let h = std::thread::Builder::new()
            .name("rank-wire-dialer".into())
            .spawn(move || conn.dial_loop(epoch));
        match h {
            Ok(h) => relock(&self.threads).push(h),
            Err(e) => {
                log_error!(
                    "rank-server {}: cannot spawn dialer ({e}); rank ports closed",
                    self.peer
                );
                *relock(&self.state) = ConnState::Closed;
            }
        }
    }

    /// The background dialer: capped exponential backoff until the
    /// server answers with the *same* topology, `close()` is called, or
    /// — past `dead_after` — the shard range is declared dead (the
    /// dialer keeps trying even then; an eventual reconnect re-adopts
    /// the range).
    fn dial_loop(self: Arc<Self>, epoch: u64) {
        // The dead session's writer has exited (queue closed); reap its
        // handle so `join()` never waits on a replaced writer.
        let old_writer = relock(&self.writer).take();
        if let Some(h) = old_writer {
            let _ = h.join();
        }
        let Some(wiring) = relock(&self.wiring).clone() else {
            *relock(&self.state) = ConnState::Closed;
            return;
        };
        let shards = wiring.shard_range(self.info.shards);
        let started = Instant::now();
        let mut backoff = self.policy.backoff_base;
        let mut declared_dead = false;
        let mut attempts = 0u64;
        loop {
            if self.closing.load(Ordering::SeqCst) {
                *relock(&self.state) = ConnState::Closed;
                return;
            }
            if !declared_dead && started.elapsed() >= self.policy.dead_after {
                declared_dead = true;
                wiring.liveness.set_range_live(shards.clone(), false);
                log_warn!(
                    "rank-server {}: unreachable for {:?}; shards {}..{} declared dead \
                     (candidates migrate to survivors; capacity re-tiles)",
                    self.peer, self.policy.dead_after, shards.start, shards.end
                );
            }
            attempts += 1;
            match Self::handshake(
                &self.peer,
                self.n_models,
                &self.clock,
                DIAL_ATTEMPT_TIMEOUT,
                epoch,
                &self.faults,
            ) {
                Ok((info, stream)) => {
                    if info.shards == self.info.shards
                        && info.gpu_lo == self.info.gpu_lo
                        && info.gpu_hi == self.info.gpu_hi
                    {
                        if self.adopt_session(info, stream, &wiring, epoch) {
                            return;
                        }
                    } else {
                        log_warn!(
                            "rank-server {}: reconnected but topology changed \
                             ({} shards over {}..{}, had {} over {}..{}); retrying",
                            self.peer,
                            info.shards,
                            info.gpu_lo,
                            info.gpu_hi,
                            self.info.shards,
                            self.info.gpu_lo,
                            self.info.gpu_hi
                        );
                    }
                }
                Err(e) => {
                    // The logger's per-call-site token bucket replaces
                    // the old hand-rolled `attempts % 16` throttle: a
                    // long outage still traces, without drowning the
                    // log (the suppressed count says how long).
                    log_warn!(
                        "rank-server {}: reconnect attempt {attempts} failed: {e:#}",
                        self.peer
                    );
                }
            }
            // Sliced sleep so close() stops the dialer within ~10ms.
            let mut slept = Duration::ZERO;
            while slept < backoff {
                if self.closing.load(Ordering::SeqCst) {
                    *relock(&self.state) = ConnState::Closed;
                    return;
                }
                let slice = Duration::from_millis(10).min(backoff - slept);
                std::thread::sleep(slice);
                slept += slice;
            }
            backoff = (backoff * 2).min(self.policy.backoff_cap);
        }
    }

    /// Wire a fresh handshake into the connection: new writer, replay
    /// of the desired-detached set, state → `Live`, liveness back up,
    /// new epoch-fenced reader, and the `Reregister` nudge that makes
    /// every model replay its candidate into the fresh shard set.
    /// Returns false if session setup failed (the dialer retries).
    fn adopt_session(
        self: &Arc<Self>,
        info: ServerPreamble,
        stream: TcpStream,
        wiring: &Arc<Wiring>,
        epoch: u64,
    ) -> bool {
        let _ = self.faults.spawn_timed_killer(&stream);
        let writer_stream = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => return false,
        };
        let (sender, writer) = match spawn_writer_with(writer_stream, Some(self.faults.session()))
        {
            Ok(x) => x,
            Err(_) => return false,
        };
        // Replay desired-detached *before* going Live: a fresh session
        // spawns fully attached, and these drains must precede anything
        // the autoscaler sends once `Live` opens the ports — otherwise
        // a GPU could be granted before its backend worker exists. The
        // acks come back as DrainAck frames with no parked sender,
        // which the dispatcher treats as benign.
        for &g in relock(&self.desired_detached).iter() {
            let mut buf = Vec::with_capacity(16);
            codec::encode_up(self.local_shard_of(g), &WireToRank::Drain { gpu: GpuId(g) }, &mut buf);
            let _ = sender.send(buf);
        }
        {
            let mut st = relock(&self.state);
            if matches!(&*st, ConnState::Closed) {
                // close() raced the adoption; stay down.
                sender.close();
                return true;
            }
            *st = ConnState::Live { sender, stream };
        }
        *relock(&self.writer) = Some(writer);
        self.last_session.store(info.session, Ordering::SeqCst);
        self.reconnects.fetch_add(1, Ordering::SeqCst);
        wiring
            .liveness
            .set_range_live(wiring.shard_range(self.info.shards), true);
        self.spawn_reader(Arc::clone(wiring), epoch);
        // The re-registration replay: every model worker invalidates
        // its coalescing state and re-registers its current candidate —
        // into its (revived) home shard or wherever liveness routes it.
        for (m, tx) in wiring.model_txs.iter().enumerate() {
            let _ = tx.send(ToModel::Reregister {
                model: ModelId(m as u32),
            });
        }
        log_info!(
            "rank-server {}: reconnected (client epoch {epoch}, server session {})",
            self.peer, info.session
        );
        true
    }

    /// The server-local shard index owning GPU `g` (the session shards
    /// split `gpu_lo..gpu_hi` with `ShardTopology::split`).
    fn local_shard_of(&self, g: u32) -> u16 {
        let range = self.info.gpu_lo..self.info.gpu_hi;
        let shards = self.info.shards as usize;
        for s in 0..shards {
            if g < crate::coordinator::router::ShardTopology::split(&range, shards, s + 1) {
                return s as u16;
            }
        }
        self.info.shards.saturating_sub(1)
    }

    /// Returns the cause if the session ended *unexpectedly*. Every
    /// frame is fenced against the session epoch captured at reader
    /// spawn: once a newer epoch exists, buffered frames from this
    /// (dead) session are dropped and counted, never dispatched.
    fn read_loop(
        &self,
        stream: TcpStream,
        wiring: &Wiring,
        session_epoch: u64,
    ) -> Option<DisconnectCause> {
        let mut reader = FrameReader::new(stream);
        loop {
            match reader.next_frame() {
                Ok(Some(frame)) => {
                    if self.epoch.load(Ordering::SeqCst) != session_epoch {
                        // The epoch fence: a stale Granted must never
                        // lease a GPU in the new session.
                        self.fenced.fetch_add(1, Ordering::Relaxed);
                        return None;
                    }
                    match codec::decode_down(frame) {
                        Ok(msg) => {
                            if let Err(why) = self.dispatch(msg, wiring) {
                                log_error!(
                                    "rank-server {}: protocol violation: {why}",
                                    self.peer
                                );
                                return Some(DisconnectCause::Protocol);
                            }
                        }
                        Err(e) => {
                            log_error!("rank-server {}: protocol error: {e}", self.peer);
                            return Some(DisconnectCause::Protocol);
                        }
                    }
                }
                Ok(None) => {
                    return if self.closing.load(Ordering::SeqCst) {
                        None
                    } else {
                        Some(DisconnectCause::Io)
                    }
                }
                Err(e) => {
                    if self.closing.load(Ordering::SeqCst)
                        || self.epoch.load(Ordering::SeqCst) != session_epoch
                    {
                        return None;
                    }
                    log_error!("rank-server {}: read error: {e}", self.peer);
                    return Some(DisconnectCause::Io);
                }
            }
        }
    }

    /// Apply one down-frame. A frame naming a GPU outside this server's
    /// advertised range or an unknown model is a protocol violation and
    /// fails the session (mirroring the server's treatment of bad
    /// up-frames): silently dropping e.g. a foreign grant would leave
    /// the granting shard's GPU leased forever — a quiet capacity
    /// wedge — whereas a surfaced disconnect is visible and counted.
    fn dispatch(&self, msg: WireFromRank, wiring: &Wiring) -> Result<(), String> {
        match msg {
            WireFromRank::Granted { model, gpu } => {
                if !self.info.owns(gpu) {
                    return Err(format!("grant for foreign GPU {}", gpu.0));
                }
                let Some(tx) = wiring.model_txs.get(model.0 as usize) else {
                    return Err(format!("grant for unknown model {}", model.0));
                };
                self.grants.fetch_add(1, Ordering::Relaxed);
                trace::model_event(Stage::WireGrantRx, model);
                let _ = tx.send(ToModel::Granted { model, gpu });
            }
            WireFromRank::Revalidate { model } => {
                let Some(tx) = wiring.model_txs.get(model.0 as usize) else {
                    return Err(format!("revalidate for unknown model {}", model.0));
                };
                let _ = tx.send(ToModel::Revalidate { model });
            }
            WireFromRank::Overflow {
                model,
                to_shard,
                seq,
            } => {
                if to_shard >= self.info.shards {
                    return Err(format!(
                        "overflow verdict for local shard {to_shard} of {}",
                        self.info.shards
                    ));
                }
                let Some(tx) = wiring.model_txs.get(model.0 as usize) else {
                    return Err(format!("overflow for unknown model {}", model.0));
                };
                let _ = tx.send(ToModel::Overflow {
                    model,
                    to_shard: wiring.shard_offset + to_shard as usize,
                    seq,
                });
            }
            WireFromRank::DrainAck { gpu } => {
                if !self.info.owns(gpu) {
                    return Err(format!("drain ack for foreign GPU {}", gpu.0));
                }
                // No parked sender is benign: an `Attach` may have
                // canceled the drain while this ack was in flight (or
                // this is the ack of a reconnect-replay drain).
                // Take the sender out first — an `if let` scrutinee
                // guard would live across the `.send(` below.
                let parked = relock(&self.acks).remove(&gpu.0);
                if let Some(ack) = parked {
                    let _ = ack.send(gpu);
                }
            }
        }
        Ok(())
    }

    /// Encode and enqueue one up-message for `shard` (server-local
    /// index). One small allocation per frame; the writer thread
    /// coalesces the queue into one syscall per drain.
    ///
    /// State-dependent semantics: `Live` enqueues (a failed enqueue
    /// fails the session — overflow and writer death are detected
    /// here); `Reconnecting` silently drops registrations and
    /// busy-until hints (`Ok` — the reconnect replay re-derives them)
    /// but refuses drain/attach (`Err` — the autoscaler must know);
    /// `Closed` refuses everything.
    pub fn send(self: &Arc<Self>, shard: u16, msg: &WireToRank) -> Result<(), PortClosed> {
        let (sender, epoch) = {
            let st = relock(&self.state);
            match &*st {
                ConnState::Live { sender, .. } => {
                    (sender.clone(), self.epoch.load(Ordering::SeqCst))
                }
                ConnState::Reconnecting => {
                    return match msg {
                        WireToRank::Candidate { .. } | WireToRank::GpuBusyUntil { .. } => Ok(()),
                        WireToRank::Drain { .. } | WireToRank::Attach { .. } => Err(PortClosed),
                    }
                }
                ConnState::Closed => return Err(PortClosed),
            }
        };
        if let WireToRank::Candidate {
            model,
            cand: Some(_),
            ..
        } = msg
        {
            trace::model_event(Stage::WireCandTx, *model);
        }
        let mut buf = Vec::with_capacity(48);
        codec::encode_up(shard, msg, &mut buf);
        match sender.send(buf) {
            Ok(()) => Ok(()),
            Err(fail) => {
                if !self.closing.load(Ordering::SeqCst) {
                    let cause = match fail {
                        SendFail::Overflow => DisconnectCause::BacklogOverflow,
                        SendFail::Closed => DisconnectCause::Io,
                    };
                    self.fail_session(cause, epoch);
                }
                Err(PortClosed)
            }
        }
    }

    /// The wire form of `ToRank::Drain`: park the ack sender, ship the
    /// frame, record the detach intent for reconnect replay; the reader
    /// releases the sender on the matching `DrainAck`.
    pub fn drain(self: &Arc<Self>, shard: u16, gpu: GpuId, ack: Sender<GpuId>) -> Result<(), PortClosed> {
        relock(&self.acks).insert(gpu.0, ack);
        let res = self.send(shard, &WireToRank::Drain { gpu });
        if res.is_ok() {
            relock(&self.desired_detached).insert(gpu.0);
        } else {
            relock(&self.acks).remove(&gpu.0);
        }
        res
    }

    /// The wire form of `ToRank::Attach`. Attaching a still-draining
    /// GPU cancels the drain server-side and its ack never fires (the
    /// in-process shard drops its ack sender on cancel), so the parked
    /// sender is dropped here too — a waiter blocked on the ack sees
    /// `Disconnected` promptly instead of hanging on a canceled drain.
    pub fn attach(self: &Arc<Self>, shard: u16, gpu: GpuId) -> Result<(), PortClosed> {
        relock(&self.acks).remove(&gpu.0);
        let res = self.send(shard, &WireToRank::Attach { gpu });
        if res.is_ok() {
            relock(&self.desired_detached).remove(&gpu.0);
        }
        res
    }

    /// `Granted` frames delivered so far.
    pub fn grants(&self) -> u64 {
        self.grants.load(Ordering::Relaxed)
    }

    /// Successful re-handshakes so far.
    pub fn reconnects(&self) -> u64 {
        self.reconnects.load(Ordering::SeqCst)
    }

    /// Stale-session down-frames dropped by the epoch fence.
    pub fn fenced(&self) -> u64 {
        self.fenced.load(Ordering::Relaxed)
    }

    /// Begin a clean shutdown: queued frames flush, the write half
    /// closes (the server ends the session on EOF), the dialer (if
    /// any) stops, and the reader's subsequent EOF is not counted as a
    /// disconnect. Idempotent.
    pub fn close(&self) {
        self.closing.store(true, Ordering::SeqCst);
        let mut st = relock(&self.state);
        match &*st {
            ConnState::Live { sender, .. } => sender.close(),
            ConnState::Reconnecting => *st = ConnState::Closed,
            ConnState::Closed => {}
        }
    }

    /// Join the writer, reader, and dialer threads (after
    /// [`RemoteRank::close`]). The handles are taken out before
    /// joining: holding a mutex across `.join()` would block any
    /// concurrent session transition for the whole thread lifetime.
    pub fn join(&self) {
        let writer = relock(&self.writer).take();
        if let Some(h) = writer {
            let _ = h.join();
        }
        loop {
            // Threads can spawn threads (a failing reader spawns a
            // dialer): drain until quiescent.
            let batch: Vec<JoinHandle<()>> = relock(&self.threads).drain(..).collect();
            if batch.is_empty() {
                break;
            }
            for h in batch {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::codec::{encode_down, encode_hello, encode_preamble, HELLO_LEN};
    use crate::util::ring::ring;
    use std::net::TcpListener;

    /// A one-session fake rank server: writes a preamble, reads the
    /// hello, then writes `frames` down-frames and closes.
    fn fake_server(
        shards: u16,
        frames: Vec<WireFromRank>,
    ) -> (String, std::thread::JoinHandle<ClientHello>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = format!("127.0.0.1:{}", listener.local_addr().unwrap().port());
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.write_all(&encode_preamble(&ServerPreamble {
                shards,
                gpu_lo: 0,
                gpu_hi: 2,
                session: 1,
            }))
            .unwrap();
            let mut hello = [0u8; HELLO_LEN];
            s.read_exact(&mut hello).unwrap();
            let hello = codec::decode_hello(&hello).unwrap();
            let mut buf = Vec::new();
            for f in &frames {
                let mut payload = Vec::new();
                encode_down(f, &mut payload);
                buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                buf.extend_from_slice(&payload);
            }
            s.write_all(&buf).unwrap();
            hello
        });
        (addr, h)
    }

    fn test_wiring(n_models: usize) -> (Arc<Wiring>, crate::util::ring::RingReceiver<ToModel>) {
        let (tx, rx) = ring::<ToModel>(64);
        let mut model_txs = Vec::new();
        for _ in 0..n_models {
            model_txs.push(tx.clone());
        }
        (
            Arc::new(Wiring {
                model_txs,
                shard_offset: 0,
                disconnects: Arc::new(DisconnectCounts::default()),
                liveness: ShardLiveness::all_live(1),
            }),
            rx,
        )
    }

    /// The epoch-fence regression test of the acceptance criteria: a
    /// down-frame buffered from a session whose epoch has already been
    /// superseded is dropped and counted, never dispatched — a stale
    /// `Granted` cannot lease a GPU in the new session.
    #[test]
    fn stale_session_frames_are_fenced() {
        let grant = WireFromRank::Granted {
            model: ModelId(0),
            gpu: GpuId(0),
        };
        let (addr, server) = fake_server(1, vec![grant, grant]);
        let conn = Arc::new(
            RemoteRank::connect(
                &addr,
                1,
                Clock::new(),
                Duration::from_secs(5),
                ReconnectPolicy::disabled(),
                FaultPlan::none(),
            )
            .unwrap(),
        );
        assert_eq!(server.join().unwrap().epoch, 0, "first hello carries epoch 0");
        let (wiring, rx) = test_wiring(1);
        *relock(&conn.wiring) = Some(Arc::clone(&wiring));
        // The session dies (epoch 0 → 1) before its buffered frames are
        // read. Running the (old session's) read loop afterwards must
        // deliver nothing.
        conn.fail_session(DisconnectCause::Io, 0);
        assert_eq!(wiring.disconnects.total(), 1);
        assert_eq!(wiring.disconnects.io(), 1);
        // fail_session shut the live stream down; hand the read loop a
        // fresh connection to the same buffered bytes instead.
        let (addr2, server2) = fake_server(1, vec![grant, grant]);
        let stream = TcpStream::connect(&addr2).unwrap();
        let mut pre = [0u8; PREAMBLE_LEN];
        (&stream).read_exact(&mut pre).unwrap();
        (&stream)
            .write_all(&encode_hello(&ClientHello {
                n_models: 1,
                now_us: 0,
                epoch: 0,
            }))
            .unwrap();
        let ended = conn.read_loop(stream, &wiring, 0);
        assert_eq!(ended, None, "a fenced exit is not a new disconnect");
        assert!(conn.fenced() > 0, "fenced frames are counted");
        assert_eq!(conn.grants(), 0, "no grant delivered");
        assert!(rx.try_iter().next().is_none(), "nothing reached the worker");
        let _ = server2.join();
    }

    /// With the session current, the same frames DO dispatch (the fence
    /// only bites after an epoch bump) — and a duplicate fail_session
    /// for the same epoch counts once.
    #[test]
    fn current_session_frames_dispatch_and_fail_is_idempotent() {
        let grant = WireFromRank::Granted {
            model: ModelId(0),
            gpu: GpuId(1),
        };
        let (addr, server) = fake_server(1, vec![grant]);
        let conn = Arc::new(
            RemoteRank::connect(
                &addr,
                1,
                Clock::new(),
                Duration::from_secs(5),
                ReconnectPolicy::disabled(),
                FaultPlan::none(),
            )
            .unwrap(),
        );
        let _ = server.join();
        let (wiring, rx) = test_wiring(1);
        *relock(&conn.wiring) = Some(Arc::clone(&wiring));
        let stream = {
            let st = relock(&conn.state);
            match &*st {
                ConnState::Live { stream, .. } => stream.try_clone().unwrap(),
                _ => unreachable!("fresh connection is live"),
            }
        };
        let ended = conn.read_loop(stream, &wiring, 0);
        assert_eq!(
            ended,
            Some(DisconnectCause::Io),
            "server closing mid-session is an unexpected EOF"
        );
        assert_eq!(conn.grants(), 1);
        assert!(matches!(
            rx.try_iter().next(),
            Some(ToModel::Granted { gpu: GpuId(1), .. })
        ));
        conn.fail_session(DisconnectCause::Io, 0);
        conn.fail_session(DisconnectCause::Protocol, 0);
        assert_eq!(
            wiring.disconnects.total(),
            1,
            "racing detectors count one disconnect"
        );
    }

    /// Reconnecting-state send semantics: registrations drop as Ok,
    /// drain/attach fail, and the drain records no detach intent.
    #[test]
    fn reconnecting_drops_registrations_and_refuses_control() {
        let (addr, server) = fake_server(1, Vec::new());
        let conn = Arc::new(
            RemoteRank::connect(
                &addr,
                1,
                Clock::new(),
                Duration::from_secs(5),
                ReconnectPolicy::disabled(),
                FaultPlan::none(),
            )
            .unwrap(),
        );
        let _ = server.join();
        *relock(&conn.state) = ConnState::Reconnecting;
        assert_eq!(
            conn.send(
                0,
                &WireToRank::Candidate {
                    model: ModelId(0),
                    cand: None,
                    seq: 1,
                    hops: 0,
                }
            ),
            Ok(()),
            "registrations drop silently (replay heals them)"
        );
        assert_eq!(
            conn.send(
                0,
                &WireToRank::GpuBusyUntil {
                    gpu: GpuId(0),
                    free_at: crate::core::time::Micros(1),
                }
            ),
            Ok(())
        );
        let (ack_tx, _ack_rx) = std::sync::mpsc::channel();
        assert_eq!(conn.drain(0, GpuId(0), ack_tx), Err(PortClosed));
        assert!(relock(&conn.desired_detached).is_empty());
        assert_eq!(conn.attach(0, GpuId(0)), Err(PortClosed));
        *relock(&conn.state) = ConnState::Closed;
        assert_eq!(
            conn.send(
                0,
                &WireToRank::Candidate {
                    model: ModelId(0),
                    cand: None,
                    seq: 2,
                    hops: 0,
                }
            ),
            Err(PortClosed),
            "Closed refuses everything"
        );
    }

    /// The desired-detached replay maps GPUs onto server-local shards
    /// with the shared split formula.
    #[test]
    fn local_shard_of_matches_split() {
        let (addr, server) = fake_server(2, Vec::new());
        let conn = RemoteRank::connect(
            &addr,
            1,
            Clock::new(),
            Duration::from_secs(5),
            ReconnectPolicy::disabled(),
            FaultPlan::none(),
        )
        .unwrap();
        let _ = server.join();
        // 2 shards over GPUs 0..2: shard 0 owns {0}, shard 1 owns {1}.
        assert_eq!(conn.local_shard_of(0), 0);
        assert_eq!(conn.local_shard_of(1), 1);
        conn.close();
    }
}
