//! Client side of the rank-coordination wire: one [`RemoteRank`] per
//! `symphony rank-server` connection.
//!
//! A connection multiplexes every shard the server hosts. The write
//! side goes through the coalescing [`crate::net::transport`] writer
//! (one syscall per queued burst); a single reader thread decodes the
//! down-traffic and fans it out exactly like an in-process rank shard
//! would:
//!
//! * `Granted` / `Revalidate` / `Overflow` → the owning model worker's
//!   inbox (`Overflow::to_shard` is re-based from the server-local
//!   shard index into the client's global topology);
//! * `DrainAck` → the `Sender<GpuId>` parked in the ack table when the
//!   matching `Drain` was issued — the wire form of the in-process
//!   `ToRank::Drain { ack }` contract, so `ClusterCtl` and the live
//!   autoscaler work unchanged over the wire.
//!
//! A disconnect that the client did not initiate is **surfaced, never
//! swallowed**: the shared disconnect counter increments, the event is
//! logged, and the send queue closes so every subsequent
//! [`RemoteRank::send`] fails fast with [`PortClosed`] — model workers
//! observe a dead rank tier exactly like a dead in-process shard
//! thread, instead of wedging on a silent black hole. There is no
//! transparent reconnect: candidate registrations are ephemeral state,
//! so a reconnect needs a fresh session (tracked in the ROADMAP).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::messages::ToModel;
use crate::coordinator::router::PortClosed;
use crate::coordinator::Clock;
use crate::core::types::GpuId;
use crate::net::codec::{self, ClientHello, ServerPreamble, WireFromRank, WireToRank, PREAMBLE_LEN};
use crate::net::transport::{connect_retry, spawn_writer, FrameReader, FrameSender, WriterStats};
use crate::util::error::{Context, Result};
use crate::util::ring::RingSender;
use crate::util::sync::relock;

/// How long the handshake may block before the peer is declared broken.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// One live connection to a rank server, shared (via `Arc`) by every
/// [`crate::coordinator::router::RankPort`] that addresses one of its
/// shards, by the cluster controller, and by the reader thread.
pub struct RemoteRank {
    /// What the server advertised in its preamble.
    pub info: ServerPreamble,
    /// The address we dialed (for log lines).
    pub peer: String,
    stream: TcpStream,
    sender: FrameSender,
    writer: Mutex<Option<JoinHandle<std::io::Result<WriterStats>>>>,
    reader: Mutex<Option<JoinHandle<()>>>,
    /// Outstanding drain acks by GPU id: parked at `Drain` issue time,
    /// released by the matching `DrainAck` frame. A second drain of the
    /// same GPU before the first acks replaces (and thereby cancels)
    /// the parked sender.
    acks: Mutex<HashMap<u32, Sender<GpuId>>>,
    /// `Granted` frames delivered — the client-side grant count merged
    /// into `ShardStats` at shutdown (the server keeps the
    /// authoritative per-shard stats and logs them per session).
    grants: AtomicU64,
    /// Set by [`RemoteRank::close`]: a subsequent EOF is the expected
    /// end of session, not a failure.
    closing: AtomicBool,
}

impl RemoteRank {
    /// Dial `addr` (retrying until `timeout` — the server may still be
    /// binding) and run the handshake: read the server preamble,
    /// answer with the model count and our clock reading so the server
    /// can host this session's shards in our clock domain.
    pub fn connect(addr: &str, n_models: usize, clock: Clock, timeout: Duration) -> Result<Self> {
        let stream = connect_retry(addr, timeout)
            .with_context(|| format!("connecting to rank-server {addr}"))?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
        let mut pre = [0u8; PREAMBLE_LEN];
        (&stream)
            .read_exact(&mut pre)
            .with_context(|| format!("reading preamble from rank-server {addr}"))?;
        let info = codec::decode_preamble(&pre)
            .with_context(|| format!("handshake with rank-server {addr}"))?;
        if info.shards == 0 || info.gpu_hi <= info.gpu_lo {
            crate::bail!(
                "rank-server {addr} advertises nothing: {} shards over GPUs {}..{}",
                info.shards,
                info.gpu_lo,
                info.gpu_hi
            );
        }
        let hello = codec::encode_hello(&ClientHello {
            n_models: n_models as u32,
            now_us: clock.now().0,
        });
        (&stream).write_all(&hello)?;
        stream.set_read_timeout(None)?;
        let (sender, writer) = spawn_writer(stream.try_clone()?)?;
        Ok(RemoteRank {
            info,
            peer: addr.to_string(),
            stream,
            sender,
            writer: Mutex::new(Some(writer)),
            reader: Mutex::new(None),
            acks: Mutex::new(HashMap::new()),
            grants: AtomicU64::new(0),
            closing: AtomicBool::new(false),
        })
    }

    /// Start the down-traffic reader. `model_txs` are the model-worker
    /// inboxes (global model id order); `shard_offset` is this server's
    /// first shard index in the client's global topology (re-bases
    /// `Overflow::to_shard`); `disconnects` is the shared counter an
    /// unexpected EOF/IO error increments. Frames naming a model or GPU
    /// outside what this server may address fail the session as a
    /// counted disconnect (a worker must never index `backends` off a
    /// wire value, and a silently dropped grant would wedge capacity).
    pub fn start_reader(
        self: &Arc<Self>,
        model_txs: Vec<RingSender<ToModel>>,
        shard_offset: usize,
        disconnects: Arc<AtomicU64>,
    ) {
        let conn = Arc::clone(self);
        // fd exhaustion / thread-spawn failure below are resource
        // errors, not bugs: surface them exactly like an immediate
        // unexpected disconnect instead of panicking the caller.
        let stream = match self.stream.try_clone() {
            Ok(s) => s,
            Err(e) => {
                self.fail_session(&disconnects, &format!("cloning stream: {e}"));
                return;
            }
        };
        let spawn_disconnects = Arc::clone(&disconnects);
        let h = std::thread::Builder::new()
            .name("rank-wire-reader".into())
            .spawn(move || {
                let unexpected = conn.read_loop(stream, &model_txs, shard_offset);
                if unexpected {
                    spawn_disconnects.fetch_add(1, Ordering::Relaxed);
                    // Fail the ports fast: a send into a dead rank tier
                    // must error like a dead in-process shard, not
                    // queue forever. Parked drain-ack senders drop too,
                    // so a blocking `recv()` on a pending drain sees
                    // Disconnected — exactly what a dead in-process
                    // shard (dropping the ack sender with its state)
                    // would produce.
                    conn.sender.close();
                    relock(&conn.acks).clear();
                    eprintln!(
                        "rank-server {} disconnected; rank ports closed \
                         (candidates in flight are lost)",
                        conn.peer
                    );
                }
            });
        match h {
            Ok(h) => *relock(&self.reader) = Some(h),
            Err(e) => self.fail_session(&disconnects, &format!("spawning reader: {e}")),
        }
    }

    /// Close the session as an unexpected disconnect before the reader
    /// ever ran (stream clone or thread spawn failed).
    fn fail_session(&self, disconnects: &AtomicU64, why: &str) {
        disconnects.fetch_add(1, Ordering::Relaxed);
        self.sender.close();
        relock(&self.acks).clear();
        eprintln!(
            "rank-server {}: reader startup failed ({why}); rank ports closed",
            self.peer
        );
    }

    /// Returns whether the session ended *unexpectedly*.
    fn read_loop(
        &self,
        stream: TcpStream,
        model_txs: &[RingSender<ToModel>],
        shard_offset: usize,
    ) -> bool {
        let mut reader = FrameReader::new(stream);
        loop {
            match reader.next_frame() {
                Ok(Some(frame)) => match codec::decode_down(frame) {
                    Ok(msg) => {
                        if let Err(why) = self.dispatch(msg, model_txs, shard_offset) {
                            eprintln!(
                                "rank-server {}: protocol violation: {why}",
                                self.peer
                            );
                            return true;
                        }
                    }
                    Err(e) => {
                        eprintln!("rank-server {}: protocol error: {e}", self.peer);
                        return true;
                    }
                },
                Ok(None) => return !self.closing.load(Ordering::SeqCst),
                Err(e) => {
                    if self.closing.load(Ordering::SeqCst) {
                        return false;
                    }
                    eprintln!("rank-server {}: read error: {e}", self.peer);
                    return true;
                }
            }
        }
    }

    /// Apply one down-frame. A frame naming a GPU outside this server's
    /// advertised range or an unknown model is a protocol violation and
    /// fails the session (mirroring the server's treatment of bad
    /// up-frames): silently dropping e.g. a foreign grant would leave
    /// the granting shard's GPU leased forever — a quiet capacity
    /// wedge — whereas a surfaced disconnect is visible and counted.
    fn dispatch(
        &self,
        msg: WireFromRank,
        model_txs: &[RingSender<ToModel>],
        shard_offset: usize,
    ) -> Result<(), String> {
        match msg {
            WireFromRank::Granted { model, gpu } => {
                if !self.info.owns(gpu) {
                    return Err(format!("grant for foreign GPU {}", gpu.0));
                }
                let Some(tx) = model_txs.get(model.0 as usize) else {
                    return Err(format!("grant for unknown model {}", model.0));
                };
                self.grants.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(ToModel::Granted { model, gpu });
            }
            WireFromRank::Revalidate { model } => {
                let Some(tx) = model_txs.get(model.0 as usize) else {
                    return Err(format!("revalidate for unknown model {}", model.0));
                };
                let _ = tx.send(ToModel::Revalidate { model });
            }
            WireFromRank::Overflow {
                model,
                to_shard,
                seq,
            } => {
                if to_shard >= self.info.shards {
                    return Err(format!(
                        "overflow verdict for local shard {to_shard} of {}",
                        self.info.shards
                    ));
                }
                let Some(tx) = model_txs.get(model.0 as usize) else {
                    return Err(format!("overflow for unknown model {}", model.0));
                };
                let _ = tx.send(ToModel::Overflow {
                    model,
                    to_shard: shard_offset + to_shard as usize,
                    seq,
                });
            }
            WireFromRank::DrainAck { gpu } => {
                if !self.info.owns(gpu) {
                    return Err(format!("drain ack for foreign GPU {}", gpu.0));
                }
                // No parked sender is benign: an `Attach` may have
                // canceled the drain while this ack was in flight.
                // Take the sender out first — an `if let` scrutinee
                // guard would live across the `.send(` below.
                let parked = relock(&self.acks).remove(&gpu.0);
                if let Some(ack) = parked {
                    let _ = ack.send(gpu);
                }
            }
        }
        Ok(())
    }

    /// Encode and enqueue one up-message for `shard` (server-local
    /// index). One small allocation per frame; the writer thread
    /// coalesces the queue into one syscall per drain.
    pub fn send(&self, shard: u16, msg: &WireToRank) -> Result<(), PortClosed> {
        let mut buf = Vec::with_capacity(48);
        codec::encode_up(shard, msg, &mut buf);
        self.sender.send(buf).map_err(|_| PortClosed)
    }

    /// The wire form of `ToRank::Drain`: park the ack sender, ship the
    /// frame; the reader releases the sender on the matching
    /// `DrainAck`.
    pub fn drain(&self, shard: u16, gpu: GpuId, ack: Sender<GpuId>) -> Result<(), PortClosed> {
        relock(&self.acks).insert(gpu.0, ack);
        let res = self.send(shard, &WireToRank::Drain { gpu });
        if res.is_err() {
            relock(&self.acks).remove(&gpu.0);
        }
        res
    }

    /// The wire form of `ToRank::Attach`. Attaching a still-draining
    /// GPU cancels the drain server-side and its ack never fires (the
    /// in-process shard drops its ack sender on cancel), so the parked
    /// sender is dropped here too — a waiter blocked on the ack sees
    /// `Disconnected` promptly instead of hanging on a canceled drain.
    pub fn attach(&self, shard: u16, gpu: GpuId) -> Result<(), PortClosed> {
        relock(&self.acks).remove(&gpu.0);
        self.send(shard, &WireToRank::Attach { gpu })
    }

    /// `Granted` frames delivered so far.
    pub fn grants(&self) -> u64 {
        self.grants.load(Ordering::Relaxed)
    }

    /// Begin a clean shutdown: queued frames flush, the write half
    /// closes (the server ends the session on EOF), and the reader's
    /// subsequent EOF is not counted as a disconnect. Idempotent.
    pub fn close(&self) {
        self.closing.store(true, Ordering::SeqCst);
        self.sender.close();
    }

    /// Join the writer and reader threads (after [`RemoteRank::close`]).
    /// The handles are taken out before joining: holding either mutex
    /// across `.join()` would block any concurrent `start_reader` (or a
    /// second `join`) for the whole thread lifetime.
    pub fn join(&self) {
        let writer = relock(&self.writer).take();
        if let Some(h) = writer {
            let _ = h.join();
        }
        let reader = relock(&self.reader).take();
        if let Some(h) = reader {
            let _ = h.join();
        }
    }
}
