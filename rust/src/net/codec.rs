//! Fixed-layout binary codec for the rank-coordination wire protocol.
//!
//! The wire vocabulary mirrors the in-process control traffic between
//! model workers and rank shards ([`crate::coordinator::messages`]):
//! [`WireToRank`] carries the up direction (`Candidate`, `GpuBusyUntil`,
//! `Drain`, `Attach` — `ToRank` minus `Shutdown`, which on the wire is
//! simply closing the connection), and [`WireFromRank`] the down
//! direction (`Granted`, `Revalidate`, `Overflow`, `DrainAck` — the
//! shard-originated `ToModel` verdicts, plus the drain ack that an
//! in-process shard delivers on a `Sender<GpuId>` and a remote shard
//! must deliver as an explicit frame routed back over the connection).
//!
//! Everything is hand-rolled little-endian with one tag byte per
//! message — the offline registry has no serde, the same constraint
//! that produced [`crate::util::error`]. Layouts are *fixed*: every
//! field is always present (a cleared candidate writes zeros behind its
//! `has` flag), so a frame's length is a function of its tag alone and
//! a decoder can reject truncated, oversized, or trailing input without
//! ever reading past the buffer.
//!
//! Up frames are prefixed with the target shard index (`u16`): one
//! connection multiplexes every shard a rank server hosts, so the
//! header — not a per-shard socket — does the routing.
//!
//! The mirror relationship with `coordinator::messages` is enforced by
//! `symphony lint` (the `wire-schema-drift` rule): variant sets, field
//! names, and the presence of an encode *and decode* arm per variant
//! are checked on every CI run, so a variant added on one side cannot
//! silently become a runtime `BadTag` on the other.

use std::fmt;

use crate::coordinator::messages::CandWindow;
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId};

/// Why a buffer failed to decode. Every failure is a clean `Err` — no
/// panic, no over-read — so a malformed or malicious peer can at worst
/// get its session dropped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecError {
    /// Payload shorter than its tag's fixed layout.
    Truncated,
    /// Bytes left over after the fixed layout (length lied).
    Trailing(usize),
    /// Unknown message tag.
    BadTag(u8),
    /// A boolean flag byte that was neither 0 nor 1.
    BadFlag(u8),
    /// Handshake magic mismatch (not a symphony peer).
    BadMagic(u32),
    /// Handshake protocol version mismatch.
    BadVersion(u16),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Truncated => write!(f, "truncated frame"),
            CodecError::Trailing(n) => write!(f, "{n} trailing bytes after fixed layout"),
            CodecError::BadTag(t) => write!(f, "unknown message tag {t}"),
            CodecError::BadFlag(b) => write!(f, "flag byte {b} is not 0/1"),
            CodecError::BadMagic(m) => write!(f, "bad handshake magic {m:#010x}"),
            CodecError::BadVersion(v) => write!(f, "unsupported wire version {v}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// Model worker / autoscaler → rank server. Mirrors
/// [`crate::coordinator::messages::ToRank`]; `Drain` drops the ack
/// sender — the ack comes back as [`WireFromRank::DrainAck`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireToRank {
    Candidate {
        model: ModelId,
        cand: Option<CandWindow>,
        seq: u64,
        hops: u32,
    },
    GpuBusyUntil { gpu: GpuId, free_at: Micros },
    Drain { gpu: GpuId },
    Attach { gpu: GpuId },
}

/// Rank server → model worker / autoscaler. Mirrors the
/// shard-originated half of [`crate::coordinator::messages::ToModel`];
/// `Overflow::to_shard` is the *server-local* shard index (the client
/// re-bases it into its global topology).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFromRank {
    Granted { model: ModelId, gpu: GpuId },
    Revalidate { model: ModelId },
    Overflow {
        model: ModelId,
        to_shard: u16,
        seq: u64,
    },
    DrainAck { gpu: GpuId },
}

const TAG_CANDIDATE: u8 = 1;
const TAG_GPU_BUSY: u8 = 2;
const TAG_DRAIN: u8 = 3;
const TAG_ATTACH: u8 = 4;

const TAG_GRANTED: u8 = 1;
const TAG_REVALIDATE: u8 = 2;
const TAG_OVERFLOW: u8 = 3;
const TAG_DRAIN_ACK: u8 = 4;

/// Bounded cursor: every read checks the remaining length, so a decoder
/// can never index past the buffer, and `done` rejects trailing bytes.
struct Cur<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cur { b, off: 0 }
    }

    fn take<const N: usize>(&mut self) -> Result<[u8; N], CodecError> {
        let end = self.off.checked_add(N).ok_or(CodecError::Truncated)?;
        // `.get`, not a slice index: the decode path must return
        // `Truncated`, never panic, on short input.
        let src = self.b.get(self.off..end).ok_or(CodecError::Truncated)?;
        let mut out = [0u8; N];
        out.copy_from_slice(src);
        self.off = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take::<1>()?[0])
    }

    fn u16(&mut self) -> Result<u16, CodecError> {
        Ok(u16::from_le_bytes(self.take::<2>()?))
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take::<4>()?))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take::<8>()?))
    }

    fn done(&self) -> Result<(), CodecError> {
        if self.off == self.b.len() {
            Ok(())
        } else {
            Err(CodecError::Trailing(self.b.len() - self.off))
        }
    }
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append the up-frame payload `[shard u16][tag u8][fields]` to `out`.
pub fn encode_up(shard: u16, msg: &WireToRank, out: &mut Vec<u8>) {
    put_u16(out, shard);
    match msg {
        WireToRank::Candidate {
            model,
            cand,
            seq,
            hops,
        } => {
            out.push(TAG_CANDIDATE);
            put_u32(out, model.0);
            put_u64(out, *seq);
            put_u32(out, *hops);
            // Fixed layout: the window fields are always present; a
            // cleared candidate writes zeros behind `has = 0`.
            let w = cand.unwrap_or(CandWindow {
                exec: Micros::ZERO,
                latest: Micros::ZERO,
                size: 0,
            });
            out.push(u8::from(cand.is_some()));
            put_u64(out, w.exec.0);
            put_u64(out, w.latest.0);
            put_u32(out, w.size);
        }
        WireToRank::GpuBusyUntil { gpu, free_at } => {
            out.push(TAG_GPU_BUSY);
            put_u32(out, gpu.0);
            put_u64(out, free_at.0);
        }
        WireToRank::Drain { gpu } => {
            out.push(TAG_DRAIN);
            put_u32(out, gpu.0);
        }
        WireToRank::Attach { gpu } => {
            out.push(TAG_ATTACH);
            put_u32(out, gpu.0);
        }
    }
}

/// Decode one up-frame payload into its target shard and message.
pub fn decode_up(buf: &[u8]) -> Result<(u16, WireToRank), CodecError> {
    let mut c = Cur::new(buf);
    let shard = c.u16()?;
    let tag = c.u8()?;
    let msg = match tag {
        TAG_CANDIDATE => {
            let model = ModelId(c.u32()?);
            let seq = c.u64()?;
            let hops = c.u32()?;
            let has = c.u8()?;
            let exec = Micros(c.u64()?);
            let latest = Micros(c.u64()?);
            let size = c.u32()?;
            let cand = match has {
                0 => None,
                1 => Some(CandWindow { exec, latest, size }),
                other => return Err(CodecError::BadFlag(other)),
            };
            WireToRank::Candidate {
                model,
                cand,
                seq,
                hops,
            }
        }
        TAG_GPU_BUSY => WireToRank::GpuBusyUntil {
            gpu: GpuId(c.u32()?),
            free_at: Micros(c.u64()?),
        },
        TAG_DRAIN => WireToRank::Drain { gpu: GpuId(c.u32()?) },
        TAG_ATTACH => WireToRank::Attach { gpu: GpuId(c.u32()?) },
        other => return Err(CodecError::BadTag(other)),
    };
    c.done()?;
    Ok((shard, msg))
}

/// Append the down-frame payload `[tag u8][fields]` to `out`.
pub fn encode_down(msg: &WireFromRank, out: &mut Vec<u8>) {
    match msg {
        WireFromRank::Granted { model, gpu } => {
            out.push(TAG_GRANTED);
            put_u32(out, model.0);
            put_u32(out, gpu.0);
        }
        WireFromRank::Revalidate { model } => {
            out.push(TAG_REVALIDATE);
            put_u32(out, model.0);
        }
        WireFromRank::Overflow {
            model,
            to_shard,
            seq,
        } => {
            out.push(TAG_OVERFLOW);
            put_u32(out, model.0);
            put_u16(out, *to_shard);
            put_u64(out, *seq);
        }
        WireFromRank::DrainAck { gpu } => {
            out.push(TAG_DRAIN_ACK);
            put_u32(out, gpu.0);
        }
    }
}

/// Decode one down-frame payload.
pub fn decode_down(buf: &[u8]) -> Result<WireFromRank, CodecError> {
    let mut c = Cur::new(buf);
    let tag = c.u8()?;
    let msg = match tag {
        TAG_GRANTED => WireFromRank::Granted {
            model: ModelId(c.u32()?),
            gpu: GpuId(c.u32()?),
        },
        TAG_REVALIDATE => WireFromRank::Revalidate {
            model: ModelId(c.u32()?),
        },
        TAG_OVERFLOW => WireFromRank::Overflow {
            model: ModelId(c.u32()?),
            to_shard: c.u16()?,
            seq: c.u64()?,
        },
        TAG_DRAIN_ACK => WireFromRank::DrainAck { gpu: GpuId(c.u32()?) },
        other => return Err(CodecError::BadTag(other)),
    };
    c.done()?;
    Ok(msg)
}

const PREAMBLE_MAGIC: u32 = 0x4B52_5953; // "SYRK"
const HELLO_MAGIC: u32 = 0x4843_5953; // "SYCH"
// Version 2 added the session-epoch pair (preamble `session`, hello
// `epoch`) so a reconnecting client can fence frames from a dead
// session — see the reconnect state machine in `net::client`.
const WIRE_VERSION: u16 = 2;

/// Fixed length of the server preamble on the wire.
pub const PREAMBLE_LEN: usize = 24;
/// Fixed length of the client hello on the wire.
pub const HELLO_LEN: usize = 24;

/// First bytes a rank server writes on every accepted connection: what
/// it hosts, so the client can build its side of the shard topology
/// before any traffic flows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServerPreamble {
    /// Rank shards this server hosts.
    pub shards: u16,
    /// First GPU id this server owns (inclusive).
    pub gpu_lo: u32,
    /// One past the last GPU id this server owns.
    pub gpu_hi: u32,
    /// Server-side session counter (1 on the first accepted session).
    /// A reconnecting client logs the pair (its own epoch, this) so a
    /// recovery can be traced end to end from both sides' output.
    pub session: u64,
}

impl ServerPreamble {
    /// Is `gpu` inside this server's advertised range? Down-frames
    /// naming foreign GPUs are dropped by the client reader.
    pub fn owns(&self, gpu: GpuId) -> bool {
        (self.gpu_lo..self.gpu_hi).contains(&gpu.0)
    }
}

pub fn encode_preamble(p: &ServerPreamble) -> [u8; PREAMBLE_LEN] {
    let mut out = [0u8; PREAMBLE_LEN];
    out[0..4].copy_from_slice(&PREAMBLE_MAGIC.to_le_bytes());
    out[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    out[6..8].copy_from_slice(&p.shards.to_le_bytes());
    out[8..12].copy_from_slice(&p.gpu_lo.to_le_bytes());
    out[12..16].copy_from_slice(&p.gpu_hi.to_le_bytes());
    out[16..24].copy_from_slice(&p.session.to_le_bytes());
    out
}

pub fn decode_preamble(buf: &[u8; PREAMBLE_LEN]) -> Result<ServerPreamble, CodecError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != PREAMBLE_MAGIC {
        return Err(CodecError::BadMagic(magic));
    }
    let version = c.u16()?;
    if version != WIRE_VERSION {
        return Err(CodecError::BadVersion(version));
    }
    Ok(ServerPreamble {
        shards: c.u16()?,
        gpu_lo: c.u32()?,
        gpu_hi: c.u32()?,
        session: c.u64()?,
    })
}

/// The client's reply to the preamble: how many models it will address
/// (sizes the server's down-path routing), its clock reading at send
/// time (the server runs its session shards on the client's clock —
/// see [`crate::coordinator::Clock::starting_at`]), and the client-side
/// session epoch — 0 on first connect, bumped on every reconnect, so
/// down-frames buffered from a dead session can be fenced on delivery.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClientHello {
    pub n_models: u32,
    pub now_us: u64,
    pub epoch: u64,
}

pub fn encode_hello(h: &ClientHello) -> [u8; HELLO_LEN] {
    let mut out = [0u8; HELLO_LEN];
    out[0..4].copy_from_slice(&HELLO_MAGIC.to_le_bytes());
    out[4..8].copy_from_slice(&h.n_models.to_le_bytes());
    out[8..16].copy_from_slice(&h.now_us.to_le_bytes());
    out[16..24].copy_from_slice(&h.epoch.to_le_bytes());
    out
}

pub fn decode_hello(buf: &[u8; HELLO_LEN]) -> Result<ClientHello, CodecError> {
    let mut c = Cur::new(buf);
    let magic = c.u32()?;
    if magic != HELLO_MAGIC {
        return Err(CodecError::BadMagic(magic));
    }
    Ok(ClientHello {
        n_models: c.u32()?,
        now_us: c.u64()?,
        epoch: c.u64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, default_cases};
    use crate::util::rng::Rng;

    fn random_window(rng: &mut Rng) -> CandWindow {
        CandWindow {
            exec: Micros(rng.next_u64()),
            latest: Micros(rng.next_u64()),
            size: rng.next_u64() as u32,
        }
    }

    fn random_up(rng: &mut Rng) -> WireToRank {
        match rng.below(4) {
            0 => WireToRank::Candidate {
                model: ModelId(rng.next_u64() as u32),
                cand: if rng.f64() < 0.25 {
                    None
                } else {
                    Some(random_window(rng))
                },
                seq: rng.next_u64(),
                hops: rng.next_u64() as u32,
            },
            1 => WireToRank::GpuBusyUntil {
                gpu: GpuId(rng.next_u64() as u32),
                free_at: Micros(rng.next_u64()),
            },
            2 => WireToRank::Drain {
                gpu: GpuId(rng.next_u64() as u32),
            },
            _ => WireToRank::Attach {
                gpu: GpuId(rng.next_u64() as u32),
            },
        }
    }

    fn random_down(rng: &mut Rng) -> WireFromRank {
        match rng.below(4) {
            0 => WireFromRank::Granted {
                model: ModelId(rng.next_u64() as u32),
                gpu: GpuId(rng.next_u64() as u32),
            },
            1 => WireFromRank::Revalidate {
                model: ModelId(rng.next_u64() as u32),
            },
            2 => WireFromRank::Overflow {
                model: ModelId(rng.next_u64() as u32),
                to_shard: rng.next_u64() as u16,
                seq: rng.next_u64(),
            },
            _ => WireFromRank::DrainAck {
                gpu: GpuId(rng.next_u64() as u32),
            },
        }
    }

    /// Encode → decode is the identity over randomized messages in both
    /// directions (the codec-robustness satellite's positive half).
    #[test]
    fn prop_roundtrip_identity() {
        check("codec_roundtrip", default_cases(), |rng| {
            let mut buf = Vec::new();
            for _ in 0..32 {
                let shard = rng.next_u64() as u16;
                let up = random_up(rng);
                buf.clear();
                encode_up(shard, &up, &mut buf);
                let (s2, up2) = decode_up(&buf).map_err(|e| format!("{up:?}: {e}"))?;
                if s2 != shard || up2 != up {
                    return Err(format!("up roundtrip {up:?} -> {up2:?}"));
                }
                let down = random_down(rng);
                buf.clear();
                encode_down(&down, &mut buf);
                let down2 = decode_down(&buf).map_err(|e| format!("{down:?}: {e}"))?;
                if down2 != down {
                    return Err(format!("down roundtrip {down:?} -> {down2:?}"));
                }
            }
            Ok(())
        });
    }

    /// Every strict prefix of a valid frame decodes to `Err` — never a
    /// panic, never a wrong message (the truncated-frame satellite).
    #[test]
    fn prop_truncation_is_an_error() {
        check("codec_truncation", default_cases(), |rng| {
            let mut buf = Vec::new();
            let up = random_up(rng);
            encode_up(rng.next_u64() as u16, &up, &mut buf);
            for cut in 0..buf.len() {
                if decode_up(&buf[..cut]).is_ok() {
                    return Err(format!("{up:?} decoded from a {cut}-byte prefix"));
                }
            }
            buf.clear();
            let down = random_down(rng);
            encode_down(&down, &mut buf);
            for cut in 0..buf.len() {
                if decode_down(&buf[..cut]).is_ok() {
                    return Err(format!("{down:?} decoded from a {cut}-byte prefix"));
                }
            }
            Ok(())
        });
    }

    /// Trailing bytes after the fixed layout are rejected: a frame's
    /// length must match its tag exactly.
    #[test]
    fn prop_trailing_bytes_are_an_error() {
        check("codec_trailing", default_cases(), |rng| {
            let mut buf = Vec::new();
            encode_up(0, &random_up(rng), &mut buf);
            buf.push(rng.next_u64() as u8);
            if !matches!(decode_up(&buf), Err(CodecError::Trailing(1))) {
                return Err(format!("trailing byte accepted: {:?}", decode_up(&buf)));
            }
            buf.clear();
            encode_down(&random_down(rng), &mut buf);
            buf.push(rng.next_u64() as u8);
            if !matches!(decode_down(&buf), Err(CodecError::Trailing(1))) {
                return Err(format!("trailing byte accepted: {:?}", decode_down(&buf)));
            }
            Ok(())
        });
    }

    #[test]
    fn corrupt_tag_is_an_error() {
        let mut buf = Vec::new();
        encode_up(3, &WireToRank::Drain { gpu: GpuId(7) }, &mut buf);
        for bad in [0u8, 5, 99, 255] {
            buf[2] = bad; // tag byte sits after the u16 shard prefix
            assert_eq!(decode_up(&buf), Err(CodecError::BadTag(bad)));
        }
        let mut buf = Vec::new();
        encode_down(&WireFromRank::DrainAck { gpu: GpuId(7) }, &mut buf);
        for bad in [0u8, 5, 99, 255] {
            buf[0] = bad;
            assert_eq!(decode_down(&buf), Err(CodecError::BadTag(bad)));
        }
    }

    #[test]
    fn corrupt_candidate_flag_is_an_error() {
        let mut buf = Vec::new();
        encode_up(
            0,
            &WireToRank::Candidate {
                model: ModelId(1),
                cand: Some(CandWindow {
                    exec: Micros(10),
                    latest: Micros(20),
                    size: 4,
                }),
                seq: 9,
                hops: 1,
            },
            &mut buf,
        );
        // The `has` flag sits after shard(2) + tag(1) + model(4) +
        // seq(8) + hops(4).
        buf[19] = 2;
        assert_eq!(decode_up(&buf), Err(CodecError::BadFlag(2)));
    }

    #[test]
    fn empty_input_is_truncated() {
        assert_eq!(decode_up(&[]), Err(CodecError::Truncated));
        assert_eq!(decode_down(&[]), Err(CodecError::Truncated));
    }

    #[test]
    fn handshake_roundtrip_and_validation() {
        let p = ServerPreamble {
            shards: 4,
            gpu_lo: 8,
            gpu_hi: 16,
            session: 3,
        };
        let bytes = encode_preamble(&p);
        assert_eq!(decode_preamble(&bytes).unwrap(), p);
        let mut bad = bytes;
        bad[0] ^= 0xFF;
        assert!(matches!(decode_preamble(&bad), Err(CodecError::BadMagic(_))));
        let mut bad = bytes;
        bad[4] = 0xFF;
        assert!(matches!(decode_preamble(&bad), Err(CodecError::BadVersion(_))));

        let h = ClientHello {
            n_models: 12,
            now_us: 55_555,
            epoch: 7,
        };
        let bytes = encode_hello(&h);
        assert_eq!(decode_hello(&bytes).unwrap(), h);
        let mut bad = bytes;
        bad[1] ^= 0xFF;
        assert!(matches!(decode_hello(&bad), Err(CodecError::BadMagic(_))));
    }

    /// A version-1 (16-byte) handshake against the version-2 decoder:
    /// the length mismatch alone would wedge a naive reader, but the
    /// fixed-length read gets 24 bytes of *something* and the version
    /// field must reject it before the epoch is ever trusted.
    #[test]
    fn old_version_preamble_is_rejected() {
        let p = ServerPreamble {
            shards: 2,
            gpu_lo: 0,
            gpu_hi: 4,
            session: 1,
        };
        let mut bytes = encode_preamble(&p);
        bytes[4..6].copy_from_slice(&1u16.to_le_bytes());
        assert!(matches!(
            decode_preamble(&bytes),
            Err(CodecError::BadVersion(1))
        ));
    }
}
