//! Deterministic fault injection for the wire fabric.
//!
//! A [`FaultPlan`] is a seeded schedule of transport faults — kill the
//! connection after N frames or T µs, tear the fatal frame, stall the
//! writer, fail handshakes — parsed from the `--fault-plan` CLI spec
//! and threaded into [`crate::net::transport::spawn_writer_with`] on
//! either endpoint. The same spec (same seed) produces the same fault
//! schedule on every run, so the chaos tests in `tests/net_wire.rs`
//! and the CI fault-recovery smoke are reproducible, not flaky.
//!
//! Grammar (comma-separated `key=value`, order-insensitive):
//!
//! ```text
//! seed=S                   draw seed (default 0)
//! kill-after-frames=N      kill the session at outbound frame N
//! kill-after-frames=LO..HI ... at a per-session draw from [LO, HI)
//! kill-after-us=T          kill the session T µs after its writer spawns
//! torn                     frame-count kills first write a torn
//!                          (half-length) fatal frame
//! stall-writer-us=T        sleep T µs before every write batch
//!                          (models a saturated peer; fills the
//!                          bounded backlog)
//! fail-handshake=K         fail the first K connection attempts at
//!                          handshake time
//! times=K                  how many sessions the kill fires in
//!                          (default 1; later sessions run clean)
//! ```
//!
//! One plan instance is shared (`Arc`) across every session an endpoint
//! opens; per-session state lives in the [`SessionFaults`] handed to
//! that session's writer. Counters are monotonic and sessions are
//! opened sequentially on both endpoints, so the per-session kill-frame
//! draw is a pure function of (seed, session index).

use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::util::error::Result;
use crate::util::rng::Rng;

/// A seeded, shareable schedule of transport faults. Inert by default:
/// [`FaultPlan::none`] injects nothing and is what every non-chaos code
/// path carries.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    /// Kill at an outbound frame drawn from `[lo, hi)` per session.
    kill_after_frames: Option<(u64, u64)>,
    /// Kill the session this many µs after its writer spawns.
    kill_after_us: Option<u64>,
    /// Frame-count kills write half the fatal frame before dying.
    torn: bool,
    /// Sleep before every write batch, µs.
    stall_writer_us: u64,
    /// Fail this many handshake attempts before letting one through.
    fail_handshake: u64,
    /// Sessions the kill triggers fire in before the plan goes inert.
    times: u64,
    /// Sessions opened under this plan (drives the per-session draw).
    sessions: AtomicU64,
    /// Kill faults that have fired (bounded by `times`).
    fired: AtomicU64,
    /// Handshake attempts observed (drives `fail-handshake`).
    handshakes: AtomicU64,
}

impl FaultPlan {
    /// A plan that injects nothing.
    pub fn none() -> Arc<FaultPlan> {
        Arc::new(FaultPlan {
            seed: 0,
            kill_after_frames: None,
            kill_after_us: None,
            torn: false,
            stall_writer_us: 0,
            fail_handshake: 0,
            times: 1,
            sessions: AtomicU64::new(0),
            fired: AtomicU64::new(0),
            handshakes: AtomicU64::new(0),
        })
    }

    /// Parse the `--fault-plan` grammar (see module docs).
    pub fn parse(spec: &str) -> Result<Arc<FaultPlan>> {
        let mut plan = FaultPlan {
            seed: 0,
            kill_after_frames: None,
            kill_after_us: None,
            torn: false,
            stall_writer_us: 0,
            fail_handshake: 0,
            times: 1,
            sessions: AtomicU64::new(0),
            fired: AtomicU64::new(0),
            handshakes: AtomicU64::new(0),
        };
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = match part.split_once('=') {
                Some((k, v)) => (k, Some(v)),
                None => (part, None),
            };
            let num = |what: &str| -> Result<u64> {
                match val {
                    Some(v) => v
                        .parse::<u64>()
                        .map_err(|_| err(format!("fault-plan: {what} wants a number, got `{v}`"))),
                    None => Err(err(format!("fault-plan: {what} wants `{what}=N`"))),
                }
            };
            match key {
                "seed" => plan.seed = num("seed")?,
                "kill-after-frames" => {
                    let v = val
                        .ok_or_else(|| err("fault-plan: kill-after-frames wants `=N` or `=LO..HI`"))?;
                    plan.kill_after_frames = Some(parse_range(v)?);
                }
                "kill-after-us" => plan.kill_after_us = Some(num("kill-after-us")?),
                "torn" => plan.torn = true,
                "stall-writer-us" => plan.stall_writer_us = num("stall-writer-us")?,
                "fail-handshake" => plan.fail_handshake = num("fail-handshake")?,
                "times" => plan.times = num("times")?,
                other => crate::bail!("fault-plan: unknown key `{other}`"),
            }
        }
        if plan.torn && plan.kill_after_frames.is_none() {
            crate::bail!("fault-plan: `torn` needs `kill-after-frames` as its trigger");
        }
        Ok(Arc::new(plan))
    }

    /// True when the plan can still inject something (lets callers skip
    /// spawning killer threads for inert plans).
    pub fn is_active(&self) -> bool {
        self.kill_after_frames.is_some()
            || self.kill_after_us.is_some()
            || self.stall_writer_us > 0
            || self.fail_handshake > 0
    }

    /// Should this handshake attempt be failed? Deterministic: the
    /// first `fail-handshake=K` calls return true.
    pub fn fail_this_handshake(&self) -> bool {
        if self.fail_handshake == 0 {
            return false;
        }
        // relaxed: a monotonic test-only counter; no data is published
        // under it.
        self.handshakes.fetch_add(1, Ordering::Relaxed) < self.fail_handshake
    }

    /// Open a session under this plan: draws the session's kill frame
    /// (a pure function of seed and session index) and hands back the
    /// per-session fault state for its writer.
    pub fn session(self: &Arc<Self>) -> SessionFaults {
        // relaxed: a monotonic session counter; the draw below only
        // needs a unique index, not ordering against other memory.
        let idx = self.sessions.fetch_add(1, Ordering::Relaxed);
        let kill_at_frame = self.kill_after_frames.map(|(lo, hi)| {
            if hi > lo.saturating_add(1) {
                lo + Rng::new(self.seed).fork(idx).next_u64() % (hi - lo)
            } else {
                lo
            }
        });
        SessionFaults {
            plan: self.clone(),
            kill_at_frame,
            frames: 0,
        }
    }

    /// Claim one of the `times` kill slots. The frame-count and timed
    /// triggers share the budget, so `times=1` means exactly one kill
    /// however it is delivered.
    fn try_fire(&self) -> bool {
        // relaxed: a bounded claim counter; the kill acts on the socket,
        // not on memory this counter publishes.
        self.fired
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |f| {
                (f < self.times).then_some(f + 1)
            })
            .is_ok()
    }

    /// Spawn the timed killer for a session whose writer just started:
    /// after `kill-after-us`, shut the stream down both ways (the peer
    /// sees a hard drop; the local reader unblocks). No-op for plans
    /// without a timed kill.
    pub fn spawn_timed_killer(self: &Arc<Self>, stream: &TcpStream) -> Option<thread::JoinHandle<()>> {
        let delay = self.kill_after_us?;
        let Ok(stream) = stream.try_clone() else {
            return None;
        };
        let plan = self.clone();
        thread::Builder::new()
            .name("fault-timed-kill".into())
            .spawn(move || {
                thread::sleep(Duration::from_micros(delay));
                if plan.try_fire() {
                    let _ = stream.shutdown(Shutdown::Both);
                }
            })
            .ok()
    }
}

fn err(msg: impl std::fmt::Display) -> crate::util::error::Error {
    crate::util::error::Error::msg(msg)
}

fn parse_range(v: &str) -> Result<(u64, u64)> {
    if let Some((lo, hi)) = v.split_once("..") {
        let lo: u64 = lo
            .parse()
            .map_err(|_| err(format!("fault-plan: bad range start `{lo}`")))?;
        let hi: u64 = hi
            .parse()
            .map_err(|_| err(format!("fault-plan: bad range end `{hi}`")))?;
        if hi <= lo {
            crate::bail!("fault-plan: empty range {lo}..{hi}");
        }
        Ok((lo, hi))
    } else {
        let n: u64 = v
            .parse()
            .map_err(|_| err(format!("fault-plan: bad frame count `{v}`")))?;
        Ok((n, n + 1))
    }
}

/// What the writer should do with the next batch of frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Admit {
    /// Frames of the batch that may go out whole.
    pub allowed: usize,
    /// Kill the session after writing the allowed prefix.
    pub kill: bool,
    /// On kill, also write half of the first disallowed frame (a torn
    /// frame: the peer's reader must surface it as an error, not hang
    /// or mis-parse).
    pub torn: bool,
}

/// Per-session fault state, owned by the session's writer thread.
#[derive(Debug)]
pub struct SessionFaults {
    plan: Arc<FaultPlan>,
    kill_at_frame: Option<u64>,
    frames: u64,
}

impl SessionFaults {
    /// µs to stall before each write batch (0 = none).
    pub fn stall_us(&self) -> u64 {
        self.plan.stall_writer_us
    }

    /// Whether kills tear the fatal frame.
    pub fn torn(&self) -> bool {
        self.plan.torn
    }

    /// Account a batch of `n` outbound frames and decide how much of it
    /// survives. Frame indices are 0-based and monotonic across the
    /// session, so the same plan admits the same prefixes every run.
    pub fn admit(&mut self, n: usize) -> Admit {
        let clean = Admit {
            allowed: n,
            kill: false,
            torn: false,
        };
        let Some(kill_at) = self.kill_at_frame else {
            self.frames += n as u64;
            return clean;
        };
        let start = self.frames;
        self.frames += n as u64;
        if start + n as u64 <= kill_at {
            return clean;
        }
        // The trigger frame falls inside this batch; the kill budget
        // decides whether it actually fires (`times=` sessions).
        if !self.plan.try_fire() {
            self.kill_at_frame = None;
            return clean;
        }
        Admit {
            allowed: kill_at.saturating_sub(start) as usize,
            kill: true,
            torn: self.plan.torn,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse(
            "seed=7,kill-after-frames=100..200,torn,stall-writer-us=50,fail-handshake=2,times=3",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.kill_after_frames, Some((100, 200)));
        assert!(p.torn);
        assert_eq!(p.stall_writer_us, 50);
        assert_eq!(p.fail_handshake, 2);
        assert_eq!(p.times, 3);
        assert!(p.is_active());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("bogus-key=1").is_err());
        assert!(FaultPlan::parse("kill-after-frames=abc").is_err());
        assert!(FaultPlan::parse("kill-after-frames=9..3").is_err());
        assert!(FaultPlan::parse("torn").is_err(), "torn without a trigger");
        assert!(FaultPlan::parse("seed").is_err(), "seed without a value");
    }

    #[test]
    fn empty_spec_is_inert() {
        let p = FaultPlan::parse("").unwrap();
        assert!(!p.is_active());
        assert_eq!(p.session().admit(1_000_000).allowed, 1_000_000);
    }

    /// Same seed ⇒ same per-session kill frames (the determinism
    /// acceptance criterion, at the unit level).
    #[test]
    fn kill_frame_draw_is_deterministic() {
        let draws = |spec: &str| -> Vec<Option<u64>> {
            let p = FaultPlan::parse(spec).unwrap();
            (0..4).map(|_| p.session().kill_at_frame).collect()
        };
        let a = draws("seed=42,kill-after-frames=10..1000,times=4");
        let b = draws("seed=42,kill-after-frames=10..1000,times=4");
        assert_eq!(a, b);
        for d in &a {
            let d = d.unwrap();
            assert!((10..1000).contains(&d), "draw {d} outside range");
        }
        let c = draws("seed=43,kill-after-frames=10..1000,times=4");
        assert_ne!(a, c, "different seeds should differ somewhere");
    }

    /// `times=` bounds kills across sessions: the first session's
    /// trigger fires, later ones run clean.
    #[test]
    fn kill_budget_is_shared_across_sessions() {
        let p = FaultPlan::parse("kill-after-frames=5,times=1").unwrap();
        let mut s1 = p.session();
        let first = s1.admit(10);
        assert_eq!(
            first,
            Admit {
                allowed: 5,
                kill: true,
                torn: false
            }
        );
        let mut s2 = p.session();
        assert_eq!(s2.admit(10).allowed, 10, "budget spent: session 2 clean");
        assert!(!s2.admit(10).kill);
    }

    /// The trigger lands mid-batch and the admitted prefix is exact.
    #[test]
    fn admit_splits_batches_at_the_trigger() {
        let p = FaultPlan::parse("kill-after-frames=7,torn").unwrap();
        let mut s = p.session();
        assert_eq!(s.admit(3).allowed, 3);
        assert_eq!(s.admit(3).allowed, 3);
        let last = s.admit(3);
        assert_eq!(last.allowed, 1, "frames 6 allowed, 7 killed");
        assert!(last.kill);
        assert!(last.torn);
    }

    #[test]
    fn handshake_failures_are_counted_down() {
        let p = FaultPlan::parse("fail-handshake=2").unwrap();
        assert!(p.fail_this_handshake());
        assert!(p.fail_this_handshake());
        assert!(!p.fail_this_handshake());
        assert!(!p.fail_this_handshake());
    }
}
