//! Distributed rank coordination over a real wire.
//!
//! Everything below `net/` exists so the rank tier — the batch-rate
//! matchmaking half of the coordinator — can leave the process: the
//! paper's scheduler coordinates *thousands of GPUs*, and a reproduction
//! whose tiers are all `std::sync::mpsc` channels can never leave one
//! machine. The stack, bottom up:
//!
//! * [`codec`] — fixed-layout binary messages ([`codec::WireToRank`] /
//!   [`codec::WireFromRank`]) mirroring the in-process `ToRank` /
//!   `ToModel` control traffic, plus the connect handshake
//!   (preamble/hello). Hand-rolled little-endian: the offline registry
//!   has no serde, the same constraint behind `util::error`.
//! * [`transport`] — length-prefixed framed TCP with `TCP_NODELAY`, a
//!   bounded-length reader, and a write side that coalesces the queued
//!   backlog into one syscall per drain (the wire analogue of
//!   `RankShard::InboxBatch`).
//! * [`server`] — `symphony rank-server`: hosts real
//!   [`crate::coordinator::RankShard`]s in their own process, one shard
//!   set per client session, in the client's clock domain.
//! * [`client`] — [`client::RemoteRank`]: the coordinator side of a
//!   connection, plugged into the model workers through
//!   [`crate::coordinator::router::RankPort`] so routing, overflow
//!   steering, and the drain/attach autoscaler protocol are
//!   transport-agnostic (`serve --remote-ranks host:port,...`).
//!
//! `benches/bench_wire.rs` sweeps frames/s and loopback submit→grant
//! round-trip latency into `BENCH_wire.json`; EXPERIMENTS.md §Wire
//! coordination has the run instructions.

pub mod client;
pub mod codec;
pub mod faults;
pub mod server;
pub mod transport;
