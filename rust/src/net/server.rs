//! `symphony rank-server` — real [`crate::coordinator::RankShard`]s in
//! their own process, behind the framed wire.
//!
//! The server owns a contiguous GPU id range and hosts `R` rank shards
//! over it. Shard state is **per session**: when a client connects (a
//! `serve --remote-ranks` coordinator), the handshake tells the server
//! how many models the client addresses and what the client's clock
//! reads, and the server spawns `R` fresh shard threads in that clock
//! domain; when the connection ends — the client's clean shutdown or
//! any disconnect — the shards are shut down, joined, and their stats
//! logged. That matches the deployment model: the backends executing
//! the batches live in the *client* process, so GPU busy/free state is
//! meaningful only within one serving session. Concurrent sessions get
//! independent shard sets (useful for tests; a production deployment
//! runs one serving tier per server).
//!
//! Per session, the plumbing mirrors the in-process coordinator:
//!
//! * the session reader decodes up-frames and forwards them to the
//!   owning shard's ring inbox (per-connection ordering ⇒ per-shard
//!   ordering, same as an in-process sender);
//! * the shards' `model_txs` are clones of one proxy ring whose
//!   converter thread encodes `Granted`/`Revalidate`/`Overflow` into
//!   down-frames (every `ToModel` verdict is model-addressed, so one
//!   ring serves all models);
//! * `Drain` frames get a session-local ack channel whose converter
//!   thread turns each ack into an explicit `DrainAck` frame — the
//!   in-process `Sender<GpuId>` contract, routed back over the wire.
//!
//! Overflow steering stays server-local: the session's `FreeHints`
//! cover only this server's shards, so a verdict's `to_shard` is a
//! server-local index the client re-bases (cross-server hint gossip is
//! future work, tracked in the ROADMAP).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::messages::{ToModel, ToRank};
use crate::coordinator::router::FreeHints;
use crate::coordinator::{
    Clock, RankShard, ShardLive, ShardStats, ShardTopology, MODEL_RING_DEPTH, RANK_RING_DEPTH,
};
use crate::core::time::Micros;
use crate::core::types::GpuId;
use crate::net::codec::{self, ServerPreamble, WireFromRank, WireToRank, HELLO_LEN};
use crate::net::faults::FaultPlan;
use crate::net::transport::{spawn_writer_with, FrameReader, FrameSender};
use crate::obs::http;
use crate::obs::prom::Prom;
use crate::util::affinity::{self, CorePlan};
use crate::util::error::{Context, Result};
use crate::util::ring::{ring, RingReceiver};
use crate::util::sync::relock;
use crate::{log_error, log_info};

/// Most models one session may address (the hello's `n_models` sizes
/// per-shard sender tables, so this wire-supplied number must be
/// bounded; ~16 MB of senders per shard at the cap — far beyond any
/// real model zoo, far below an OOM).
pub const MAX_SESSION_MODELS: usize = 1 << 20;

/// What one rank server hosts.
#[derive(Clone, Debug)]
pub struct RankServerConfig {
    /// Listen address, e.g. `127.0.0.1:7811` (`:0` for an ephemeral
    /// port — see [`RankServer::local_addr`]).
    pub listen: String,
    /// Rank shards over the owned GPU range (clamped to the range
    /// length).
    pub shards: usize,
    /// Owned GPU id range; a multi-server tier partitions the id space
    /// across servers the way shards partition it within one.
    pub gpus: std::ops::Range<u32>,
    /// Exit after this many sessions (CI smoke / tests); `None` serves
    /// forever.
    pub max_sessions: Option<u64>,
    /// Keep session shard drains spinning instead of parking
    /// (`--busy-poll`); see [`crate::coordinator::CoordinatorConfig`].
    pub busy_poll: bool,
    /// Pin session shard threads round-robin onto the host's cores in
    /// NUMA order (`--pin-cores`); no-op off Linux.
    pub pin_cores: bool,
    /// Deterministic wire fault injection for this server's sessions
    /// ([`FaultPlan::parse`] grammar; `--fault-plan` on the CLI).
    /// [`FaultPlan::none`] — the default — injects nothing. This is how
    /// CI kills a live session mid-run to exercise the client's
    /// reconnect path without OS-level tricks.
    pub fault_plan: Arc<FaultPlan>,
    /// Serve Prometheus text exposition on this address
    /// (`--metrics-listen ADDR`); `None` (the default) runs no
    /// listener.
    pub metrics_listen: Option<String>,
}

/// Scrape-visible server-side counters, shared by the accept loop,
/// every live session (which registers its shards' [`ShardLive`]), and
/// the `/metrics` listener. Closed sessions fold their final
/// [`ShardStats`] into the cumulative counters so the exposed totals
/// are monotone across session churn.
#[derive(Default)]
pub struct ServerMetrics {
    /// Sessions accepted over the server's lifetime.
    sessions: AtomicU64,
    /// Sessions whose hello carried a bumped epoch — i.e. successful
    /// client reconnects, as observed server-side.
    reconnected_sessions: AtomicU64,
    /// Grants / mis-steers from already-closed sessions.
    closed_grants: AtomicU64,
    closed_mis_steers: AtomicU64,
    /// Per-shard live counters of open sessions, keyed by session id.
    live: Mutex<Vec<(u64, Vec<Arc<ShardLive>>)>>,
}

impl ServerMetrics {
    fn adopt(&self, session: u64, shards: Vec<Arc<ShardLive>>) {
        relock(&self.live).push((session, shards));
    }

    /// Session teardown: swap the live counters for the authoritative
    /// end-of-run stats.
    fn fold(&self, session: u64, stats: &ShardStats) {
        relock(&self.live).retain(|(s, _)| *s != session);
        self.closed_grants.fetch_add(stats.grants, Ordering::Relaxed);
        self.closed_mis_steers
            .fetch_add(stats.mis_steers, Ordering::Relaxed);
    }

    pub fn sessions(&self) -> u64 {
        self.sessions.load(Ordering::Relaxed)
    }

    pub fn reconnected_sessions(&self) -> u64 {
        self.reconnected_sessions.load(Ordering::Relaxed)
    }

    pub fn grants(&self) -> u64 {
        let live: u64 = relock(&self.live)
            .iter()
            .flat_map(|(_, shards)| shards.iter())
            .map(|s| s.grants())
            .sum();
        self.closed_grants.load(Ordering::Relaxed) + live
    }

    pub fn mis_steers(&self) -> u64 {
        let live: u64 = relock(&self.live)
            .iter()
            .flat_map(|(_, shards)| shards.iter())
            .map(|s| s.mis_steers())
            .sum();
        self.closed_mis_steers.load(Ordering::Relaxed) + live
    }

    /// The server's Prometheus exposition page.
    pub fn render(&self) -> String {
        let mut p = Prom::new();
        p.family(
            "symphony_server_sessions_total",
            "counter",
            "Sessions accepted over the server's lifetime.",
        );
        p.sample("symphony_server_sessions_total", &[], self.sessions());
        p.family(
            "symphony_server_reconnected_sessions_total",
            "counter",
            "Accepted sessions whose hello carried a bumped client epoch (reconnects).",
        );
        p.sample(
            "symphony_server_reconnected_sessions_total",
            &[],
            self.reconnected_sessions(),
        );
        p.family(
            "symphony_server_grants_total",
            "counter",
            "GPU grants issued across all sessions (live + closed).",
        );
        p.sample("symphony_server_grants_total", &[], self.grants());
        p.family(
            "symphony_server_mis_steers_total",
            "counter",
            "Overflow-routed candidates that arrived on a stale free hint.",
        );
        p.sample("symphony_server_mis_steers_total", &[], self.mis_steers());
        p.finish()
    }
}

/// A bound rank server (bind and accept are split so callers can learn
/// an ephemeral port before blocking in [`RankServer::run`]).
pub struct RankServer {
    listener: TcpListener,
    cfg: RankServerConfig,
}

impl RankServer {
    pub fn bind(cfg: RankServerConfig) -> Result<Self> {
        if cfg.gpus.is_empty() {
            crate::bail!("rank-server owns an empty GPU range {:?}", cfg.gpus);
        }
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding rank-server on {}", cfg.listen))?;
        Ok(RankServer { listener, cfg })
    }

    pub fn local_addr(&self) -> SocketAddr {
        // lint:allow(panic-free-wire-surface): queries our own bound
        // listener, not peer input; failure here is an OS-level fault.
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Shards actually hosted (config clamped to the GPU range).
    pub fn num_shards(&self) -> usize {
        self.cfg.shards.clamp(1, self.cfg.gpus.len())
    }

    /// Accept sessions until `max_sessions` (or forever). Each session
    /// runs on its own thread; a session failure is logged, never
    /// fatal to the server.
    pub fn run(self) -> Result<()> {
        let shards = self.num_shards();
        log_info!(
            "rank-server: {} shards over GPUs {}..{} listening on {}",
            shards,
            self.cfg.gpus.start,
            self.cfg.gpus.end,
            self.local_addr()
        );
        let metrics = Arc::new(ServerMetrics::default());
        // The `/metrics` listener lives exactly as long as the accept
        // loop: dropping the guard at return unblocks its thread.
        let _metrics_srv = match &self.cfg.metrics_listen {
            Some(addr) => {
                let m = metrics.clone();
                let srv = http::spawn(addr, Arc::new(move || m.render()))
                    .with_context(|| format!("binding metrics listener on {addr}"))?;
                log_info!("rank-server: metrics on http://{}/metrics", srv.addr());
                Some(srv)
            }
            None => None,
        };
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut accepted = 0u64;
        for stream in self.listener.incoming() {
            // Per-connection accept errors (ECONNABORTED — the peer
            // RST before accept —, fd pressure) must not take down a
            // forever-serving process and its healthy sessions: log
            // and keep accepting.
            let stream = match stream {
                Ok(s) => s,
                Err(e) => {
                    log_error!("rank-server: accept failed: {e}");
                    continue;
                }
            };
            // Reap finished sessions as we go: a forever-serving
            // process (`max_sessions: None`) must not accumulate one
            // handle per connection it ever saw.
            handles.retain(|h| !h.is_finished());
            accepted += 1;
            // `accepted` doubles as the server-side session counter the
            // preamble advertises (1 on the first accepted session).
            let session = accepted;
            metrics.sessions.fetch_add(1, Ordering::Relaxed);
            let gpus = self.cfg.gpus.clone();
            let (busy_poll, pin_cores) = (self.cfg.busy_poll, self.cfg.pin_cores);
            let faults = self.cfg.fault_plan.clone();
            let session_metrics = metrics.clone();
            handles.push(std::thread::Builder::new().name("rank-session".into()).spawn(
                move || {
                    if let Err(e) = serve_session(
                        stream,
                        session,
                        shards,
                        gpus,
                        busy_poll,
                        pin_cores,
                        faults,
                        session_metrics,
                    ) {
                        log_error!("rank-server: session failed: {e:#}");
                    }
                },
            )?);
            if Some(accepted) == self.cfg.max_sessions {
                break;
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Server-local shard bounds over `gpus` — delegates to the one shared
/// split formula ([`ShardTopology::split`]) the client reconstructs
/// the topology with; the two must agree byte for byte or GPU routing
/// silently lands on the wrong shard.
fn shard_range(gpus: &std::ops::Range<u32>, shards: usize, s: usize) -> std::ops::Range<u32> {
    ShardTopology::split(gpus, shards, s)..ShardTopology::split(gpus, shards, s + 1)
}

#[allow(clippy::too_many_arguments)]
fn serve_session(
    stream: TcpStream,
    session: u64,
    shards: usize,
    gpus: std::ops::Range<u32>,
    busy_poll: bool,
    pin_cores: bool,
    faults: Arc<FaultPlan>,
    metrics: Arc<ServerMetrics>,
) -> Result<()> {
    stream.set_nodelay(true)?;
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown>".into());
    // Injected handshake failure: drop the connection before the
    // preamble, exactly what a server dying mid-accept looks like to
    // the client's dialer.
    if faults.fail_this_handshake() {
        crate::bail!("{peer}: fault-plan: injected handshake failure");
    }

    // Handshake: advertise what we host, learn the client's model
    // count and clock. A peer that stalls mid-handshake is dropped
    // after the timeout instead of pinning the session thread.
    (&stream).write_all(&codec::encode_preamble(&ServerPreamble {
        shards: shards as u16,
        gpu_lo: gpus.start,
        gpu_hi: gpus.end,
        session,
    }))?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut hello = [0u8; HELLO_LEN];
    (&stream)
        .read_exact(&mut hello)
        .with_context(|| format!("reading hello from {peer}"))?;
    let hello = codec::decode_hello(&hello).with_context(|| format!("handshake with {peer}"))?;
    stream.set_read_timeout(None)?;
    let n_models = hello.n_models as usize;
    // The hello is wire data: cap it before it sizes any allocation
    // (n_models senders per shard), so a corrupt or hostile hello
    // fails this session instead of OOMing the whole server.
    if n_models > MAX_SESSION_MODELS {
        crate::bail!(
            "{peer}: hello claims {n_models} models (cap {MAX_SESSION_MODELS})"
        );
    }
    // Session shards run in the client's clock domain (offset by the
    // hello's one-way latency — budgeted by the client's net_bound).
    let clock = Clock::starting_at(Micros(hello.now_us));
    if hello.epoch > 0 {
        metrics.reconnected_sessions.fetch_add(1, Ordering::Relaxed);
        log_info!(
            "rank-server: {peer} reconnected (client epoch {}, server session {session})",
            hello.epoch
        );
    }

    // Arm this session's fault schedule (deterministic per seed and
    // session index) and the timed killer, if the plan has one.
    let session_faults = faults.session();
    let _ = faults.spawn_timed_killer(&stream);

    // Down path: coalescing writer + converter threads turning shard
    // verdicts and drain acks into frames. The verdict proxy is a ring
    // (it sits on the grant hot path); the drain-ack channel stays
    // mpsc — one-shot control-rate traffic behind the Sender<GpuId>
    // ack contract.
    let (sender, writer_h) = spawn_writer_with(stream.try_clone()?, Some(session_faults))?;
    let (model_tx, model_rx) = ring::<ToModel>(MODEL_RING_DEPTH);
    model_rx.set_busy_poll(busy_poll);
    let model_conv = {
        let sender = sender.clone();
        std::thread::spawn(move || down_pump(model_rx, sender))
    };
    let (gack_tx, gack_rx) = channel::<GpuId>();
    let ack_conv = {
        let sender = sender.clone();
        std::thread::spawn(move || ack_pump(gack_rx, sender))
    };

    // The session's rank shards: real `RankShard`s, fully attached
    // (a client that wants headroom drains it — a drain of a free GPU
    // retires it immediately, exactly `initial_gpus` semantics).
    let hints = FreeHints::new(shards);
    let mut cores = if pin_cores {
        CorePlan::detect()
    } else {
        CorePlan::disabled()
    };
    let mut shard_txs = Vec::with_capacity(shards);
    let mut shard_handles = Vec::with_capacity(shards);
    let mut shard_live = Vec::with_capacity(shards);
    for s in 0..shards {
        let (tx, rx) = ring::<ToRank>(RANK_RING_DEPTH);
        rx.set_busy_poll(busy_poll);
        shard_txs.push(tx);
        let range = shard_range(&gpus, shards, s);
        let live = Arc::new(ShardLive::default());
        shard_live.push(live.clone());
        let shard = RankShard {
            clock,
            shard: s,
            inbox: rx,
            model_txs: vec![model_tx.clone(); n_models],
            active: range.clone(),
            gpus: range,
            hints: hints.clone(),
            live,
        };
        let core = cores.assign();
        shard_handles.push(
            std::thread::Builder::new()
                .name(format!("rank-srv-shard-{s}"))
                .spawn(move || {
                    affinity::pin(core);
                    shard.run()
                })?,
        );
    }
    // From here the session is scrape-visible: its shard counters show
    // up in `/metrics` totals until `fold` swaps them for final stats.
    metrics.adopt(session, shard_live);

    // Up path: this thread is the session reader. A protocol violation
    // (bad frame, out-of-range shard/model/GPU) kills the session — a
    // confused client must not corrupt shard state.
    let mut frames_in = 0u64;
    let mut reader = FrameReader::new(stream.try_clone()?);
    let end: Result<()> = loop {
        let frame = match reader.next_frame() {
            Ok(Some(f)) => f,
            Ok(None) => break Ok(()), // client closed: normal end of session
            Err(e) => break Err(e.into()),
        };
        frames_in += 1;
        match codec::decode_up(frame) {
            Ok((shard, msg)) => {
                let shard = shard as usize;
                // `shard` is wire data: `.get`, never index.
                let Some(shard_tx) = shard_txs.get(shard) else {
                    break Err(crate::util::error::Error::msg(format!(
                        "{peer}: frame for shard {shard} of {}",
                        shard_txs.len()
                    )));
                };
                match validate(&msg, n_models, &gpus) {
                    Ok(()) => {
                        let to_rank = lift(msg, &gack_tx);
                        if shard_tx.send(to_rank).is_err() {
                            break Err(crate::util::error::Error::msg(format!(
                                "shard {shard} exited mid-session"
                            )));
                        }
                    }
                    Err(why) => {
                        break Err(crate::util::error::Error::msg(format!("{peer}: {why}")))
                    }
                }
            }
            Err(e) => {
                break Err(crate::util::error::Error::msg(format!(
                    "{peer}: bad frame: {e}"
                )))
            }
        }
    };

    // Teardown in dependency order: shards first (they hold model_tx
    // clones), then the converters' inbound channels disconnect, then
    // the writer flushes and closes.
    for tx in &shard_txs {
        let _ = tx.send(ToRank::Shutdown);
    }
    let mut stats = ShardStats::new();
    for h in shard_handles {
        if let Ok(s) = h.join() {
            stats.merge(&s);
        }
    }
    drop(model_tx);
    drop(gack_tx);
    let _ = model_conv.join();
    let _ = ack_conv.join();
    drop(sender);
    let _ = writer_h.join();
    metrics.fold(session, &stats);
    log_info!(
        "rank-server: session {peer} closed: frames_in={frames_in} grants={} \
         mis_steers={} p99_grant_latency_us={}",
        stats.grants,
        stats.mis_steers,
        stats.p99_grant_latency_us()
    );
    end
}

/// Bounds-check an up-message against what this session hosts.
fn validate(msg: &WireToRank, n_models: usize, gpus: &std::ops::Range<u32>) -> Result<(), String> {
    match msg {
        WireToRank::Candidate { model, .. } => {
            if model.0 as usize >= n_models {
                return Err(format!("candidate for model {} of {n_models}", model.0));
            }
        }
        WireToRank::GpuBusyUntil { gpu, .. }
        | WireToRank::Drain { gpu }
        | WireToRank::Attach { gpu } => {
            if !gpus.contains(&gpu.0) {
                return Err(format!("message for GPU {} outside {gpus:?}", gpu.0));
            }
        }
    }
    Ok(())
}

/// Wire message → in-process message (a `Drain` borrows the session's
/// ack channel; its ack returns as a `DrainAck` frame).
fn lift(msg: WireToRank, gack_tx: &Sender<GpuId>) -> ToRank {
    match msg {
        WireToRank::Candidate {
            model,
            cand,
            seq,
            hops,
        } => ToRank::Candidate {
            model,
            cand,
            seq,
            hops,
        },
        WireToRank::GpuBusyUntil { gpu, free_at } => ToRank::GpuBusyUntil { gpu, free_at },
        WireToRank::Drain { gpu } => ToRank::Drain {
            gpu,
            ack: gack_tx.clone(),
        },
        WireToRank::Attach { gpu } => ToRank::Attach { gpu },
    }
}

/// Shard verdicts → down-frames. Only the shard-originated `ToModel`
/// variants can appear here; anything else is a wiring bug. One
/// exactly-sized allocation per frame, moved straight into the writer
/// queue (the queue owns its frames, so a reused scratch would pay the
/// same allocation again on clone).
fn down_pump(rx: RingReceiver<ToModel>, sender: FrameSender) {
    while let Ok(msg) = rx.recv() {
        let down = match msg {
            ToModel::Granted { model, gpu } => WireFromRank::Granted { model, gpu },
            ToModel::Revalidate { model } => WireFromRank::Revalidate { model },
            ToModel::Overflow {
                model,
                to_shard,
                seq,
            } => {
                debug_assert!(to_shard <= u16::MAX as usize, "local shard index fits u16");
                WireFromRank::Overflow {
                    model,
                    to_shard: to_shard as u16,
                    seq,
                }
            }
            other => {
                debug_assert!(false, "non-verdict {other:?} on the server down path");
                continue;
            }
        };
        let mut buf = Vec::with_capacity(16);
        codec::encode_down(&down, &mut buf);
        if sender.send(buf).is_err() {
            break;
        }
    }
}

/// Drain acks → `DrainAck` frames.
fn ack_pump(rx: Receiver<GpuId>, sender: FrameSender) {
    for gpu in rx {
        let mut buf = Vec::with_capacity(8);
        codec::encode_down(&WireFromRank::DrainAck { gpu }, &mut buf);
        if sender.send(buf).is_err() {
            break;
        }
    }
}
