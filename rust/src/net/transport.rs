//! Length-prefixed framed TCP transport for the rank-coordination wire.
//!
//! A frame on the wire is `[len: u32 LE][payload]` where the payload is
//! one [`crate::net::codec`] message. Two pieces:
//!
//! * [`FrameReader`] — a blocking per-connection reader: reads one
//!   frame at a time into a reused buffer, distinguishes clean EOF (at
//!   a frame boundary) from a torn frame, and rejects zero-length or
//!   oversized lengths **before** allocating or reading the payload, so
//!   a corrupt length prefix cannot make the reader balloon or stall.
//! * [`spawn_writer`] — the write side, the wire analogue of
//!   `RankShard::InboxBatch`: senders enqueue encoded payloads into a
//!   shared queue; the writer thread swaps the *entire* backlog out
//!   under one lock, prefixes every frame into one contiguous buffer,
//!   and ships the batch with a single `write_all` — one syscall per
//!   drain no matter how many frames queued behind it. `TCP_NODELAY`
//!   is set by both peers, so latency when the queue is shallow comes
//!   from the wire, not from Nagle.
//!
//! Like everything under `net/`, std-only by construction.

use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::net::faults::{Admit, SessionFaults};
use crate::util::ring::Waiter;
use crate::util::sync::relock;

/// Maximum accepted frame payload length. Codec frames are tens of
/// bytes; anything near this bound is a corrupt prefix or a foreign
/// protocol, rejected without reading the claimed payload.
pub const MAX_FRAME: usize = 4096;

/// Backlog cap, in frames, for a writer queue (see [`SendFail`]).
pub const MAX_BACKLOG_FRAMES: usize = 1 << 16;
/// Backlog cap, in payload bytes, for a writer queue.
pub const MAX_BACKLOG_BYTES: usize = 8 << 20;

/// Why a frame was not enqueued.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendFail {
    /// The peer (or the writer thread) is gone.
    Closed,
    /// The backlog cap was hit: the peer has stalled long enough that
    /// queuing more would only grow memory without bound, so *this*
    /// send killed the session (queue closed, socket shut down). The
    /// caller should count it as a backlog-overflow disconnect.
    Overflow,
}

impl std::fmt::Display for SendFail {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SendFail::Closed => write!(f, "wire connection closed"),
            SendFail::Overflow => write!(f, "wire writer backlog overflow"),
        }
    }
}

impl std::error::Error for SendFail {}

/// Blocking frame reader over any `Read` (a `TcpStream` in production,
/// a `Cursor` in tests). The payload buffer is reused across frames.
pub struct FrameReader<R: Read> {
    src: R,
    buf: Vec<u8>,
}

impl<R: Read> FrameReader<R> {
    pub fn new(src: R) -> Self {
        FrameReader {
            src,
            buf: Vec::new(),
        }
    }

    /// Read the next frame payload. `Ok(None)` is a clean EOF exactly
    /// at a frame boundary; EOF mid-prefix or mid-payload, a zero
    /// length, and a length beyond [`MAX_FRAME`] are all errors.
    pub fn next_frame(&mut self) -> io::Result<Option<&[u8]>> {
        let mut prefix = [0u8; 4];
        if !read_exact_or_eof(&mut self.src, &mut prefix)? {
            return Ok(None);
        }
        let len = u32::from_le_bytes(prefix) as usize;
        if len == 0 || len > MAX_FRAME {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("frame length {len} outside 1..={MAX_FRAME}"),
            ));
        }
        self.buf.resize(len, 0);
        self.src.read_exact(&mut self.buf)?;
        Ok(Some(&self.buf))
    }
}

/// `read_exact`, except a clean EOF before the *first* byte returns
/// `Ok(false)` instead of an error (EOF after partial data stays an
/// `UnexpectedEof` error — a torn frame).
fn read_exact_or_eof<R: Read>(src: &mut R, buf: &mut [u8]) -> io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        // lint:allow(panic-free-wire-surface): `got < buf.len()` is the loop
        // condition, so the range is in bounds by construction.
        match src.read(&mut buf[got..]) {
            Ok(0) if got == 0 => return Ok(false),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof inside a frame",
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

struct QueueInner {
    frames: Vec<Vec<u8>>,
    /// Payload bytes queued (the frames' summed lengths).
    bytes: usize,
    senders: usize,
    closed: bool,
}

/// The shared send queue behind [`FrameSender`] / the writer thread.
struct FrameQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    /// Backlog caps (frames, bytes) — exceeding either fails the
    /// session instead of growing memory against a stalled peer.
    max_frames: usize,
    max_bytes: usize,
    /// A clone of the session stream, so an overflowing *sender* can
    /// shut the socket down — unblocking a writer stuck in `write_all`
    /// and the peer-facing reader — without waiting for the writer.
    stream: Mutex<Option<TcpStream>>,
}

/// Clonable handle that enqueues encoded frame payloads for the writer
/// thread. Dropping the last sender (or calling [`FrameSender::close`])
/// lets the writer flush what is queued and close the write half.
pub struct FrameSender {
    q: Arc<FrameQueue>,
}

impl Clone for FrameSender {
    fn clone(&self) -> Self {
        relock(&self.q.inner).senders += 1;
        FrameSender { q: self.q.clone() }
    }
}

impl Drop for FrameSender {
    fn drop(&mut self) {
        let mut g = relock(&self.q.inner);
        g.senders -= 1;
        if g.senders == 0 {
            g.closed = true;
            self.q.cv.notify_all();
        }
    }
}

impl FrameSender {
    /// Enqueue one encoded payload (length prefix added by the writer).
    ///
    /// The size assertion guards the *local* encoder's contract — every
    /// payload here comes from `codec::encode_*`, never from the peer —
    /// so a violation is a codec bug worth a loud stop, not a
    /// wire-reachable panic.
    pub fn send(&self, frame: Vec<u8>) -> Result<(), SendFail> {
        // lint:allow(panic-free-wire-surface): asserts on locally encoded
        // payloads (codec bug), not on peer-supplied input.
        assert!(
            !frame.is_empty() && frame.len() <= MAX_FRAME,
            "frame payload of {} bytes outside 1..={MAX_FRAME}",
            frame.len()
        );
        let mut g = relock(&self.q.inner);
        if g.closed {
            return Err(SendFail::Closed);
        }
        if g.frames.len() >= self.q.max_frames || g.bytes + frame.len() > self.q.max_bytes {
            // Backlog full: the peer stopped draining. Fail the whole
            // session now — queued frames are as undeliverable as this
            // one, and the shutdown unblocks a writer wedged mid-write.
            g.closed = true;
            g.frames.clear();
            g.bytes = 0;
            drop(g);
            self.q.cv.notify_all();
            if let Some(s) = relock(&self.q.stream).take() {
                let _ = s.shutdown(Shutdown::Both);
            }
            return Err(SendFail::Overflow);
        }
        g.bytes += frame.len();
        g.frames.push(frame);
        self.q.cv.notify_one();
        Ok(())
    }

    /// Close the queue: queued frames still flush, further sends fail.
    pub fn close(&self) {
        let mut g = relock(&self.q.inner);
        g.closed = true;
        self.q.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        relock(&self.q.inner).closed
    }
}

/// What the writer thread did over its lifetime — `writes` vs `frames`
/// is the coalescing factor `bench_wire` reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct WriterStats {
    pub frames: u64,
    pub writes: u64,
    pub bytes: u64,
}

/// Spawn the coalescing writer thread owning `stream`'s write half.
/// The thread exits — flushing the remaining queue and shutting the write half
/// down — when every sender is dropped or `close` is called; a write
/// error also closes the queue so senders fail fast instead of piling
/// frames onto a dead connection. Spawn failure (thread-resource
/// exhaustion) is surfaced as an `io::Error`, like any other failure to
/// set up a session.
pub fn spawn_writer(
    stream: TcpStream,
) -> io::Result<(FrameSender, JoinHandle<io::Result<WriterStats>>)> {
    spawn_writer_with(stream, None)
}

/// [`spawn_writer`] with a fault-injection hook: when `faults` is set,
/// the writer consults it per batch — stalling, tearing, or killing the
/// session exactly where the seeded [`crate::net::faults::FaultPlan`]
/// says to.
pub fn spawn_writer_with(
    stream: TcpStream,
    faults: Option<SessionFaults>,
) -> io::Result<(FrameSender, JoinHandle<io::Result<WriterStats>>)> {
    spawn_writer_bounded(stream, faults, MAX_BACKLOG_FRAMES, MAX_BACKLOG_BYTES)
}

/// [`spawn_writer_with`] with explicit backlog caps (tests shrink them
/// to hit the overflow path without megabytes of traffic).
pub fn spawn_writer_bounded(
    stream: TcpStream,
    faults: Option<SessionFaults>,
    max_frames: usize,
    max_bytes: usize,
) -> io::Result<(FrameSender, JoinHandle<io::Result<WriterStats>>)> {
    let q = Arc::new(FrameQueue {
        inner: Mutex::new(QueueInner {
            frames: Vec::new(),
            bytes: 0,
            senders: 1,
            closed: false,
        }),
        cv: Condvar::new(),
        max_frames,
        max_bytes,
        stream: Mutex::new(stream.try_clone().ok()),
    });
    let sender = FrameSender { q: q.clone() };
    let handle = std::thread::Builder::new()
        .name("wire-writer".into())
        .spawn(move || write_loop(q, stream, faults))?;
    Ok((sender, handle))
}

fn write_loop(
    q: Arc<FrameQueue>,
    mut stream: TcpStream,
    mut faults: Option<SessionFaults>,
) -> io::Result<WriterStats> {
    let mut stats = WriterStats::default();
    let mut batch: Vec<Vec<u8>> = Vec::new();
    let mut out: Vec<u8> = Vec::new();
    // The shared adaptive drain policy (`util::ring::Waiter`): spin →
    // yield before each Condvar block — a line-rate sender usually
    // refills the queue within the spin budget, skipping the futex
    // round trip per drain. `SYMPHONY_BUSY_POLL=1` keeps the writer
    // spinning outright. (The *read* side stays a blocking socket
    // read: the kernel already wakes it exactly when bytes arrive.)
    let mut waiter = Waiter::from_env(false);
    'outer: loop {
        loop {
            let mut g = relock(&q.inner);
            if !g.frames.is_empty() {
                std::mem::swap(&mut g.frames, &mut batch);
                g.bytes = 0;
                break;
            }
            if g.closed {
                break 'outer;
            }
            if waiter.should_block() {
                while g.frames.is_empty() && !g.closed {
                    g = q.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
                }
                std::mem::swap(&mut g.frames, &mut batch);
                g.bytes = 0;
                if batch.is_empty() && g.closed {
                    break 'outer;
                }
                break;
            }
            drop(g);
            waiter.idle();
        }
        waiter.reset();
        // Fault hooks: a seeded plan can stall the writer (modelling a
        // saturated peer) and cut the session at an exact frame index.
        let admit = match faults.as_mut() {
            Some(f) => {
                let stall = f.stall_us();
                if stall > 0 {
                    std::thread::sleep(Duration::from_micros(stall));
                }
                f.admit(batch.len())
            }
            None => Admit {
                allowed: batch.len(),
                kill: false,
                torn: false,
            },
        };
        // One contiguous buffer, one syscall, however deep the backlog.
        out.clear();
        for f in batch.iter().take(admit.allowed) {
            out.extend_from_slice(&(f.len() as u32).to_le_bytes());
            out.extend_from_slice(f);
            stats.frames += 1;
        }
        if admit.kill && admit.torn {
            // Ship the fatal frame's prefix and half its payload: the
            // peer's reader must surface a torn frame as an error.
            if let Some(f) = batch.get(admit.allowed) {
                out.extend_from_slice(&(f.len() as u32).to_le_bytes());
                if let Some(half) = f.get(..f.len() / 2) {
                    out.extend_from_slice(half);
                }
            }
        }
        batch.clear();
        if let Err(e) = stream.write_all(&out) {
            let mut g = relock(&q.inner);
            g.closed = true;
            g.frames.clear();
            g.bytes = 0;
            drop(g);
            q.cv.notify_all();
            let _ = stream.shutdown(Shutdown::Write);
            return Err(e);
        }
        stats.writes += 1;
        stats.bytes += out.len() as u64;
        if admit.kill {
            let mut g = relock(&q.inner);
            g.closed = true;
            g.frames.clear();
            g.bytes = 0;
            drop(g);
            q.cv.notify_all();
            let _ = stream.shutdown(Shutdown::Both);
            return Err(io::Error::new(
                io::ErrorKind::ConnectionAborted,
                "fault-plan kill",
            ));
        }
    }
    let _ = stream.shutdown(Shutdown::Write);
    Ok(stats)
}

/// `TcpStream::connect` with retry until `timeout` — the peer may still
/// be binding (CI spawns `rank-server` and `serve` back to back). Only
/// plausibly-transient failures retry; a permanent error (bad hostname,
/// unreachable network) surfaces immediately instead of stalling the
/// spawn for the whole timeout.
pub fn connect_retry(addr: &str, timeout: Duration) -> io::Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let transient = matches!(
                    e.kind(),
                    io::ErrorKind::ConnectionRefused
                        | io::ErrorKind::ConnectionReset
                        | io::ErrorKind::ConnectionAborted
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::AddrNotAvailable
                );
                if !transient || Instant::now() >= deadline {
                    return Err(e);
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;
    use std::net::TcpListener;

    fn frame(len: usize, fill: u8) -> Vec<u8> {
        let mut out = (len as u32).to_le_bytes().to_vec();
        out.resize(4 + len, fill);
        out
    }

    #[test]
    fn reader_parses_back_to_back_frames() {
        let mut bytes = frame(3, 0xAB);
        bytes.extend(frame(1, 0xCD));
        let mut r = FrameReader::new(Cursor::new(bytes));
        assert_eq!(r.next_frame().unwrap().unwrap(), &[0xAB, 0xAB, 0xAB]);
        assert_eq!(r.next_frame().unwrap().unwrap(), &[0xCD]);
        assert!(r.next_frame().unwrap().is_none(), "clean EOF");
    }

    /// Oversized / zero lengths are rejected before any payload read —
    /// the transport half of the codec-robustness satellite.
    #[test]
    fn reader_rejects_bad_lengths() {
        let bytes = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        let mut r = FrameReader::new(Cursor::new(bytes));
        let err = r.next_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{err}");

        let bytes = 0u32.to_le_bytes().to_vec();
        let mut r = FrameReader::new(Cursor::new(bytes));
        let err = r.next_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{err}");
    }

    #[test]
    fn reader_torn_frame_is_unexpected_eof() {
        // Prefix promises 8 bytes, only 2 follow.
        let mut bytes = 8u32.to_le_bytes().to_vec();
        bytes.extend([1, 2]);
        let mut r = FrameReader::new(Cursor::new(bytes));
        let err = r.next_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);

        // EOF inside the prefix itself is torn too.
        let mut r = FrameReader::new(Cursor::new(vec![1u8, 0]));
        let err = r.next_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    /// End-to-end over loopback: frames enqueued from several senders
    /// arrive intact, and the writer coalesces a queued backlog into
    /// fewer syscalls than frames.
    #[test]
    fn writer_coalesces_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reader_h = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut r = FrameReader::new(stream);
            let mut got: Vec<Vec<u8>> = Vec::new();
            while let Some(f) = r.next_frame().unwrap() {
                got.push(f.to_vec());
            }
            got
        });
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let (tx, writer_h) = spawn_writer(stream).unwrap();
        let n = 512u32;
        let tx2 = tx.clone();
        for i in 0..n {
            let who = if i % 2 == 0 { &tx } else { &tx2 };
            who.send(i.to_le_bytes().to_vec()).unwrap();
        }
        drop(tx);
        drop(tx2);
        let stats = writer_h.join().unwrap().unwrap();
        let got = reader_h.join().unwrap();
        assert_eq!(got.len(), n as usize);
        for (i, f) in got.iter().enumerate() {
            assert_eq!(f, &(i as u32).to_le_bytes().to_vec(), "frame {i} in order");
        }
        assert_eq!(stats.frames, n as u64);
        assert!(
            stats.writes <= stats.frames,
            "coalescing can never add syscalls: {stats:?}"
        );
    }

    #[test]
    fn send_after_close_fails() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let accept_h = std::thread::spawn(move || listener.accept().unwrap());
        let stream = TcpStream::connect(addr).unwrap();
        let (tx, writer_h) = spawn_writer(stream).unwrap();
        tx.send(vec![1]).unwrap();
        tx.close();
        assert!(tx.is_closed());
        assert_eq!(tx.send(vec![2]), Err(SendFail::Closed));
        drop(tx);
        let stats = writer_h.join().unwrap().unwrap();
        assert_eq!(stats.frames, 1, "queued frame still flushed");
        drop(accept_h.join().unwrap());
    }

    /// The backlog-bound satellite's regression test: with the writer
    /// stalled (fault plan) and a tiny frame cap, sends hit
    /// `SendFail::Overflow`, the session dies, and later sends fail as
    /// `Closed` — memory never grows without bound against a stalled
    /// peer.
    #[test]
    fn backlog_overflow_fails_the_session() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let accept_h = std::thread::spawn(move || listener.accept().unwrap());
        let stream = TcpStream::connect(addr).unwrap();
        // Stall every write batch for 2s: the queue must absorb — and
        // then refuse — everything sent during the stall.
        let plan = crate::net::faults::FaultPlan::parse("stall-writer-us=2000000").unwrap();
        let (tx, writer_h) =
            spawn_writer_bounded(stream, Some(plan.session()), 8, 1 << 20).unwrap();
        let mut overflowed = false;
        for i in 0..64u32 {
            match tx.send(i.to_le_bytes().to_vec()) {
                Ok(()) => {}
                Err(SendFail::Overflow) => {
                    overflowed = true;
                    break;
                }
                Err(SendFail::Closed) => panic!("closed before overflow"),
            }
        }
        assert!(overflowed, "64 sends against an 8-frame cap must overflow");
        assert!(tx.is_closed(), "overflow closes the whole session");
        assert_eq!(tx.send(vec![9]), Err(SendFail::Closed));
        drop(tx);
        // The overflow shutdown unblocks the (stalled) writer; its exit
        // status does not matter, only that it exits.
        let _ = writer_h.join().unwrap();
        drop(accept_h.join().unwrap());
    }

    /// A frame-count kill cuts the stream at exactly the planned frame,
    /// and the same plan does the same thing every run (determinism at
    /// the transport level).
    #[test]
    fn fault_kill_cuts_at_the_planned_frame() {
        for _run in 0..2 {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let reader_h = std::thread::spawn(move || {
                let (stream, _) = listener.accept().unwrap();
                let mut r = FrameReader::new(stream);
                let mut got = 0u64;
                loop {
                    match r.next_frame() {
                        Ok(Some(_)) => got += 1,
                        Ok(None) => return (got, false),
                        Err(_) => return (got, true),
                    }
                }
            });
            let stream = TcpStream::connect(addr).unwrap();
            let plan = crate::net::faults::FaultPlan::parse("kill-after-frames=5,torn").unwrap();
            let (tx, writer_h) = spawn_writer_with(stream, Some(plan.session())).unwrap();
            for i in 0..32u32 {
                if tx.send(i.to_le_bytes().to_vec()).is_err() {
                    break; // killed mid-run: exactly what the plan wants
                }
            }
            drop(tx);
            let err = writer_h.join().unwrap().unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::ConnectionAborted, "{err}");
            let (got, torn) = reader_h.join().unwrap();
            assert_eq!(got, 5, "exactly the planned frames survive");
            assert!(torn, "the torn fatal frame must read as an error");
        }
    }
}
