//! Length-prefixed framed TCP transport for the rank-coordination wire.
//!
//! A frame on the wire is `[len: u32 LE][payload]` where the payload is
//! one [`crate::net::codec`] message. Two pieces:
//!
//! * [`FrameReader`] — a blocking per-connection reader: reads one
//!   frame at a time into a reused buffer, distinguishes clean EOF (at
//!   a frame boundary) from a torn frame, and rejects zero-length or
//!   oversized lengths **before** allocating or reading the payload, so
//!   a corrupt length prefix cannot make the reader balloon or stall.
//! * [`spawn_writer`] — the write side, the wire analogue of
//!   `RankShard::InboxBatch`: senders enqueue encoded payloads into a
//!   shared queue; the writer thread swaps the *entire* backlog out
//!   under one lock, prefixes every frame into one contiguous buffer,
//!   and ships the batch with a single `write_all` — one syscall per
//!   drain no matter how many frames queued behind it. `TCP_NODELAY`
//!   is set by both peers, so latency when the queue is shallow comes
//!   from the wire, not from Nagle.
//!
//! Like everything under `net/`, std-only by construction.

use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::ring::Waiter;
use crate::util::sync::relock;

/// Maximum accepted frame payload length. Codec frames are tens of
/// bytes; anything near this bound is a corrupt prefix or a foreign
/// protocol, rejected without reading the claimed payload.
pub const MAX_FRAME: usize = 4096;

/// The peer (or the writer thread) is gone; the frame was not sent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireClosed;

impl std::fmt::Display for WireClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire connection closed")
    }
}

impl std::error::Error for WireClosed {}

/// Blocking frame reader over any `Read` (a `TcpStream` in production,
/// a `Cursor` in tests). The payload buffer is reused across frames.
pub struct FrameReader<R: Read> {
    src: R,
    buf: Vec<u8>,
}

impl<R: Read> FrameReader<R> {
    pub fn new(src: R) -> Self {
        FrameReader {
            src,
            buf: Vec::new(),
        }
    }

    /// Read the next frame payload. `Ok(None)` is a clean EOF exactly
    /// at a frame boundary; EOF mid-prefix or mid-payload, a zero
    /// length, and a length beyond [`MAX_FRAME`] are all errors.
    pub fn next_frame(&mut self) -> io::Result<Option<&[u8]>> {
        let mut prefix = [0u8; 4];
        if !read_exact_or_eof(&mut self.src, &mut prefix)? {
            return Ok(None);
        }
        let len = u32::from_le_bytes(prefix) as usize;
        if len == 0 || len > MAX_FRAME {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("frame length {len} outside 1..={MAX_FRAME}"),
            ));
        }
        self.buf.resize(len, 0);
        self.src.read_exact(&mut self.buf)?;
        Ok(Some(&self.buf))
    }
}

/// `read_exact`, except a clean EOF before the *first* byte returns
/// `Ok(false)` instead of an error (EOF after partial data stays an
/// `UnexpectedEof` error — a torn frame).
fn read_exact_or_eof<R: Read>(src: &mut R, buf: &mut [u8]) -> io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        // lint:allow(panic-free-wire-surface): `got < buf.len()` is the loop
        // condition, so the range is in bounds by construction.
        match src.read(&mut buf[got..]) {
            Ok(0) if got == 0 => return Ok(false),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "eof inside a frame",
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

struct QueueInner {
    frames: Vec<Vec<u8>>,
    senders: usize,
    closed: bool,
}

/// The shared send queue behind [`FrameSender`] / the writer thread.
struct FrameQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

/// Clonable handle that enqueues encoded frame payloads for the writer
/// thread. Dropping the last sender (or calling [`FrameSender::close`])
/// lets the writer flush what is queued and close the write half.
pub struct FrameSender {
    q: Arc<FrameQueue>,
}

impl Clone for FrameSender {
    fn clone(&self) -> Self {
        relock(&self.q.inner).senders += 1;
        FrameSender { q: self.q.clone() }
    }
}

impl Drop for FrameSender {
    fn drop(&mut self) {
        let mut g = relock(&self.q.inner);
        g.senders -= 1;
        if g.senders == 0 {
            g.closed = true;
            self.q.cv.notify_all();
        }
    }
}

impl FrameSender {
    /// Enqueue one encoded payload (length prefix added by the writer).
    ///
    /// The size assertion guards the *local* encoder's contract — every
    /// payload here comes from `codec::encode_*`, never from the peer —
    /// so a violation is a codec bug worth a loud stop, not a
    /// wire-reachable panic.
    pub fn send(&self, frame: Vec<u8>) -> Result<(), WireClosed> {
        // lint:allow(panic-free-wire-surface): asserts on locally encoded
        // payloads (codec bug), not on peer-supplied input.
        assert!(
            !frame.is_empty() && frame.len() <= MAX_FRAME,
            "frame payload of {} bytes outside 1..={MAX_FRAME}",
            frame.len()
        );
        let mut g = relock(&self.q.inner);
        if g.closed {
            return Err(WireClosed);
        }
        g.frames.push(frame);
        self.q.cv.notify_one();
        Ok(())
    }

    /// Close the queue: queued frames still flush, further sends fail.
    pub fn close(&self) {
        let mut g = relock(&self.q.inner);
        g.closed = true;
        self.q.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        relock(&self.q.inner).closed
    }
}

/// What the writer thread did over its lifetime — `writes` vs `frames`
/// is the coalescing factor `bench_wire` reports.
#[derive(Clone, Copy, Debug, Default)]
pub struct WriterStats {
    pub frames: u64,
    pub writes: u64,
    pub bytes: u64,
}

/// Spawn the coalescing writer thread owning `stream`'s write half.
/// The thread exits — flushing the remaining queue and shutting the write half
/// down — when every sender is dropped or `close` is called; a write
/// error also closes the queue so senders fail fast instead of piling
/// frames onto a dead connection. Spawn failure (thread-resource
/// exhaustion) is surfaced as an `io::Error`, like any other failure to
/// set up a session.
pub fn spawn_writer(
    stream: TcpStream,
) -> io::Result<(FrameSender, JoinHandle<io::Result<WriterStats>>)> {
    let q = Arc::new(FrameQueue {
        inner: Mutex::new(QueueInner {
            frames: Vec::new(),
            senders: 1,
            closed: false,
        }),
        cv: Condvar::new(),
    });
    let sender = FrameSender { q: q.clone() };
    let handle = std::thread::Builder::new()
        .name("wire-writer".into())
        .spawn(move || write_loop(q, stream))?;
    Ok((sender, handle))
}

fn write_loop(q: Arc<FrameQueue>, mut stream: TcpStream) -> io::Result<WriterStats> {
    let mut stats = WriterStats::default();
    let mut batch: Vec<Vec<u8>> = Vec::new();
    let mut out: Vec<u8> = Vec::new();
    // The shared adaptive drain policy (`util::ring::Waiter`): spin →
    // yield before each Condvar block — a line-rate sender usually
    // refills the queue within the spin budget, skipping the futex
    // round trip per drain. `SYMPHONY_BUSY_POLL=1` keeps the writer
    // spinning outright. (The *read* side stays a blocking socket
    // read: the kernel already wakes it exactly when bytes arrive.)
    let mut waiter = Waiter::from_env(false);
    'outer: loop {
        loop {
            let mut g = relock(&q.inner);
            if !g.frames.is_empty() {
                std::mem::swap(&mut g.frames, &mut batch);
                break;
            }
            if g.closed {
                break 'outer;
            }
            if waiter.should_block() {
                while g.frames.is_empty() && !g.closed {
                    g = q.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
                }
                std::mem::swap(&mut g.frames, &mut batch);
                if batch.is_empty() && g.closed {
                    break 'outer;
                }
                break;
            }
            drop(g);
            waiter.idle();
        }
        waiter.reset();
        // One contiguous buffer, one syscall, however deep the backlog.
        out.clear();
        for f in batch.drain(..) {
            out.extend_from_slice(&(f.len() as u32).to_le_bytes());
            out.extend_from_slice(&f);
            stats.frames += 1;
        }
        if let Err(e) = stream.write_all(&out) {
            let mut g = relock(&q.inner);
            g.closed = true;
            g.frames.clear();
            drop(g);
            q.cv.notify_all();
            let _ = stream.shutdown(Shutdown::Write);
            return Err(e);
        }
        stats.writes += 1;
        stats.bytes += out.len() as u64;
    }
    let _ = stream.shutdown(Shutdown::Write);
    Ok(stats)
}

/// `TcpStream::connect` with retry until `timeout` — the peer may still
/// be binding (CI spawns `rank-server` and `serve` back to back). Only
/// plausibly-transient failures retry; a permanent error (bad hostname,
/// unreachable network) surfaces immediately instead of stalling the
/// spawn for the whole timeout.
pub fn connect_retry(addr: &str, timeout: Duration) -> io::Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let transient = matches!(
                    e.kind(),
                    io::ErrorKind::ConnectionRefused
                        | io::ErrorKind::ConnectionReset
                        | io::ErrorKind::ConnectionAborted
                        | io::ErrorKind::TimedOut
                        | io::ErrorKind::AddrNotAvailable
                );
                if !transient || Instant::now() >= deadline {
                    return Err(e);
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;
    use std::net::TcpListener;

    fn frame(len: usize, fill: u8) -> Vec<u8> {
        let mut out = (len as u32).to_le_bytes().to_vec();
        out.resize(4 + len, fill);
        out
    }

    #[test]
    fn reader_parses_back_to_back_frames() {
        let mut bytes = frame(3, 0xAB);
        bytes.extend(frame(1, 0xCD));
        let mut r = FrameReader::new(Cursor::new(bytes));
        assert_eq!(r.next_frame().unwrap().unwrap(), &[0xAB, 0xAB, 0xAB]);
        assert_eq!(r.next_frame().unwrap().unwrap(), &[0xCD]);
        assert!(r.next_frame().unwrap().is_none(), "clean EOF");
    }

    /// Oversized / zero lengths are rejected before any payload read —
    /// the transport half of the codec-robustness satellite.
    #[test]
    fn reader_rejects_bad_lengths() {
        let bytes = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        let mut r = FrameReader::new(Cursor::new(bytes));
        let err = r.next_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{err}");

        let bytes = 0u32.to_le_bytes().to_vec();
        let mut r = FrameReader::new(Cursor::new(bytes));
        let err = r.next_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{err}");
    }

    #[test]
    fn reader_torn_frame_is_unexpected_eof() {
        // Prefix promises 8 bytes, only 2 follow.
        let mut bytes = 8u32.to_le_bytes().to_vec();
        bytes.extend([1, 2]);
        let mut r = FrameReader::new(Cursor::new(bytes));
        let err = r.next_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);

        // EOF inside the prefix itself is torn too.
        let mut r = FrameReader::new(Cursor::new(vec![1u8, 0]));
        let err = r.next_frame().unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    /// End-to-end over loopback: frames enqueued from several senders
    /// arrive intact, and the writer coalesces a queued backlog into
    /// fewer syscalls than frames.
    #[test]
    fn writer_coalesces_over_loopback() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let reader_h = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut r = FrameReader::new(stream);
            let mut got: Vec<Vec<u8>> = Vec::new();
            while let Some(f) = r.next_frame().unwrap() {
                got.push(f.to_vec());
            }
            got
        });
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let (tx, writer_h) = spawn_writer(stream).unwrap();
        let n = 512u32;
        let tx2 = tx.clone();
        for i in 0..n {
            let who = if i % 2 == 0 { &tx } else { &tx2 };
            who.send(i.to_le_bytes().to_vec()).unwrap();
        }
        drop(tx);
        drop(tx2);
        let stats = writer_h.join().unwrap().unwrap();
        let got = reader_h.join().unwrap();
        assert_eq!(got.len(), n as usize);
        for (i, f) in got.iter().enumerate() {
            assert_eq!(f, &(i as u32).to_le_bytes().to_vec(), "frame {i} in order");
        }
        assert_eq!(stats.frames, n as u64);
        assert!(
            stats.writes <= stats.frames,
            "coalescing can never add syscalls: {stats:?}"
        );
    }

    #[test]
    fn send_after_close_fails() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let accept_h = std::thread::spawn(move || listener.accept().unwrap());
        let stream = TcpStream::connect(addr).unwrap();
        let (tx, writer_h) = spawn_writer(stream).unwrap();
        tx.send(vec![1]).unwrap();
        tx.close();
        assert!(tx.is_closed());
        assert_eq!(tx.send(vec![2]), Err(WireClosed));
        drop(tx);
        let stats = writer_h.join().unwrap().unwrap();
        assert_eq!(stats.frames, 1, "queued frame still flushed");
        drop(accept_h.join().unwrap());
    }
}
