//! A deliberately tiny std-only HTTP/1.1 listener for `/metrics`.
//!
//! Scope: serve Prometheus scrapes from one render closure. One accept
//! loop thread, one connection at a time, read-timeout bounded, no
//! keep-alive (`Connection: close`). This is not a web server — a
//! scraper polls it every few seconds, and anything fancier (thread
//! pools, TLS, HTTP/2) belongs to the cluster's sidecar, not to the
//! serving process. Shutdown unblocks the accept loop with a
//! self-connect, the same trick the harness uses for blocking
//! listeners elsewhere.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// What the listener serves: a fresh exposition page per scrape.
pub type Render = Arc<dyn Fn() -> String + Send + Sync>;

pub struct MetricsServer {
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
    handle: Option<JoinHandle<()>>,
}

/// Bind `listen` (e.g. `127.0.0.1:9184`, port 0 for ephemeral) and
/// serve `render()` on every `GET /metrics`.
pub fn spawn(listen: &str, render: Render) -> std::io::Result<MetricsServer> {
    let listener = TcpListener::bind(listen)?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let handle = std::thread::Builder::new()
        .name("obs-metrics".into())
        .spawn(move || accept_loop(listener, render, stop2))?;
    Ok(MetricsServer {
        stop,
        addr,
        handle: Some(handle),
    })
}

impl MetricsServer {
    /// The bound address (resolves port 0 for tests).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        let Some(handle) = self.handle.take() else {
            return;
        };
        self.stop.store(true, Ordering::Release);
        // Unblock the accept; a wildcard bind answers on loopback.
        let mut poke = self.addr;
        if poke.ip().is_unspecified() {
            poke.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
        }
        let _ = TcpStream::connect_timeout(&poke, Duration::from_millis(200));
        let _ = handle.join();
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn accept_loop(listener: TcpListener, render: Render, stop: Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            break;
        }
        let Ok(mut stream) = conn else {
            // Transient accept failure (ECONNABORTED, fd pressure):
            // keep serving, same policy as the rank server.
            continue;
        };
        let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
        let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
        let _ = serve_one(&mut stream, &render);
    }
}

fn serve_one(stream: &mut TcpStream, render: &Render) -> std::io::Result<()> {
    // Read until the end of the request head (or a 4 KiB cap — a
    // scrape's GET has no body worth waiting for).
    let mut head = [0u8; 4096];
    let mut n = 0usize;
    loop {
        if n == head.len() {
            break;
        }
        let read = match stream.read(&mut head[n..]) {
            Ok(0) => break,
            Ok(r) => r,
            Err(_) => break,
        };
        n += read;
        if head[..n].windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
    }
    let request = String::from_utf8_lossy(&head[..n]);
    let path = request
        .lines()
        .next()
        .and_then(|line| line.split_whitespace().nth(1))
        .unwrap_or("/");
    let (status, body) = if path == "/metrics" || path == "/" {
        ("200 OK", render())
    } else {
        ("404 Not Found", String::from("not found\n"))
    };
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn scrape(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).expect("connect");
        write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\n\r\n").expect("request");
        let mut out = String::new();
        s.read_to_string(&mut out).expect("response");
        out
    }

    #[test]
    fn serves_metrics_and_404s_elsewhere() {
        let hits = Arc::new(AtomicU64::new(0));
        let h2 = hits.clone();
        let render: Render = Arc::new(move || {
            format!(
                "symphony_scrapes_total {}\n",
                h2.fetch_add(1, Ordering::Relaxed) + 1
            )
        });
        let srv = spawn("127.0.0.1:0", render).expect("bind");
        let addr = srv.addr();
        let one = scrape(addr, "/metrics");
        assert!(one.starts_with("HTTP/1.1 200 OK\r\n"), "{one}");
        assert!(one.contains("symphony_scrapes_total 1"), "{one}");
        let two = scrape(addr, "/metrics");
        assert!(two.contains("symphony_scrapes_total 2"), "{two}");
        let miss = scrape(addr, "/nope");
        assert!(miss.starts_with("HTTP/1.1 404"), "{miss}");
        srv.shutdown();
    }

    #[test]
    fn shutdown_joins_promptly() {
        let render: Render = Arc::new(|| String::from("x 1\n"));
        let srv = spawn("127.0.0.1:0", render).expect("bind");
        let t0 = std::time::Instant::now();
        srv.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(2));
    }
}
