//! Rate-limited leveled logging: `log_error!` / `log_warn!` /
//! `log_info!` / `log_debug!`.
//!
//! Two problems with the raw `eprintln!` calls these macros replace
//! (the `no-bare-eprintln` lint rule now keeps them out of
//! `coordinator/` and `net/`):
//!
//! * **Unbounded spam.** A flapping peer under fault injection drives
//!   the read/write/dial loops through their error paths thousands of
//!   times per second; the dial loop even grew a hand-rolled
//!   `attempts % 16` throttle. Every call site now carries its own
//!   token bucket ([`Site`]): a burst of [`BURST`] lines passes, then
//!   the site is limited to [`REFILL_PER_SEC`] lines/second, and the
//!   next line that does print says how many were suppressed —
//!   evidence of the storm without the storm.
//! * **No levels.** `SYMPHONY_LOG` (`off`, `error`, `warn`, `info`,
//!   `debug`; default `info`) filters by severity, read once per
//!   process.
//!
//! The macros expand to a per-call-site `static Site` plus one call
//! into [`log`] — no allocation when the level is filtered or the
//! bucket is dry, and the token bucket itself is three relaxed atomics
//! (ordering is irrelevant: the worst race double-prints or
//! double-counts one line of stderr).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Lines a call site may burst before the per-second limit kicks in.
pub const BURST: u64 = 8;
/// Sustained per-call-site rate once the burst is spent.
pub const REFILL_PER_SEC: u64 = 2;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Max level that prints; -1 silences everything (`SYMPHONY_LOG=off`).
static MAX_LEVEL: OnceLock<i8> = OnceLock::new();
static LOG_ORIGIN: OnceLock<Instant> = OnceLock::new();

fn max_level() -> i8 {
    *MAX_LEVEL.get_or_init(|| parse_level(std::env::var("SYMPHONY_LOG").ok().as_deref()))
}

fn parse_level(v: Option<&str>) -> i8 {
    match v.map(str::trim).map(str::to_ascii_lowercase).as_deref() {
        Some("off") | Some("none") => -1,
        Some("error") => Level::Error as i8,
        Some("warn") | Some("warning") => Level::Warn as i8,
        Some("debug") | Some("trace") => Level::Debug as i8,
        // Unrecognized values (and unset) keep the default.
        _ => Level::Info as i8,
    }
}

pub fn level_enabled(level: Level) -> bool {
    (level as i8) <= max_level()
}

fn now_ms() -> u64 {
    LOG_ORIGIN.get_or_init(Instant::now).elapsed().as_millis() as u64
}

/// Per-call-site token bucket. `const`-constructible so the logging
/// macros can declare one `static` per expansion.
pub struct Site {
    /// ms timestamp (process origin) of the last whole-second refill.
    last_refill_ms: AtomicU64,
    tokens: AtomicU64,
    suppressed: AtomicU64,
}

impl Site {
    pub const fn new() -> Self {
        Site {
            last_refill_ms: AtomicU64::new(0),
            tokens: AtomicU64::new(BURST),
            suppressed: AtomicU64::new(0),
        }
    }

    /// Token-bucket admission at time `now_ms`. `Some(n)` means print
    /// (with `n` lines suppressed since the site last printed); `None`
    /// means suppress. Pure over its inputs, so tests drive it with a
    /// synthetic clock.
    pub fn admit(&self, now_ms: u64) -> Option<u64> {
        let last = self.last_refill_ms.load(Ordering::Relaxed);
        if now_ms > last {
            let gained = (now_ms - last) / 1000 * REFILL_PER_SEC;
            if gained > 0 {
                let advanced = last + (gained / REFILL_PER_SEC) * 1000;
                // One racer wins the refill window and credits the
                // bucket; losers just try again next call.
                if self
                    .last_refill_ms
                    .compare_exchange(last, advanced, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
                {
                    let _ = self.tokens.fetch_update(
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                        |t| Some((t + gained).min(BURST)),
                    );
                }
            }
        }
        if self
            .tokens
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |t| t.checked_sub(1))
            .is_ok()
        {
            Some(self.suppressed.swap(0, Ordering::Relaxed))
        } else {
            self.suppressed.fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

impl Default for Site {
    fn default() -> Self {
        Site::new()
    }
}

/// The macro target: level filter, then token-bucket admission, then
/// one stderr line (with the suppressed count when the site was
/// recently dry).
pub fn log(level: Level, site: &Site, args: std::fmt::Arguments<'_>) {
    if !level_enabled(level) {
        return;
    }
    match site.admit(now_ms()) {
        Some(0) => eprintln!("[{}] {args}", level.tag()),
        Some(n) => eprintln!("[{}] {args} ({n} similar lines suppressed)", level.tag()),
        None => {}
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {{
        static __SITE: $crate::obs::log::Site = $crate::obs::log::Site::new();
        $crate::obs::log::log(
            $crate::obs::log::Level::Error,
            &__SITE,
            ::core::format_args!($($arg)*),
        );
    }};
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {{
        static __SITE: $crate::obs::log::Site = $crate::obs::log::Site::new();
        $crate::obs::log::log(
            $crate::obs::log::Level::Warn,
            &__SITE,
            ::core::format_args!($($arg)*),
        );
    }};
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {{
        static __SITE: $crate::obs::log::Site = $crate::obs::log::Site::new();
        $crate::obs::log::log(
            $crate::obs::log::Level::Info,
            &__SITE,
            ::core::format_args!($($arg)*),
        );
    }};
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {{
        static __SITE: $crate::obs::log::Site = $crate::obs::log::Site::new();
        $crate::obs::log::log(
            $crate::obs::log::Level::Debug,
            &__SITE,
            ::core::format_args!($($arg)*),
        );
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_suppress_then_refill_with_count() {
        let site = Site::new();
        // The full burst passes, nothing suppressed yet.
        for i in 0..BURST {
            assert_eq!(site.admit(0), Some(0), "burst line {i}");
        }
        // Bucket dry: the next 5 lines are suppressed.
        for _ in 0..5 {
            assert_eq!(site.admit(10), None);
        }
        // One second later: REFILL_PER_SEC tokens return, and the first
        // admitted line reports everything suppressed in between.
        assert_eq!(site.admit(1000), Some(5));
        for _ in 1..REFILL_PER_SEC {
            assert_eq!(site.admit(1000), Some(0));
        }
        assert_eq!(site.admit(1000), None);
    }

    #[test]
    fn refill_caps_at_burst() {
        let site = Site::new();
        for _ in 0..BURST {
            assert!(site.admit(0).is_some());
        }
        // A long quiet period refills to the cap, not beyond.
        let mut admitted = 0;
        for _ in 0..(2 * BURST) {
            if site.admit(3_600_000).is_some() {
                admitted += 1;
            }
        }
        assert_eq!(admitted, BURST);
    }

    #[test]
    fn sub_second_elapse_refills_nothing() {
        let site = Site::new();
        for _ in 0..BURST {
            assert!(site.admit(0).is_some());
        }
        assert_eq!(site.admit(999), None);
    }

    #[test]
    fn level_parse() {
        assert_eq!(parse_level(Some("off")), -1);
        assert_eq!(parse_level(Some("ERROR")), 0);
        assert_eq!(parse_level(Some("warn")), 1);
        assert_eq!(parse_level(Some("info")), 2);
        assert_eq!(parse_level(Some("debug")), 3);
        assert_eq!(parse_level(None), 2);
        assert_eq!(parse_level(Some("gibberish")), 2);
    }
}
