//! Observability: the flight recorder, the Prometheus `/metrics`
//! exposition, and the rate-limited leveled logger.
//!
//! Everything here is std-only (offline registry, like the rest of the
//! crate) and built to the same discipline as the fabric it watches:
//!
//! * [`trace`] — a **flight recorder**: a bounded lock-free span
//!   buffer (one [`crate::util::ring`] MPSC ring with thread-cached
//!   senders) recording integer-µs lifecycle events for a 1-in-N
//!   sample of requests across every hop of the serving pipeline
//!   (submit → ingest bin → worker → grant → dispatch → completion,
//!   plus model-keyed registration/grant/wire events). Overflow sheds
//!   and counts, never blocks; with tracing disabled every tap costs
//!   one relaxed load and one predictable branch — zero allocations —
//!   which `tests/alloc_free.rs` proves and `bench_hotpath`'s
//!   traced-vs-untraced probe measures. Sampled spans aggregate into
//!   a per-hop latency breakdown ([`crate::util::stats::LogHistogram`]
//!   p50/p99 per stage) surfaced in `ServeReport`, and `--trace-out
//!   FILE` dumps raw spans as Chrome trace-event JSON loadable in
//!   Perfetto.
//! * [`prom`] + [`http`] — a tiny std-only HTTP listener (`serve
//!   --metrics-listen ADDR`, `rank-server --metrics-listen ADDR`)
//!   exposing the already-collected counters (goodput, drops,
//!   grants, mis-steers, per-cause disconnects, reconnects, fenced
//!   frames, queue depths, ring occupancy high-watermarks, autoscale
//!   gauges) in Prometheus text exposition format — the substrate
//!   for the ROADMAP's k8s/cluster-autoscaler recipe.
//! * [`log`] — a rate-limited leveled logger (level filter via
//!   `SYMPHONY_LOG`, per-call-site token bucket with a
//!   suppressed-count line) behind the `log_error!` / `log_warn!` /
//!   `log_info!` / `log_debug!` macros. The `no-bare-eprintln` lint
//!   rule keeps raw `eprintln!` out of `coordinator/` and `net/`, so
//!   a flapping peer under fault injection can no longer spam stderr
//!   unboundedly from the read/write/dial loops.

pub mod http;
pub mod log;
pub mod prom;
pub mod trace;
