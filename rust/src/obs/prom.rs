//! Prometheus text exposition (format version 0.0.4), hand-rolled —
//! the offline registry has no prometheus crate, and the exposition
//! format is a handful of `name{labels} value` lines anyway.
//!
//! [`Prom`] is a write-once page builder: declare each metric family
//! with [`Prom::family`] (emits `# HELP` / `# TYPE` once), then append
//! samples. Family declarations are deduplicated and sample series
//! (name + label set) are debug-asserted unique, which the golden test
//! in `tests/observability.rs` re-checks from the parsed output.

use std::collections::BTreeSet;
use std::fmt::Write as _;

pub struct Prom {
    out: String,
    families: BTreeSet<String>,
    #[cfg(debug_assertions)]
    series: BTreeSet<String>,
}

impl Prom {
    pub fn new() -> Self {
        Prom {
            out: String::with_capacity(4096),
            families: BTreeSet::new(),
            #[cfg(debug_assertions)]
            series: BTreeSet::new(),
        }
    }

    /// Declare a metric family once: `kind` is `counter` or `gauge`.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        if self.families.insert(name.to_string()) {
            let _ = writeln!(self.out, "# HELP {name} {help}");
            let _ = writeln!(self.out, "# TYPE {name} {kind}");
        }
    }

    /// Append one sample. Labels render as `name{k="v",..} value`;
    /// empty labels render bare.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: u64) {
        let series = self.render_series(name, labels);
        #[cfg(debug_assertions)]
        debug_assert!(
            self.series.insert(series.clone()),
            "duplicate metric series {series}"
        );
        let _ = writeln!(self.out, "{series} {value}");
    }

    fn render_series(&self, name: &str, labels: &[(&str, &str)]) -> String {
        if labels.is_empty() {
            return name.to_string();
        }
        let mut s = String::with_capacity(name.len() + 16);
        s.push_str(name);
        s.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{k}=\"{}\"", escape_label(v));
        }
        s.push('}');
        s
    }

    pub fn finish(self) -> String {
        self.out
    }
}

impl Default for Prom {
    fn default() -> Self {
        Prom::new()
    }
}

/// Prometheus label-value escaping: backslash, double quote, newline.
fn escape_label(v: &str) -> String {
    let mut s = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => s.push_str("\\\\"),
            '"' => s.push_str("\\\""),
            '\n' => s.push_str("\\n"),
            c => s.push(c),
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_families_once_and_samples_in_order() {
        let mut p = Prom::new();
        p.family("symphony_grants_total", "counter", "grants issued");
        p.sample("symphony_grants_total", &[("shard", "0")], 3);
        p.sample("symphony_grants_total", &[("shard", "1")], 5);
        p.family("symphony_grants_total", "counter", "grants issued");
        p.family("symphony_gpus_active", "gauge", "active GPUs");
        p.sample("symphony_gpus_active", &[], 4);
        let s = p.finish();
        assert_eq!(s.matches("# TYPE symphony_grants_total").count(), 1);
        assert!(s.contains("symphony_grants_total{shard=\"0\"} 3\n"));
        assert!(s.contains("symphony_grants_total{shard=\"1\"} 5\n"));
        assert!(s.contains("symphony_gpus_active 4\n"));
    }

    #[test]
    fn escapes_label_values() {
        let mut p = Prom::new();
        p.family("m", "counter", "x");
        p.sample("m", &[("peer", "a\"b\\c\nd")], 1);
        let s = p.finish();
        assert!(s.contains("m{peer=\"a\\\"b\\\\c\\nd\"} 1\n"), "{s}");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "duplicate metric series")]
    fn duplicate_series_panics_in_debug() {
        let mut p = Prom::new();
        p.family("m", "counter", "x");
        p.sample("m", &[("a", "1")], 1);
        p.sample("m", &[("a", "1")], 2);
    }
}
