//! The flight recorder: bounded, lock-free, shed-on-overflow request
//! lifecycle tracing.
//!
//! Design constraints, in priority order:
//!
//! 1. **Disabled tracing is free.** Every tap on the steady-state path
//!    ([`req_event`], [`model_event`]) starts with one relaxed load of
//!    a static sampling word and one predictable branch; when the word
//!    is zero nothing else runs — no clock read, no thread-local
//!    access, no allocation. `tests/alloc_free.rs` proves the
//!    zero-allocation half; `bench_hotpath`'s traced-vs-untraced probe
//!    measures the branch.
//! 2. **Enabled tracing never blocks the pipeline.** Sampled events
//!    ride one bounded [`crate::util::ring`] MPSC ring (the same
//!    Vyukov fabric the pipeline itself runs on) via a thread-cached
//!    sender clone; a full ring sheds the event into a counter
//!    (`try_send`, never `send`). A background drainer thread owns the
//!    receiver, so producers only ever pay a slot write.
//! 3. **Sessions are re-installable.** Tests and long-lived harnesses
//!    run `serve()` multiple times per process, so the recorder is not
//!    a `OnceLock`: [`install`] / [`TraceSession::finish`] swap the
//!    global sender under a mutex and bump an epoch word that
//!    invalidates every thread-cached sender (the cache re-clones on
//!    its next sampled event; meanwhile `SAMPLE == 0` already
//!    short-circuits the taps).
//!
//! Timestamps are integer micros from one process-wide `Instant`
//! origin (set at first install), so events from every thread —
//! ingest shards, model workers, rank shards, the wire client reader —
//! compare on a single monotone axis regardless of which `Clock`
//! domain their tier runs in.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::core::types::{ModelId, RequestId};
use crate::util::ring::{ring, RecvTimeoutError, RingReceiver, RingSender};
use crate::util::stats::LogHistogram;
use crate::util::sync::relock;

/// Capacity of the span ring. Sampled events beyond what the drainer
/// absorbs between wakeups shed into [`shed_count`].
pub const TRACE_RING_DEPTH: usize = 1 << 15;

/// Hard cap on events the drainer retains per session; everything past
/// it is counted as shed rather than growing the heap unboundedly.
const MAX_RETAINED: usize = 1 << 20;

/// A lifecycle tap point. Ordered the way a request traverses the
/// pipeline, so sorting a request's events by stage yields its
/// chronology; [`Stage::per_request`] distinguishes request-keyed
/// stages from the model-keyed (batch-rate) registration/grant/wire
/// stages.
#[repr(u8)]
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Frontend handed the request to an ingest shard (`submit`).
    Submit = 0,
    /// Ingest shard binned it into a per-model burst.
    IngestBin = 1,
    /// Model worker absorbed it into the tracking queue.
    WorkerRecv = 2,
    /// Model-keyed: the router (re)registered a candidate window.
    CandReg = 3,
    /// Model-keyed: the wire client encoded a Candidate frame.
    WireCandTx = 4,
    /// Model-keyed: a rank shard granted the candidate a GPU.
    RankGrant = 5,
    /// Model-keyed: the wire client decoded a Granted frame.
    WireGrantRx = 6,
    /// Model worker received the grant for the batch holding this
    /// request.
    GrantRecv = 7,
    /// Model worker dispatched the batch to a backend GPU.
    Dispatch = 8,
    /// Completion collector saw the request finish.
    Complete = 9,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Submit => "submit",
            Stage::IngestBin => "ingest_bin",
            Stage::WorkerRecv => "worker_recv",
            Stage::CandReg => "cand_reg",
            Stage::WireCandTx => "wire_cand_tx",
            Stage::RankGrant => "rank_grant",
            Stage::WireGrantRx => "wire_grant_rx",
            Stage::GrantRecv => "grant_recv",
            Stage::Dispatch => "dispatch",
            Stage::Complete => "complete",
        }
    }

    /// Request-keyed stages form the per-request hop chain; the rest
    /// are model-keyed batch-rate events (a registration or grant
    /// covers every request in the candidate batch).
    pub fn per_request(self) -> bool {
        matches!(
            self,
            Stage::Submit
                | Stage::IngestBin
                | Stage::WorkerRecv
                | Stage::GrantRecv
                | Stage::Dispatch
                | Stage::Complete
        )
    }
}

/// One recorded tap: 24 bytes, `Copy`, no heap — a ring slot write.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub stage: Stage,
    /// `RequestId.0` for request-keyed stages, `ModelId.0` for
    /// model-keyed ones.
    pub key: u64,
    /// Micros since the recorder origin (one process-wide axis).
    pub t_us: u64,
}

/// 0 = disabled. Otherwise the power-of-two sampling interval N:
/// request id `id` is sampled iff `id & (N - 1) == 0`. This is the
/// ONE word every tap loads on the steady-state path.
static SAMPLE: AtomicU64 = AtomicU64::new(0);
/// Bumped on every install/finish; a mismatch tells a thread its
/// cached sender belongs to a dead session.
static EPOCH: AtomicU64 = AtomicU64::new(0);
/// Sampled events dropped: ring full, retained cap hit, or no live
/// session at emit time.
static SHED: AtomicU64 = AtomicU64::new(0);
/// The live session's sender, cloned into thread caches on demand.
static SOURCE: Mutex<Option<RingSender<Event>>> = Mutex::new(None);
/// Process-wide time origin for all trace timestamps (set at first
/// install, never reset — monotonicity must survive re-installs).
static ORIGIN: OnceLock<Instant> = OnceLock::new();

thread_local! {
    /// Per-thread cached (epoch, sender). `const` init keeps first
    /// access allocation-free.
    static TL_TX: RefCell<Option<(u64, RingSender<Event>)>> = const { RefCell::new(None) };
}

/// Record a request-keyed lifecycle event. Disabled cost: one relaxed
/// load + one predictable branch, zero allocations.
#[inline]
pub fn req_event(stage: Stage, req: RequestId) {
    let n = SAMPLE.load(Ordering::Relaxed);
    if n == 0 {
        return;
    }
    if req.0 & (n - 1) != 0 {
        return;
    }
    emit(stage, req.0);
}

/// Record a model-keyed (batch-rate) event: candidate registration,
/// rank grant, wire encode/decode. Not subsampled — these are already
/// batch-rate, and the invariant checks need every grant paired with
/// its registration.
#[inline]
pub fn model_event(stage: Stage, model: ModelId) {
    if SAMPLE.load(Ordering::Relaxed) == 0 {
        return;
    }
    emit(stage, u64::from(model.0));
}

/// True while a session is live (used by benches to verify the probe's
/// two arms really differ).
pub fn enabled() -> bool {
    SAMPLE.load(Ordering::Relaxed) != 0
}

/// Sampled events dropped so far this session.
pub fn shed_count() -> u64 {
    SHED.load(Ordering::Relaxed)
}

#[inline]
fn emit(stage: Stage, key: u64) {
    let Some(origin) = ORIGIN.get() else {
        return;
    };
    let ev = Event {
        stage,
        key,
        t_us: origin.elapsed().as_micros() as u64,
    };
    let epoch = EPOCH.load(Ordering::Acquire);
    let sent = TL_TX.with(|tl| {
        let mut tl = tl.borrow_mut();
        let stale = match &*tl {
            Some((e, _)) => *e != epoch,
            None => true,
        };
        if stale {
            *tl = relock(&SOURCE).clone().map(|tx| (epoch, tx));
        }
        match &*tl {
            Some((_, tx)) => tx.try_send(ev).is_ok(),
            None => false,
        }
    });
    if !sent {
        SHED.fetch_add(1, Ordering::Relaxed);
    }
}

/// A live recorder session: owns the drainer thread accumulating the
/// sampled events. Exactly one session is live at a time; [`install`]
/// returns `None` while another holds the recorder.
pub struct TraceSession {
    stop: Arc<AtomicBool>,
    drainer: Option<JoinHandle<Vec<Event>>>,
}

/// Install the global recorder, sampling 1 request in
/// `sample_n.next_power_of_two()`. Returns `None` if a session is
/// already live (first install wins — concurrent `serve()` runs in one
/// process trace only the first).
pub fn install(sample_n: u64) -> Option<TraceSession> {
    let mut src = relock(&SOURCE);
    if src.is_some() {
        return None;
    }
    ORIGIN.get_or_init(Instant::now);
    let (tx, rx) = ring::<Event>(TRACE_RING_DEPTH);
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let drainer = std::thread::Builder::new()
        .name("obs-trace-drain".into())
        .spawn(move || drain_loop(rx, stop2))
        .ok()?;
    *src = Some(tx);
    SHED.store(0, Ordering::Relaxed);
    EPOCH.fetch_add(1, Ordering::Release);
    SAMPLE.store(sample_n.max(1).next_power_of_two(), Ordering::Relaxed);
    Some(TraceSession {
        stop,
        drainer: Some(drainer),
    })
}

fn drain_loop(rx: RingReceiver<Event>, stop: Arc<AtomicBool>) -> Vec<Event> {
    let mut out: Vec<Event> = Vec::new();
    let mut push = |out: &mut Vec<Event>, ev: Event| {
        if out.len() < MAX_RETAINED {
            out.push(ev);
        } else {
            SHED.fetch_add(1, Ordering::Relaxed);
        }
    };
    loop {
        while let Ok(ev) = rx.try_recv() {
            push(&mut out, ev);
        }
        if stop.load(Ordering::Acquire) {
            break;
        }
        match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(ev) => push(&mut out, ev),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // Final sweep: events emitted between the stop flag and the taps
    // observing SAMPLE == 0.
    while let Ok(ev) = rx.try_recv() {
        push(&mut out, ev);
    }
    out
}

impl TraceSession {
    /// Tear the recorder down and return everything it captured. Taps
    /// see `SAMPLE == 0` immediately; thread-cached senders for the
    /// dead session are dropped lazily on each thread's next sampled
    /// event (a later session's epoch bump).
    pub fn finish(mut self) -> TraceDump {
        SAMPLE.store(0, Ordering::Relaxed);
        *relock(&SOURCE) = None;
        EPOCH.fetch_add(1, Ordering::Release);
        self.stop.store(true, Ordering::Release);
        let events = match self.drainer.take() {
            Some(h) => h.join().unwrap_or_default(),
            None => Vec::new(),
        };
        TraceDump {
            events,
            shed: SHED.load(Ordering::Relaxed),
        }
    }
}

impl Drop for TraceSession {
    fn drop(&mut self) {
        // A dropped (not finished) session still releases the global
        // recorder so a later install works.
        if self.drainer.is_some() {
            SAMPLE.store(0, Ordering::Relaxed);
            *relock(&SOURCE) = None;
            EPOCH.fetch_add(1, Ordering::Release);
            self.stop.store(true, Ordering::Release);
            if let Some(h) = self.drainer.take() {
                let _ = h.join();
            }
        }
    }
}

/// Everything one session recorded, plus its shed count.
pub struct TraceDump {
    pub events: Vec<Event>,
    pub shed: u64,
}

/// A per-hop latency summary row for `ServeReport`.
#[derive(Clone, Debug)]
pub struct HopStat {
    /// `"submit→ingest_bin"`, `"dispatch→complete"`, … — consecutive
    /// *observed* request stages, so a hop absent from a run's taps
    /// simply folds into its neighbor.
    pub hop: String,
    pub count: u64,
    pub p50_us: u64,
    pub p99_us: u64,
}

impl TraceDump {
    /// Group request-keyed events by request and stage-order them.
    fn by_request(&self) -> BTreeMap<u64, Vec<(Stage, u64)>> {
        let mut reqs: BTreeMap<u64, Vec<(Stage, u64)>> = BTreeMap::new();
        for ev in &self.events {
            if ev.stage.per_request() {
                reqs.entry(ev.key).or_default().push((ev.stage, ev.t_us));
            }
        }
        for evs in reqs.values_mut() {
            evs.sort();
        }
        reqs
    }

    /// Aggregate sampled spans into per-hop p50/p99 rows (stage-pair →
    /// log-bucketed histogram), ordered by pipeline position.
    pub fn hop_breakdown(&self) -> Vec<HopStat> {
        let mut hists: BTreeMap<(Stage, Stage), LogHistogram> = BTreeMap::new();
        for evs in self.by_request().values() {
            for w in evs.windows(2) {
                let ((a, ta), (b, tb)) = (w[0], w[1]);
                if a == b {
                    continue;
                }
                hists
                    .entry((a, b))
                    .or_insert_with(LogHistogram::new)
                    .add(tb.saturating_sub(ta));
            }
        }
        hists
            .into_iter()
            .map(|((a, b), h)| HopStat {
                hop: format!("{}→{}", a.name(), b.name()),
                count: h.count(),
                p50_us: h.quantile(0.50),
                p99_us: h.quantile(0.99),
            })
            .collect()
    }

    /// The span accounting invariants the recorder promises:
    /// per-request wall-clock monotonicity in stage order, the sum of
    /// per-hop spans bounded by the end-to-end latency, and no rank
    /// grant before its model ever registered a candidate.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (req, evs) in self.by_request() {
            for w in evs.windows(2) {
                let ((a, ta), (b, tb)) = (w[0], w[1]);
                if tb < ta {
                    return Err(format!(
                        "req {req}: {} at {tb}µs precedes {} at {ta}µs",
                        b.name(),
                        a.name()
                    ));
                }
            }
            if let (Some((_, first)), Some((_, last))) = (evs.first(), evs.last()) {
                let hop_sum: u64 = evs
                    .windows(2)
                    .map(|w| w[1].1.saturating_sub(w[0].1))
                    .sum();
                if hop_sum > last.saturating_sub(*first) {
                    return Err(format!(
                        "req {req}: hop spans sum to {hop_sum}µs > end-to-end {}µs",
                        last.saturating_sub(*first)
                    ));
                }
            }
        }
        // Grant never precedes registration, per model.
        let mut first_reg: BTreeMap<u64, u64> = BTreeMap::new();
        for ev in &self.events {
            if ev.stage == Stage::CandReg {
                let e = first_reg.entry(ev.key).or_insert(ev.t_us);
                *e = (*e).min(ev.t_us);
            }
        }
        for ev in &self.events {
            if ev.stage == Stage::RankGrant {
                match first_reg.get(&ev.key) {
                    Some(reg) if *reg <= ev.t_us => {}
                    Some(reg) => {
                        return Err(format!(
                            "model {}: grant at {}µs precedes first registration at {reg}µs",
                            ev.key, ev.t_us
                        ));
                    }
                    None => {
                        return Err(format!(
                            "model {}: grant at {}µs with no registration ever recorded",
                            ev.key, ev.t_us
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Dump as a Chrome trace-event JSON array (loadable in Perfetto /
    /// `chrome://tracing`). Request-keyed events land in pid 1 with
    /// tid = request id (instants `ph:"i"` plus derived `ph:"X"` hop
    /// spans); model-keyed events land in pid 2 with tid = model id.
    /// Hand-rolled JSON — offline registry, same as the bench writers.
    pub fn write_chrome_trace(&self, path: &Path) -> std::io::Result<()> {
        let mut lines: Vec<String> = Vec::with_capacity(self.events.len() + 64);
        for ev in &self.events {
            let pid = if ev.stage.per_request() { 1 } else { 2 };
            lines.push(format!(
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"ts\":{},\"pid\":{},\"tid\":{},\"s\":\"t\",\"args\":{{\"key\":{}}}}}",
                ev.stage.name(),
                if pid == 1 { "req" } else { "model" },
                ev.t_us,
                pid,
                ev.key,
                ev.key
            ));
        }
        for (req, evs) in self.by_request() {
            for w in evs.windows(2) {
                let ((a, ta), (b, tb)) = (w[0], w[1]);
                if a == b {
                    continue;
                }
                lines.push(format!(
                    "{{\"name\":\"{}→{}\",\"cat\":\"hop\",\"ph\":\"X\",\"ts\":{ta},\"dur\":{},\"pid\":1,\"tid\":{req}}}",
                    a.name(),
                    b.name(),
                    tb.saturating_sub(ta)
                ));
            }
        }
        lines.push(format!(
            "{{\"name\":\"trace_shed\",\"ph\":\"C\",\"ts\":0,\"pid\":1,\"args\":{{\"shed\":{}}}}}",
            self.shed
        ));
        let mut f = std::fs::File::create(path)?;
        f.write_all(b"[\n")?;
        for (i, l) in lines.iter().enumerate() {
            let sep = if i + 1 < lines.len() { "," } else { "" };
            f.write_all(l.as_bytes())?;
            f.write_all(sep.as_bytes())?;
            f.write_all(b"\n")?;
        }
        f.write_all(b"]\n")?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(stage: Stage, key: u64, t_us: u64) -> Event {
        Event { stage, key, t_us }
    }

    #[test]
    fn hop_breakdown_and_invariants_on_synthetic_events() {
        let dump = TraceDump {
            events: vec![
                ev(Stage::Submit, 0, 100),
                ev(Stage::IngestBin, 0, 110),
                ev(Stage::WorkerRecv, 0, 130),
                ev(Stage::GrantRecv, 0, 500),
                ev(Stage::Dispatch, 0, 510),
                ev(Stage::Complete, 0, 900),
                ev(Stage::CandReg, 3, 140),
                ev(Stage::RankGrant, 3, 490),
            ],
            shed: 0,
        };
        dump.check_invariants().expect("clean trace");
        let hops = dump.hop_breakdown();
        assert_eq!(hops.len(), 5, "{hops:?}");
        let e2e: u64 = hops.iter().map(|h| h.p50_us).sum();
        // Log-bucket representatives can exceed exact values by the
        // bucket's relative error, but the sum stays in the ballpark.
        assert!(e2e >= 700 && e2e <= 1000, "hop p50 sum {e2e}");
        assert!(hops.iter().all(|h| h.count == 1));
    }

    #[test]
    fn invariants_catch_grant_before_registration() {
        let dump = TraceDump {
            events: vec![
                ev(Stage::CandReg, 7, 200),
                ev(Stage::RankGrant, 7, 150),
            ],
            shed: 0,
        };
        let err = dump.check_invariants().unwrap_err();
        assert!(err.contains("precedes first registration"), "{err}");
    }

    #[test]
    fn invariants_catch_unregistered_grant() {
        let dump = TraceDump {
            events: vec![ev(Stage::RankGrant, 9, 10)],
            shed: 0,
        };
        let err = dump.check_invariants().unwrap_err();
        assert!(err.contains("no registration"), "{err}");
    }

    #[test]
    fn install_records_and_finish_drains() {
        // Serialized with other recorder tests by the module-global
        // recorder: install fails while a peer holds it, so retry.
        let session = loop {
            match install(1) {
                Some(s) => break s,
                None => std::thread::sleep(Duration::from_millis(5)),
            }
        };
        assert!(enabled());
        req_event(Stage::Submit, RequestId(1));
        req_event(Stage::Complete, RequestId(1));
        model_event(Stage::CandReg, ModelId(0));
        let dump = session.finish();
        assert!(!enabled());
        assert_eq!(dump.events.len(), 3, "{:?}", dump.events);
        dump.check_invariants().expect("clean");
        // Disabled taps are no-ops.
        req_event(Stage::Submit, RequestId(2));
    }

    #[test]
    fn sampling_mask_filters_requests() {
        let session = loop {
            match install(4) {
                Some(s) => break s,
                None => std::thread::sleep(Duration::from_millis(5)),
            }
        };
        for id in 0..16u64 {
            req_event(Stage::Submit, RequestId(id));
        }
        let dump = session.finish();
        // ids 0, 4, 8, 12 pass `id & 3 == 0`.
        assert_eq!(dump.events.len(), 4, "{:?}", dump.events);
        assert!(dump.events.iter().all(|e| e.key % 4 == 0));
    }
}
