//! Sub-cluster partitioning (§4.4, Appendix A): assign models to
//! sub-clusters minimizing `ΔR + w·ΔS` (deviation of per-sub-cluster
//! request rate and static memory from their means) subject to
//! per-sub-cluster rate and memory capacity and a bound on reassignment
//! (loading/unloading) cost.
//!
//! The paper solves the MILP approximately under a 10 s CPLEX budget and
//! shows that beats random search (Fig 16). We reproduce that
//! comparison with a greedy seed + simulated-annealing local search
//! under the same wall-clock budget, and the same random-search
//! baseline.

use std::time::{Duration, Instant};

use crate::util::rng::Rng;

/// One model's partitioning-relevant attributes.
#[derive(Clone, Copy, Debug)]
pub struct ModelLoad {
    /// Request rate r_i (req/s).
    pub rate: f64,
    /// Static (weights) memory s_i, MB.
    pub static_mem: f64,
    /// Peak dynamic memory d_i, MB.
    pub dyn_mem: f64,
}

/// The MILP instance.
#[derive(Clone, Debug)]
pub struct PartitionProblem {
    pub models: Vec<ModelLoad>,
    /// Number of sub-clusters l.
    pub parts: usize,
    /// Max request rate per sub-cluster (dispatcher capability).
    pub rate_cap: f64,
    /// Max memory per backend (static sum + max dynamic ≤ cap).
    pub mem_cap: f64,
    /// Objective weight w between ΔR and ΔS.
    pub w: f64,
    /// Optional current assignment + switching-cost bound (disruption
    /// minimization): `(previous assignment, per-model move cost, C_max)`.
    pub disruption: Option<(Vec<usize>, Vec<f64>, f64)>,
}

/// An assignment: `assign[i]` = sub-cluster of model i.
pub type Assignment = Vec<usize>;

impl PartitionProblem {
    pub fn mean_rate(&self) -> f64 {
        self.models.iter().map(|m| m.rate).sum::<f64>() / self.parts as f64
    }

    pub fn mean_mem(&self) -> f64 {
        self.models.iter().map(|m| m.static_mem).sum::<f64>() / self.parts as f64
    }

    /// Per-part (rate, static_mem, max_dyn) aggregates.
    fn aggregates(&self, a: &Assignment) -> Vec<(f64, f64, f64)> {
        let mut agg = vec![(0.0, 0.0, 0.0f64); self.parts];
        for (i, m) in self.models.iter().enumerate() {
            let p = a[i];
            agg[p].0 += m.rate;
            agg[p].1 += m.static_mem;
            agg[p].2 = agg[p].2.max(m.dyn_mem);
        }
        agg
    }

    /// Constraint check (4), (5), (10).
    pub fn feasible(&self, a: &Assignment) -> bool {
        if a.len() != self.models.len() || a.iter().any(|&p| p >= self.parts) {
            return false;
        }
        for &(r, s, d) in &self.aggregates(a) {
            if r > self.rate_cap || s + d > self.mem_cap {
                return false;
            }
        }
        if let Some((prev, costs, cmax)) = &self.disruption {
            let moved: f64 = a
                .iter()
                .zip(prev)
                .zip(costs)
                .filter(|((now, was), _)| now != was)
                // y_ij flips both the old and new sub-cluster entries;
                // cost counts the load + unload (symmetric).
                .map(|(_, c)| 2.0 * c)
                .sum();
            if moved > *cmax {
                return false;
            }
        }
        true
    }

    /// Objective (3): ΔR + w·ΔS (max deviation from the means).
    pub fn objective(&self, a: &Assignment) -> f64 {
        let rbar = self.mean_rate();
        let sbar = self.mean_mem();
        let mut dr: f64 = 0.0;
        let mut ds: f64 = 0.0;
        for &(r, s, _) in &self.aggregates(a) {
            dr = dr.max((r - rbar).abs());
            ds = ds.max((s - sbar).abs());
        }
        dr + self.w * ds
    }

    /// Imbalance factors (Appendix A.2): `(max − min)/avg` for rate and
    /// static memory.
    pub fn imbalance(&self, a: &Assignment) -> (f64, f64) {
        let agg = self.aggregates(a);
        let (mut rmin, mut rmax, mut smin, mut smax) =
            (f64::MAX, f64::MIN, f64::MAX, f64::MIN);
        for &(r, s, _) in &agg {
            rmin = rmin.min(r);
            rmax = rmax.max(r);
            smin = smin.min(s);
            smax = smax.max(s);
        }
        let rbar = self.mean_rate();
        let sbar = self.mean_mem();
        ((rmax - rmin) / rbar.max(1e-12), (smax - smin) / sbar.max(1e-12))
    }
}

/// Greedy seed: models by descending rate, each to the feasible part
/// with the lowest current objective contribution (LPT-style).
pub fn greedy(p: &PartitionProblem) -> Option<Assignment> {
    let mut order: Vec<usize> = (0..p.models.len()).collect();
    order.sort_by(|&a, &b| p.models[b].rate.partial_cmp(&p.models[a].rate).unwrap());
    let mut assign = vec![usize::MAX; p.models.len()];
    let mut agg = vec![(0.0f64, 0.0f64, 0.0f64); p.parts];
    for &i in &order {
        let m = p.models[i];
        // Pick the feasible part minimizing the balance score.
        let mut best: Option<(f64, usize)> = None;
        for part in 0..p.parts {
            let (r, s, d) = agg[part];
            if r + m.rate > p.rate_cap || s + m.static_mem + d.max(m.dyn_mem) > p.mem_cap
            {
                continue;
            }
            let score = (r + m.rate) + p.w * (s + m.static_mem);
            if best.map_or(true, |(b, _)| score < b) {
                best = Some((score, part));
            }
        }
        let (_, part) = best?;
        assign[i] = part;
        agg[part].0 += m.rate;
        agg[part].1 += m.static_mem;
        agg[part].2 = agg[part].2.max(m.dyn_mem);
    }
    // Greedy ignores the disruption bound; callers repair via annealing.
    Some(assign)
}

/// Simulated-annealing local search from a seed, within a time budget.
pub fn anneal(
    p: &PartitionProblem,
    seed: Assignment,
    budget: Duration,
    rng: &mut Rng,
) -> Assignment {
    let n = p.models.len();
    let mut cur = seed.clone();
    let mut cur_obj = p.objective(&cur);
    let mut best = cur.clone();
    let mut best_obj = cur_obj;
    let t0 = Instant::now();
    let mut temp = (cur_obj * 0.25).max(1e-6);
    let mut iters = 0u64;
    while t0.elapsed() < budget {
        iters += 1;
        // Move: relocate one model, or swap two models' parts.
        let mut cand = cur.clone();
        if rng.f64() < 0.7 {
            let i = rng.below(n as u64) as usize;
            cand[i] = rng.below(p.parts as u64) as usize;
        } else {
            let i = rng.below(n as u64) as usize;
            let j = rng.below(n as u64) as usize;
            cand.swap(i, j);
        }
        if !p.feasible(&cand) {
            continue;
        }
        let obj = p.objective(&cand);
        let accept = obj <= cur_obj || rng.f64() < ((cur_obj - obj) / temp).exp();
        if accept {
            cur = cand;
            cur_obj = obj;
            if cur_obj < best_obj {
                best = cur.clone();
                best_obj = cur_obj;
            }
        }
        // Geometric cooling tied to iterations.
        if iters % 512 == 0 {
            temp = (temp * 0.97).max(1e-9);
        }
    }
    best
}

/// The paper's solver pipeline: greedy seed (fall back to round-robin)
/// + annealing under the budget. Returns `None` only if no feasible
/// assignment was found at all.
pub fn solve(p: &PartitionProblem, budget: Duration, rng: &mut Rng) -> Option<Assignment> {
    let mut seed = greedy(p).unwrap_or_else(|| {
        (0..p.models.len()).map(|i| i % p.parts).collect()
    });
    if !p.feasible(&seed) {
        // Try the previous assignment if disruption-bounded.
        if let Some((prev, _, _)) = &p.disruption {
            if p.feasible(prev) {
                seed = prev.clone();
            }
        }
    }
    let out = anneal(p, seed, budget, rng);
    if p.feasible(&out) {
        Some(out)
    } else {
        None
    }
}

/// The Appendix A.2 baseline: repeated random assignments under the same
/// time budget, keeping the best feasible one.
pub fn random_search(
    p: &PartitionProblem,
    budget: Duration,
    rng: &mut Rng,
) -> Option<Assignment> {
    let t0 = Instant::now();
    let n = p.models.len();
    let mut best: Option<(f64, Assignment)> = None;
    while t0.elapsed() < budget {
        let cand: Assignment = (0..n).map(|_| rng.below(p.parts as u64) as usize).collect();
        if !p.feasible(&cand) {
            continue;
        }
        let obj = p.objective(&cand);
        if best.as_ref().map_or(true, |(b, _)| obj < *b) {
            best = Some((obj, cand));
        }
    }
    best.map(|(_, a)| a)
}

/// Generate a random partitioning instance from zoo-like statistics
/// (Appendix A.2's setup: many specialized model variants, exponential
/// request rates).
pub fn random_instance(
    n_models: usize,
    parts: usize,
    rng: &mut Rng,
) -> PartitionProblem {
    let models: Vec<ModelLoad> = (0..n_models)
        .map(|_| ModelLoad {
            rate: 50.0 * rng.exp1(),
            static_mem: 80.0 + 400.0 * rng.f64(),
            dyn_mem: 20.0 + 100.0 * rng.f64(),
        })
        .collect();
    let total_rate: f64 = models.iter().map(|m| m.rate).sum();
    let total_mem: f64 = models.iter().map(|m| m.static_mem).sum();
    PartitionProblem {
        models,
        parts,
        // Caps ~1.6x the mean leave headroom but bind occasionally.
        rate_cap: 1.6 * total_rate / parts as f64,
        mem_cap: 1.6 * total_mem / parts as f64 + 150.0,
        w: 0.5,
        disruption: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> PartitionProblem {
        PartitionProblem {
            models: vec![
                ModelLoad { rate: 10.0, static_mem: 100.0, dyn_mem: 10.0 },
                ModelLoad { rate: 20.0, static_mem: 100.0, dyn_mem: 10.0 },
                ModelLoad { rate: 30.0, static_mem: 100.0, dyn_mem: 10.0 },
                ModelLoad { rate: 40.0, static_mem: 100.0, dyn_mem: 10.0 },
            ],
            parts: 2,
            rate_cap: 60.0,
            mem_cap: 250.0,
            w: 0.1,
            disruption: None,
        }
    }

    #[test]
    fn objective_prefers_balance() {
        let p = tiny();
        // {40,10} vs {30,20}: perfectly balanced rate 50/50.
        let balanced = vec![1, 0, 0, 1];
        // {40,30} vs {20,10}: rate 70/30 — also infeasible (70 > 60).
        let skewed = vec![0, 0, 1, 1];
        assert!(p.feasible(&balanced));
        assert!(!p.feasible(&skewed));
        assert!(p.objective(&balanced) < 1e-9);
    }

    #[test]
    fn greedy_finds_feasible_balance() {
        let p = tiny();
        let a = greedy(&p).expect("feasible");
        assert!(p.feasible(&a));
        assert!(p.objective(&a) <= 10.0 + 1e-9);
    }

    #[test]
    fn solve_beats_random_on_bigger_instances() {
        let mut rng = Rng::new(77);
        let p = random_instance(120, 6, &mut rng);
        let budget = Duration::from_millis(150);
        let ours = solve(&p, budget, &mut rng).expect("solver feasible");
        let rand = random_search(&p, budget, &mut rng).expect("random feasible");
        let (o, r) = (p.objective(&ours), p.objective(&rand));
        assert!(o <= r, "solver {o} vs random {r}");
        let (imb_r, _) = p.imbalance(&ours);
        let (imb_rand, _) = p.imbalance(&rand);
        assert!(imb_r <= imb_rand * 1.05, "imbalance {imb_r} vs {imb_rand}");
    }

    #[test]
    fn disruption_bound_enforced() {
        let mut p = tiny();
        let prev = vec![0, 0, 1, 1];
        // Moving any model costs 10 (x2 for load+unload); C_max = 15
        // allows zero moves.
        p.disruption = Some((prev.clone(), vec![10.0; 4], 15.0));
        assert!(!p.feasible(&vec![1, 0, 0, 1]));
        // Note prev itself violates rate_cap (70>60) — relax caps so the
        // stay-put assignment is checkable.
        p.rate_cap = 100.0;
        assert!(p.feasible(&prev));
    }

    #[test]
    fn imbalance_zero_when_equal() {
        let p = tiny();
        let a = vec![1, 0, 0, 1];
        let (ri, si) = p.imbalance(&a);
        assert!(ri.abs() < 1e-9);
        assert!(si.abs() < 1e-9);
    }
}
