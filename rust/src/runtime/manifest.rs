//! Parsers for the build-time artifact metadata: `manifest.tsv` (batch
//! size → artifact path) and `profile.tsv` (measured CPU ℓ(b) + fitted
//! α/β) written by `python/compile/aot.py`.

use std::fs;
use std::path::Path;

use crate::bail;
use crate::util::error::{Context, Result};

use crate::core::profile::LatencyProfile;

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub batch_size: u32,
    pub artifact: String,
    pub input_shape: String,
    pub output_shape: String,
}

/// Parsed `manifest.tsv`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().context("empty manifest")?;
        if !header.starts_with("batch_size\t") {
            bail!("unexpected manifest header: {header}");
        }
        let mut entries = Vec::new();
        for (i, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                bail!("manifest line {} malformed: {line}", i + 2);
            }
            entries.push(ManifestEntry {
                batch_size: cols[0].parse().context("batch_size")?,
                artifact: cols[1].to_string(),
                input_shape: cols[2].to_string(),
                output_shape: cols[3].to_string(),
            });
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(Manifest { entries })
    }
}

/// Parsed `profile.tsv`: measured per-batch latency + fitted α/β.
#[derive(Clone, Debug)]
pub struct MeasuredProfile {
    pub fitted: LatencyProfile,
    /// (batch_size, measured ms).
    pub points: Vec<(u32, f64)>,
}

impl MeasuredProfile {
    pub fn load(path: &Path) -> Result<Self> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut alpha = None;
        let mut beta = None;
        let mut points = Vec::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# fitted ") {
                for tok in rest.split_whitespace() {
                    if let Some(v) = tok.strip_prefix("alpha_ms=") {
                        alpha = v.parse::<f64>().ok();
                    } else if let Some(v) = tok.strip_prefix("beta_ms=") {
                        beta = v.parse::<f64>().ok();
                    }
                }
            } else if !line.starts_with('#') && !line.starts_with("batch_size") {
                let cols: Vec<&str> = line.split('\t').collect();
                if cols.len() == 2 {
                    if let (Ok(b), Ok(ms)) = (cols[0].parse(), cols[1].parse()) {
                        points.push((b, ms));
                    }
                }
            }
        }
        let (Some(a), Some(b)) = (alpha, beta) else {
            bail!("profile.tsv missing fitted alpha/beta");
        };
        // The CPU fit can produce a tiny or even negative beta; clamp to
        // a small positive cost so ℓ stays a valid profile.
        Ok(MeasuredProfile {
            fitted: LatencyProfile::new(a.max(1e-6), b.max(0.0)),
            points,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest() {
        let text = "batch_size\tartifact\tinput_shape\toutput_shape\n\
                    1\tmodel_b1.hlo.txt\t1x32x32x3\t1x64\n\
                    8\tmodel_b8.hlo.txt\t8x32x32x3\t8x64\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.entries[1].batch_size, 8);
        assert_eq!(m.entries[1].artifact, "model_b8.hlo.txt");
    }

    #[test]
    fn parse_manifest_rejects_garbage() {
        assert!(Manifest::parse("nope\n").is_err());
        assert!(Manifest::parse("batch_size\tartifact\tinput_shape\toutput_shape\n").is_err());
        assert!(Manifest::parse(
            "batch_size\tartifact\tinput_shape\toutput_shape\n1\tonly-two\n"
        )
        .is_err());
    }

    #[test]
    fn parse_profile() {
        let text = "# fitted alpha_ms=0.036000 beta_ms=0.058000\n\
                    batch_size\tlatency_ms\n1\t0.1\n2\t0.13\n";
        let p = MeasuredProfile::parse(text).unwrap();
        assert!((p.fitted.alpha_ms - 0.036).abs() < 1e-9);
        assert!((p.fitted.beta_ms - 0.058).abs() < 1e-9);
        assert_eq!(p.points.len(), 2);
    }

    #[test]
    fn parse_profile_requires_fit() {
        assert!(MeasuredProfile::parse("batch_size\tlatency_ms\n1\t0.1\n").is_err());
    }
}
