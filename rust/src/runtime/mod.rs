//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO **text** — see /opt/xla-example: the
//! bundled xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized
//! protos, while the text parser reassigns ids) and executes them on the
//! PJRT CPU client. One compiled executable per batch size; Python never
//! runs on the request path.
//!
//! The PJRT client comes from the external `xla` crate, which is not in
//! the offline registry; the execution path is therefore gated behind
//! the `pjrt` cargo feature. The default build ships a stub
//! [`ModelRuntime`] with the same API whose `load` fails, so the
//! manifest/profile parsers, the serving stack, and every scheduler
//! experiment build and run with zero external dependencies.

pub mod manifest;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::Result;
#[cfg(feature = "pjrt")]
use crate::util::error::Context;

pub use manifest::{Manifest, MeasuredProfile};

/// Input image dims baked into the artifacts (model.py).
pub const IMAGE_DIM: usize = 32;
pub const IMAGE_CHANNELS: usize = 3;
pub const NUM_CLASSES: usize = 64;

/// A loaded model: PJRT executables keyed by batch size.
#[cfg(feature = "pjrt")]
pub struct ModelRuntime {
    client: xla::PjRtClient,
    executables: BTreeMap<u32, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    pub profile: Option<MeasuredProfile>,
}

#[cfg(feature = "pjrt")]
impl ModelRuntime {
    /// Load every artifact listed in `<dir>/manifest.tsv` and compile it
    /// on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.tsv"))
            .with_context(|| format!("reading manifest in {}", dir.display()))?;
        let profile = MeasuredProfile::load(&dir.join("profile.tsv")).ok();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = BTreeMap::new();
        for entry in &manifest.entries {
            let path: PathBuf = dir.join(&entry.artifact);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path utf-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            executables.insert(entry.batch_size, exe);
        }
        if executables.is_empty() {
            crate::bail!("no artifacts in {}", dir.display());
        }
        Ok(ModelRuntime {
            client,
            executables,
            manifest,
            profile,
        })
    }

    /// Batch sizes with a compiled executable, ascending.
    pub fn batch_sizes(&self) -> Vec<u32> {
        self.executables.keys().copied().collect()
    }

    /// Smallest compiled batch size ≥ `n` (or the largest available).
    pub fn padded_batch(&self, n: u32) -> u32 {
        self.executables
            .range(n..)
            .next()
            .map(|(&b, _)| b)
            .unwrap_or_else(|| *self.executables.keys().last().unwrap())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute one batch of `n` requests. `inputs` is row-major
    /// `[n, 32, 32, 3]` f32 (extra rows beyond `n` are padding). Returns
    /// the `[n, NUM_CLASSES]` probabilities (padding rows stripped).
    pub fn execute(&self, n: u32, inputs: &[f32]) -> Result<Vec<f32>> {
        let padded = self.padded_batch(n);
        let exe = &self.executables[&padded];
        let per_row = IMAGE_DIM * IMAGE_DIM * IMAGE_CHANNELS;
        let want = padded as usize * per_row;
        let mut buf = vec![0f32; want];
        let have = (n as usize * per_row).min(inputs.len());
        buf[..have].copy_from_slice(&inputs[..have]);
        let lit = xla::Literal::vec1(&buf).reshape(&[
            padded as i64,
            IMAGE_DIM as i64,
            IMAGE_DIM as i64,
            IMAGE_CHANNELS as i64,
        ])?;
        let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True -> unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let probs = out.to_vec::<f32>()?;
        Ok(probs[..n as usize * NUM_CLASSES].to_vec())
    }
}

/// Stub runtime for builds without the `pjrt` feature: same API, but
/// `load` always fails, so callers fall back exactly as they do when
/// `artifacts/` has not been built.
#[cfg(not(feature = "pjrt"))]
pub struct ModelRuntime {
    executables: BTreeMap<u32, ()>,
    pub manifest: Manifest,
    pub profile: Option<MeasuredProfile>,
}

#[cfg(not(feature = "pjrt"))]
impl ModelRuntime {
    pub fn load(dir: &Path) -> Result<Self> {
        crate::bail!(
            "PJRT runtime disabled: rebuild with `--features pjrt` (and the \
             `xla` crate available) to execute artifacts in {}",
            dir.display()
        )
    }

    pub fn batch_sizes(&self) -> Vec<u32> {
        self.executables.keys().copied().collect()
    }

    pub fn padded_batch(&self, n: u32) -> u32 {
        self.executables
            .range(n..)
            .next()
            .map(|(&b, _)| b)
            .unwrap_or(n)
    }

    pub fn platform(&self) -> String {
        "stub (built without the pjrt feature)".to_string()
    }

    pub fn execute(&self, _n: u32, _inputs: &[f32]) -> Result<Vec<f32>> {
        crate::bail!("PJRT runtime disabled: rebuild with `--features pjrt`")
    }
}

/// Locate `artifacts/` relative to the repo root (works from the repo
/// root, `rust/`, or a target dir).
pub fn default_artifacts_dir() -> Option<PathBuf> {
    let candidates = [
        PathBuf::from("artifacts"),
        PathBuf::from("../artifacts"),
        PathBuf::from("../../artifacts"),
    ];
    candidates
        .into_iter()
        .find(|p| p.join("manifest.tsv").exists())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Full PJRT round trip — skipped when artifacts aren't built
    /// (`make artifacts` first).
    #[cfg(feature = "pjrt")]
    #[test]
    fn execute_real_model() {
        let Some(dir) = default_artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let rt = ModelRuntime::load(&dir).expect("load artifacts");
        assert!(!rt.batch_sizes().is_empty());
        let n = 3u32;
        let inputs = vec![0.25f32; n as usize * IMAGE_DIM * IMAGE_DIM * IMAGE_CHANNELS];
        let probs = rt.execute(n, &inputs).expect("execute");
        assert_eq!(probs.len(), n as usize * NUM_CLASSES);
        // Each row is a softmax distribution.
        for row in probs.chunks(NUM_CLASSES) {
            let sum: f32 = row.iter().sum();
            assert!((sum - 1.0).abs() < 1e-3, "row sum {sum}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
        // Identical inputs -> identical rows (batch consistency).
        let (a, b) = (&probs[..NUM_CLASSES], &probs[NUM_CLASSES..2 * NUM_CLASSES]);
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_reports_disabled_feature() {
        let err = ModelRuntime::load(Path::new("/tmp/none")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn padded_batch_selection() {
        // Construct the mapping logic without PJRT via a fake manifest.
        // (Real selection is covered by execute_real_model.)
        let mut m = BTreeMap::new();
        for b in [1u32, 2, 4, 8, 16, 32] {
            m.insert(b, ());
        }
        let pick = |n: u32| m.range(n..).next().map(|(&b, _)| b).unwrap_or(32);
        assert_eq!(pick(1), 1);
        assert_eq!(pick(3), 4);
        assert_eq!(pick(9), 16);
        assert_eq!(pick(33), 32); // clamp to max
    }
}
