//! Analytical batching model (§3.3, §5.3, Table 2).
//!
//! *Staggered execution* (what deferred scheduling converges to): N GPUs
//! execute uniformly large batches offset by ℓ(b)/N, so the worst-case
//! queueing delay is ℓ(b)/N and
//!
//! ```text
//! (1 + 1/N) · ℓ(b) ≤ SLO            (latency)        [eq 1]
//! N · b / ℓ(b)     ≥ λ              (throughput)     [eq 2]
//! ```
//!
//! *No coordination* (Nexus-style distributed): worst queueing is a full
//! ℓ(b), so b = ⌊(SLO/2 − β)/α⌋.

use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;

/// Result of the analytical solve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AnalyticalPoint {
    pub batch_size: u32,
    /// Aggregate throughput of N GPUs at that batch size (req/s).
    pub throughput: f64,
}

/// Staggered-execution optimum: largest b with `(1 + 1/N)·ℓ(b) ≤ SLO`.
pub fn staggered(profile: &LatencyProfile, slo: Micros, n_gpus: u32) -> AnalyticalPoint {
    let factor = 1.0 + 1.0 / n_gpus as f64;
    let budget = Micros((slo.0 as f64 / factor) as u64);
    let b = profile.max_batch_within(budget);
    AnalyticalPoint {
        batch_size: b,
        throughput: n_gpus as f64 * profile.throughput(b),
    }
}

/// Uncoordinated optimum: b = maxfit(SLO/2) (§5.3's closed form
/// ⌊(SLO/2 − β)/α⌋).
pub fn no_coordination(profile: &LatencyProfile, slo: Micros, n_gpus: u32) -> AnalyticalPoint {
    let b = profile.max_batch_within(Micros(slo.0 / 2));
    AnalyticalPoint {
        batch_size: b,
        throughput: n_gpus as f64 * profile.throughput(b),
    }
}

/// Solve eq (1)+(2) for the minimum GPUs sustaining rate λ (used by the
/// Fig 10 analysis and the autoscaler's sizing hints): smallest N such
/// that with b = maxfit(SLO/(1+1/N)), `N·b/ℓ(b) ≥ λ`.
pub fn min_gpus_for_rate(profile: &LatencyProfile, slo: Micros, rate: f64) -> Option<u32> {
    for n in 1..=65_536u32 {
        let pt = staggered(profile, slo, n);
        if pt.batch_size >= 1 && pt.throughput >= rate {
            return Some(n);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 2 row 1: ResNet50, α=1.053, β=5.072, SLO 25 ms, 8 GPUs.
    #[test]
    fn table2_resnet50() {
        let p = LatencyProfile::new(1.053, 5.072);
        let slo = Micros::from_millis_f64(25.0);
        let nc = no_coordination(&p, slo, 8);
        assert_eq!(nc.batch_size, 7);
        assert!((nc.throughput - 4501.0).abs() / 4501.0 < 0.01, "{}", nc.throughput);
        let st = staggered(&p, slo, 8);
        assert_eq!(st.batch_size, 16);
        assert!((st.throughput - 5839.0).abs() / 5839.0 < 0.01, "{}", st.throughput);
    }

    /// Table 2 row 2: InceptionResNetV2, α=5.090, β=18.368, SLO 70 ms.
    #[test]
    fn table2_inception_resnet_v2() {
        let p = LatencyProfile::new(5.090, 18.368);
        let slo = Micros::from_millis_f64(70.0);
        let nc = no_coordination(&p, slo, 8);
        assert_eq!(nc.batch_size, 3);
        assert!((nc.throughput - 713.0).abs() / 713.0 < 0.01, "{}", nc.throughput);
        let st = staggered(&p, slo, 8);
        assert_eq!(st.batch_size, 8);
        assert!((st.throughput - 1083.0).abs() / 1083.0 < 0.01, "{}", st.throughput);
    }

    #[test]
    fn staggered_beats_no_coordination() {
        let p = LatencyProfile::new(1.053, 5.072);
        let slo = Micros::from_millis_f64(25.0);
        let st = staggered(&p, slo, 8);
        let nc = no_coordination(&p, slo, 8);
        // §5.3: staggered runs ~2x the batch, 30-50% higher throughput.
        assert!(st.batch_size >= 2 * nc.batch_size);
        let gain = st.throughput / nc.throughput;
        assert!((1.25..1.55).contains(&gain), "gain {gain}");
    }

    #[test]
    fn min_gpus_monotone_in_rate() {
        let p = LatencyProfile::new(0.268, 5.172); // A100 ResNet50
        let slo = Micros::from_millis_f64(25.0);
        let n1 = min_gpus_for_rate(&p, slo, 5_000.0).unwrap();
        let n2 = min_gpus_for_rate(&p, slo, 15_000.0).unwrap();
        assert!(n2 >= n1);
        // Sanity: the cluster it returns actually sustains the rate.
        let pt = staggered(&p, slo, n2);
        assert!(pt.throughput >= 15_000.0);
    }
}
