//! `GetBatch` (Algorithm 1, line 2): per-model FIFO queue + the
//! batch-gathering policy that returns the maximum batch that can still
//! finish within the head request's deadline, dropping heads that can no
//! longer run at all.

use std::collections::VecDeque;

use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;
use crate::core::types::{ReqList, Request, RequestId};

/// A model's pending-request queue. Requests of one model share an SLO,
/// so FIFO order is deadline order.
#[derive(Clone, Debug, Default)]
pub struct ModelQueue {
    q: VecDeque<Request>,
}

/// Result of `get_batch`.
#[derive(Clone, Debug, Default)]
pub struct BatchPlan {
    /// The batch (a prefix of the queue); empty if nothing can run.
    pub batch: Vec<RequestId>,
    /// Deadline of the batch = earliest deadline among its requests.
    pub deadline: Micros,
    /// Requests dropped because even a batch of 1 can't meet their SLO.
    pub dropped: Vec<RequestId>,
}

impl ModelQueue {
    pub fn new() -> Self {
        ModelQueue::default()
    }

    /// Insert preserving deadline order. In-order arrival is the common
    /// case (one SLO per model makes FIFO order deadline order) and is
    /// O(1); an out-of-order arrival insert-sorts from the back. The
    /// seed only `debug_assert`ed the ordering, so a single out-of-order
    /// arrival silently corrupted head-deadline planning in release
    /// builds.
    pub fn push(&mut self, r: Request) {
        let mut i = self.q.len();
        while i > 0 && self.q[i - 1].deadline > r.deadline {
            i -= 1;
        }
        if i == self.q.len() {
            self.q.push_back(r);
        } else {
            self.q.insert(i, r);
        }
    }

    /// Re-insert preempted requests, restoring global deadline order
    /// (a merge — preempted requests usually all precede the queue, but
    /// same-timestamp arrivals and repeated preemptions can interleave).
    pub fn push_front_sorted(&mut self, mut rs: Vec<Request>) {
        rs.sort_by_key(|r| r.deadline);
        let mut merged = VecDeque::with_capacity(self.q.len() + rs.len());
        let mut old = std::mem::take(&mut self.q);
        let mut it = rs.into_iter().peekable();
        while let Some(front) = old.front() {
            while it.peek().map_or(false, |r| r.deadline <= front.deadline) {
                merged.push_back(it.next().unwrap());
            }
            merged.push_back(old.pop_front().unwrap());
        }
        merged.extend(it);
        self.q = merged;
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn head_deadline(&self) -> Option<Micros> {
        self.q.front().map(|r| r.deadline)
    }

    pub fn head_arrival(&self) -> Option<Micros> {
        self.q.front().map(|r| r.arrival)
    }

    /// Plan the maximum batch that can start at `start` and finish by the
    /// head deadline, after dropping hopeless heads. `budget_slack` is
    /// subtracted from every deadline (network-delay bound, Fig 18's
    /// `delay(bs)`), `max_batch` caps the size (0 = uncapped).
    pub fn plan(
        &mut self,
        start: Micros,
        profile: &LatencyProfile,
        budget_slack: Micros,
        max_batch: u32,
    ) -> BatchPlan {
        self.plan_target(start, profile, budget_slack, max_batch, 0)
    }

    /// `plan` with Nexus-style *drop-head batch gathering* (§3.2: "the
    /// batch-gathering algorithm can prematurely drop the head of the
    /// queue in order to maintain a larger target batch size"). When the
    /// queue holds at least `target` requests but the (stale) head's
    /// deadline would force a batch smaller than `target`, heads are
    /// shed until the achievable batch recovers — this is what gives
    /// goodput *stability* under overload (§3.5): bad rate ≈ (o−p)/o
    /// instead of a collapsing batch-size death spiral. `target = 0`
    /// disables the policy.
    pub fn plan_target(
        &mut self,
        start: Micros,
        profile: &LatencyProfile,
        budget_slack: Micros,
        max_batch: u32,
        target: u32,
    ) -> BatchPlan {
        let mut plan = BatchPlan::default();
        self.shed_heads(start, profile, budget_slack, target, &mut plan.dropped);
        let Some(front) = self.q.front() else {
            return plan;
        };
        let budget = front.deadline.saturating_sub(start + budget_slack);
        let mut b = profile.max_batch_within(budget);
        if max_batch > 0 {
            b = b.min(max_batch);
        }
        let b = (b as usize).min(self.q.len());
        plan.deadline = front.deadline;
        plan.batch = self.q.iter().take(b).map(|r| r.id).collect();
        plan
    }

    /// Like [`plan_target`] but without materializing the batch id
    /// vector — candidate (re)computation only needs the count, and it
    /// runs on every request arrival. Dropped ids go into the
    /// caller-provided scratch buffer (appended), so the steady-state
    /// no-drop path performs zero allocations (§Perf: this was the
    /// scheduler's hottest allocation).
    pub fn plan_len(
        &mut self,
        start: Micros,
        profile: &LatencyProfile,
        budget_slack: Micros,
        max_batch: u32,
        target: u32,
        dropped: &mut Vec<RequestId>,
    ) -> (usize, Micros) {
        self.shed_heads(start, profile, budget_slack, target, dropped);
        let Some(front) = self.q.front() else {
            return (0, Micros::ZERO);
        };
        let budget = front.deadline.saturating_sub(start + budget_slack);
        let mut b = profile.max_batch_within(budget);
        if max_batch > 0 {
            b = b.min(max_batch);
        }
        ((b as usize).min(self.q.len()), front.deadline)
    }

    /// The shared head-shedding pass of [`plan_target`](Self::plan_target)
    /// and [`plan_len`](Self::plan_len): drop heads that cannot run even
    /// alone, then (with `target > 0`) drop stale heads that would cap
    /// the batch below the target while enough fresher requests are
    /// queued to reach it. One implementation keeps the arrival path and
    /// the materializing path drop-for-drop identical.
    fn shed_heads(
        &mut self,
        start: Micros,
        profile: &LatencyProfile,
        budget_slack: Micros,
        target: u32,
        dropped: &mut Vec<RequestId>,
    ) {
        while let Some(front) = self.q.front() {
            let budget = front.deadline.saturating_sub(start + budget_slack);
            if profile.max_batch_within(budget) == 0 {
                dropped.push(front.id);
                self.q.pop_front();
            } else {
                break;
            }
        }
        if target > 0 {
            while let Some(front) = self.q.front() {
                let budget = front.deadline.saturating_sub(start + budget_slack);
                let b = profile.max_batch_within(budget);
                let reachable = target.min(self.q.len() as u32);
                if b < reachable {
                    dropped.push(front.id);
                    self.q.pop_front();
                } else {
                    break;
                }
            }
        }
    }

    /// Remove the first `n` requests (they were dispatched).
    pub fn take(&mut self, n: usize) -> Vec<RequestId> {
        (0..n).map(|_| self.q.pop_front().unwrap().id).collect()
    }

    /// Like [`take`](Self::take) but into an inline-first [`ReqList`] —
    /// the dispatch hot path: batches up to `REQLIST_INLINE` ids
    /// allocate nothing.
    pub fn take_list(&mut self, n: usize) -> ReqList {
        let mut out = ReqList::with_capacity(n);
        for _ in 0..n {
            out.push(self.q.pop_front().unwrap().id);
        }
        out
    }

    /// Drop every queued request (used at shutdown).
    pub fn drain_ids(&mut self) -> Vec<RequestId> {
        self.q.drain(..).map(|r| r.id).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::types::ModelId;

    fn req(id: u64, arrival_ms: f64, deadline_ms: f64) -> Request {
        Request {
            id: RequestId(id),
            model: ModelId(0),
            arrival: Micros::from_millis_f64(arrival_ms),
            deadline: Micros::from_millis_f64(deadline_ms),
        }
    }

    #[test]
    fn plan_max_fit() {
        // ℓ(b) = b + 5 (ms), head deadline 12ms, start at 0: fits b=7,
        // but only 4 queued.
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = ModelQueue::new();
        for i in 0..4 {
            q.push(req(i, 0.75 * i as f64, 12.0 + 0.75 * i as f64));
        }
        let plan = q.plan(Micros::ZERO, &p, Micros::ZERO, 0);
        assert_eq!(plan.batch.len(), 4);
        assert_eq!(plan.deadline, Micros::from_millis_f64(12.0));
        assert!(plan.dropped.is_empty());
    }

    #[test]
    fn plan_caps_at_deadline() {
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = ModelQueue::new();
        for i in 0..20 {
            q.push(req(i, 0.0, 12.0));
        }
        // At start=0, budget=12 => max fit ℓ(7)=12 => b=7.
        let plan = q.plan(Micros::ZERO, &p, Micros::ZERO, 0);
        assert_eq!(plan.batch.len(), 7);
        // With slack 2ms, budget=10 => b=5.
        let plan = q.plan(Micros::ZERO, &p, Micros::from_millis_f64(2.0), 0);
        assert_eq!(plan.batch.len(), 5);
        // With max_batch=3.
        let plan = q.plan(Micros::ZERO, &p, Micros::ZERO, 3);
        assert_eq!(plan.batch.len(), 3);
    }

    #[test]
    fn plan_drops_hopeless_heads() {
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = ModelQueue::new();
        q.push(req(0, 0.0, 10.0));
        q.push(req(1, 1.0, 11.0));
        q.push(req(2, 20.0, 32.0));
        // At t=6, head needs ℓ(1)=6 > 10-6=4 -> dropped; same for id 1
        // (11-6=5 < 6); id 2 fits.
        let plan = q.plan(Micros::from_millis_f64(6.0), &p, Micros::ZERO, 0);
        assert_eq!(plan.dropped, vec![RequestId(0), RequestId(1)]);
        assert_eq!(plan.batch, vec![RequestId(2)]);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn take_removes_prefix() {
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = ModelQueue::new();
        for i in 0..5 {
            q.push(req(i, 0.0, 100.0));
        }
        let plan = q.plan(Micros::ZERO, &p, Micros::ZERO, 3);
        assert_eq!(plan.batch.len(), 3);
        let taken = q.take(3);
        assert_eq!(taken, vec![RequestId(0), RequestId(1), RequestId(2)]);
        assert_eq!(q.len(), 2);
    }

    /// Regression (release-mode ordering): an out-of-order arrival must
    /// insert-sort, not silently corrupt head-deadline planning.
    #[test]
    fn push_out_of_order_insert_sorts() {
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = ModelQueue::new();
        q.push(req(0, 0.0, 20.0));
        q.push(req(1, 1.0, 30.0));
        // Late-delivered request with the earliest deadline: must become
        // the head, so planning budgets against it.
        q.push(req(2, 0.5, 10.0));
        // Equal deadline keeps arrival (FIFO) order among ties.
        q.push(req(3, 2.0, 20.0));
        assert_eq!(q.head_deadline(), Some(Micros::from_millis_f64(10.0)));
        let plan = q.plan(Micros::ZERO, &p, Micros::ZERO, 0);
        assert_eq!(plan.deadline, Micros::from_millis_f64(10.0));
        let taken = q.take(4);
        assert_eq!(
            taken,
            vec![RequestId(2), RequestId(0), RequestId(3), RequestId(1)]
        );
    }

    #[test]
    fn plan_len_and_take_list_match_plan_target() {
        let p = LatencyProfile::new(1.0, 5.0);
        let mut q = ModelQueue::new();
        for i in 0..20 {
            q.push(req(i, 0.0, 12.0));
        }
        let mut q2 = q.clone();
        let plan = q.plan_target(Micros::ZERO, &p, Micros::ZERO, 0, 0);
        let mut dropped = Vec::new();
        let (b, d) = q2.plan_len(Micros::ZERO, &p, Micros::ZERO, 0, 0, &mut dropped);
        assert_eq!(b, plan.batch.len());
        assert_eq!(d, plan.deadline);
        assert!(dropped.is_empty());
        let list = q2.take_list(b);
        assert_eq!(list.as_slice(), &plan.batch[..]);
        assert_eq!(q2.len(), q.len() - b);
    }

    #[test]
    fn preempted_requests_reinserted_in_order() {
        let mut q = ModelQueue::new();
        q.push(req(5, 10.0, 40.0));
        q.push_front_sorted(vec![req(2, 3.0, 33.0), req(1, 2.0, 32.0)]);
        assert_eq!(q.head_deadline(), Some(Micros::from_millis_f64(32.0)));
        assert_eq!(q.len(), 3);
        let taken = q.take(3);
        assert_eq!(taken, vec![RequestId(1), RequestId(2), RequestId(5)]);
    }
}
