//! Clockwork-style baseline (§2.2).
//!
//! Clockwork's controller relies on *predictable* execution and binds
//! work to GPUs **early**: it keeps an action queued behind the one
//! running on each GPU so devices never idle ("minimize device idle
//! time"). For an incoming request it creates batch candidates and, when
//! choosing what to bind, picks the candidate whose *latest executable
//! moment* (`d − ℓ(b)`) is earliest, invalidating the related candidates.
//!
//! The early binding is what keeps Clockwork's batches tiny (Fig 1:
//! median 1): a request is attached to some GPU's action slot almost
//! immediately — before later requests could have joined the batch —
//! because with one pending slot per GPU, slots outnumber queued
//! requests at any feasible load. Its goodput is correspondingly near
//! the `N/ℓ(1)` floor (Table 2: 1358 r/s where Symphony reaches 5264).

use std::collections::BTreeSet;

use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, Request};
use crate::scheduler::batch_policy::ModelQueue;
use crate::scheduler::{Command, Scheduler, TimerKey};

struct MState {
    queue: ModelQueue,
    profile: LatencyProfile,
}

/// How many actions Clockwork keeps bound ahead per GPU (schedule-ahead
/// for predictability: the controller fills GPU queues in advance so
/// transfers overlap execution).
const QUEUE_AHEAD: usize = 3;

/// An action bound to a GPU but not yet running.
#[derive(Clone, Debug)]
struct Pending {
    model: ModelId,
    requests: Vec<crate::core::types::RequestId>,
}

struct GpuSlot {
    /// Predicted time the GPU finishes everything bound to it.
    drained_at: Micros,
    busy: bool,
    pending: std::collections::VecDeque<Pending>,
}

pub struct ClockworkScheduler {
    models: Vec<MState>,
    gpus: Vec<GpuSlot>,
    /// GPUs with queue-ahead room, keyed by predicted drain time.
    open_slots: BTreeSet<(Micros, GpuId)>,
}

impl ClockworkScheduler {
    pub fn new(profiles: Vec<LatencyProfile>, num_gpus: usize) -> Self {
        ClockworkScheduler {
            models: profiles
                .into_iter()
                .map(|profile| MState {
                    queue: ModelQueue::new(),
                    profile,
                })
                .collect(),
            gpus: (0..num_gpus)
                .map(|_| GpuSlot {
                    drained_at: Micros::ZERO,
                    busy: false,
                    pending: std::collections::VecDeque::new(),
                })
                .collect(),
            open_slots: (0..num_gpus as u32).map(|g| (Micros::ZERO, GpuId(g))).collect(),
        }
    }

    fn remove_slot_key(&mut self, gpu: GpuId) {
        let stale: Vec<(Micros, GpuId)> = self
            .open_slots
            .iter()
            .filter(|&&(_, g)| g == gpu)
            .copied()
            .collect();
        for k in stale {
            self.open_slots.remove(&k);
        }
    }

    /// Re-publish the GPU's slot key if it still has queue-ahead room.
    fn refresh_slot(&mut self, gpu: GpuId) {
        self.remove_slot_key(gpu);
        let slot = &self.gpus[gpu.0 as usize];
        let depth = slot.pending.len() + usize::from(slot.busy);
        if depth < QUEUE_AHEAD {
            self.open_slots.insert((slot.drained_at, gpu));
        }
    }

    /// Bind unassigned requests to open GPU slots (early binding): fill
    /// the earliest-draining slot with the most urgent candidate, repeat.
    fn bind(&mut self, now: Micros, out: &mut Vec<Command>) {
        loop {
            let Some(&(drained_at, gpu)) = self.open_slots.iter().next() else {
                return;
            };
            let start_est = drained_at.max(now);
            // Most urgent candidate at that predicted start: min over
            // models of the latest executable moment `d_head − ℓ(b)`.
            let mut best: Option<(Micros, usize, usize)> = None;
            for (mi, st) in self.models.iter_mut().enumerate() {
                let plan = st.queue.plan(start_est, &st.profile, Micros::ZERO, 0);
                if !plan.dropped.is_empty() {
                    out.push(Command::Drop(plan.dropped.clone().into()));
                }
                if plan.batch.is_empty() {
                    continue;
                }
                let b = plan.batch.len();
                let latest = plan.deadline - st.profile.latency(b as u32);
                if best.map_or(true, |(l, _, _)| latest < l) {
                    best = Some((latest, mi, b));
                }
            }
            let Some((_, mi, b)) = best else {
                return; // nothing bindable at this horizon
            };
            let requests = self.models[mi].queue.take(b);
            let dur = self.models[mi].profile.latency(b as u32);
            let action = Pending {
                model: ModelId(mi as u32),
                requests,
            };
            let slot = &mut self.gpus[gpu.0 as usize];
            if slot.busy || !slot.pending.is_empty() {
                slot.pending.push_back(action);
                slot.drained_at = slot.drained_at.max(now) + dur;
            } else {
                // Idle GPU: run immediately.
                slot.busy = true;
                slot.drained_at = now + dur;
                out.push(Command::Dispatch {
                    gpu,
                    model: action.model,
                    requests: action.requests.into(),
                });
            }
            self.refresh_slot(gpu);
        }
    }
}

impl Scheduler for ClockworkScheduler {
    fn on_request(&mut self, req: Request, now: Micros, out: &mut Vec<Command>) {
        let m = req.model.0 as usize;
        self.models[m].queue.push(req);
        // Early binding: attach to an open slot right away.
        self.bind(now, out);
    }

    fn on_timer(&mut self, _key: TimerKey, _now: Micros, _out: &mut Vec<Command>) {}

    fn on_gpu_free(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        let slot = &mut self.gpus[gpu.0 as usize];
        slot.busy = false;
        if let Some(action) = slot.pending.pop_front() {
            let mi = action.model.0 as usize;
            let dur = self.models[mi].profile.latency(action.requests.len() as u32);
            let slot = &mut self.gpus[gpu.0 as usize];
            slot.busy = true;
            // drained_at already includes this action's duration, but
            // re-anchor to now in case execution ran late (network).
            slot.drained_at = slot.drained_at.max(now + dur);
            out.push(Command::Dispatch {
                gpu,
                model: action.model,
                requests: action.requests.into(),
            });
        } else {
            slot.drained_at = now;
        }
        self.refresh_slot(gpu);
        self.bind(now, out);
    }

    fn on_gpu_added(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        let gi = gpu.0 as usize;
        if gi >= self.gpus.len() {
            for i in self.gpus.len()..=gi {
                self.gpus.push(GpuSlot {
                    drained_at: now,
                    busy: false,
                    pending: std::collections::VecDeque::new(),
                });
                self.refresh_slot(GpuId(i as u32));
            }
        }
        self.bind(now, out);
    }

    fn on_gpu_removed(&mut self, gpu: GpuId, _now: Micros, _out: &mut Vec<Command>) {
        self.remove_slot_key(gpu);
    }

    fn name(&self) -> &'static str {
        "clockwork"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::profile::ModelSpec;
    use crate::sim::{Engine, SimConfig};
    use crate::workload::{Workload, WorkloadSpec};

    #[test]
    fn urgent_model_wins() {
        // Saturate the queue-ahead pipeline (r0 running + r1, r2
        // pending); r3 (loose) and r4 (tight) then compete for the slot
        // that opens when the GPU frees — the tighter
        // latest-executable-moment wins.
        let loose = ModelSpec::new("loose", 1.0, 5.0, 100.0);
        let tight = ModelSpec::new("tight", 1.0, 5.0, 30.0);
        let workload = Workload::explicit(
            vec![loose.clone(), tight.clone()],
            vec![
                vec![Micros(0), Micros(1), Micros(2), Micros(30)],
                vec![Micros(40)],
            ],
        );
        let sched = ClockworkScheduler::new(vec![loose.profile, tight.profile], 1);
        let res = Engine::new(
            workload,
            sched,
            SimConfig::new(1, Micros::from_secs_f64(1.0)).trace(true),
        )
        .run();
        let order: Vec<u32> = res.trace.iter().map(|t| t.model.0).collect();
        assert_eq!(order[..4], [0, 0, 0, 1], "urgent model bound first: {order:?}");
    }

    #[test]
    fn early_binding_beats_late_arrivals() {
        // A request that arrives 10 µs after its peer does NOT join the
        // peer's batch — the peer was already bound (the §2.2 critique).
        let m = ModelSpec::new("m", 1.0, 5.0, 100.0);
        let workload = Workload::explicit(
            vec![m.clone()],
            vec![vec![Micros(0), Micros(10), Micros(20)]],
        );
        let sched = ClockworkScheduler::new(vec![m.profile], 2);
        let res = Engine::new(
            workload,
            sched,
            SimConfig::new(2, Micros::from_secs_f64(1.0)).trace(true),
        )
        .run();
        // Three requests, two idle GPUs: r0 -> gpu, r1 -> gpu, r2 ->
        // pending; all batches of size 1.
        assert!(res.trace.iter().all(|t| t.size == 1), "{:?}", res.trace);
        assert_eq!(res.trace.len(), 3);
    }

    #[test]
    fn early_binding_keeps_batches_tiny() {
        // Fig 1 / Table 2: at ~Clockwork's own goodput the median batch
        // is ~1 because requests bind to slots before peers arrive.
        let model = ModelSpec::new("r50", 1.053, 5.072, 25.0);
        let spec = WorkloadSpec::new(vec![model.clone()], 1_300.0).seed(7);
        let sched = ClockworkScheduler::new(vec![model.profile], 8);
        let res = Engine::new(
            spec.build(),
            sched,
            SimConfig::new(8, Micros::from_secs_f64(4.0)),
        )
        .run();
        let median = res.metrics.per_model[0].median_batch();
        assert!(median <= 2, "clockwork median batch {median}");
    }

    #[test]
    fn overload_degrades_not_recovers() {
        // Fig 2: beyond saturation Clockwork's goodput falls well below
        // the deferred scheduler's at the same rate.
        let model = ModelSpec::new("r50", 1.053, 5.072, 25.0);
        let mk = |rate: f64| {
            let spec = WorkloadSpec::new(vec![model.clone()], rate).seed(9);
            let sched = ClockworkScheduler::new(vec![model.profile], 8);
            Engine::new(
                spec.build(),
                sched,
                SimConfig::new(8, Micros::from_secs_f64(4.0)),
            )
            .run()
            .metrics
        };
        let m = mk(5_000.0);
        // Far below the 5k offered: early binding caps efficiency.
        assert!(m.goodput() < 4_000.0, "clockwork overload goodput {}", m.goodput());
    }
}
