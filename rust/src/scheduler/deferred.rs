//! Symphony's deferred batch scheduler — Algorithm 1 / Figure 18.
//!
//! Per model, one *candidate batch* with a schedulable window
//! `[exec, latest]` where
//!
//! ```text
//! frontrun = d − ℓ(b+1) − net      exec = max(now, frontrun)
//! latest   = d − ℓ(b)   − net
//! ```
//!
//! (§3.1: dispatching at *frontrun* keeps the batching efficiency of
//! *latest* — any request arriving after frontrun could not join the
//! batch without violating the deadline — while reducing GPU idle time.)
//!
//! Matchmaking (§3.2):
//! * a model timer fires at `exec`; the scheduler picks the free GPU
//!   with the **smallest id** (consolidation — high-id GPUs stay idle so
//!   the autoscaler can reclaim them);
//! * when a GPU frees, it picks among schedulable candidates
//!   (`exec ≤ now ≤ latest`) the one whose `latest` is **closest**
//!   (urgency first).
//!
//! Data structures give the paper's `O(log M + log G)` bounds: a
//! `BTreeSet<(latest, model)>` of ready candidates and an allocation-free
//! bitset ([`GpuSet`]) of free GPUs.
//!
//! §Perf: the steady-state `on_request` path is allocation-free — see
//! the hot-path architecture note in [`crate::scheduler`]. Dropped ids
//! accumulate in a reusable scratch buffer, dispatch batches go out in
//! inline [`ReqList`]s, the shedding target is memoized per model, and
//! an unchanged recomputed candidate skips all bookkeeping.

use std::collections::BTreeSet;

use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, ReqList, Request, RequestId};
use crate::scheduler::batch_policy::ModelQueue;
use crate::scheduler::{Command, Scheduler, TimerKey};
use crate::util::bitset::GpuSet;

/// A candidate batch (Algorithm 1: `c_M = (B, exec, latest)`).
/// The request set is the current queue prefix of length `size`; it is
/// re-materialized at dispatch ("Update exec", line 10).
#[derive(Clone, Copy, Debug)]
struct Candidate {
    size: u32,
    exec: Micros,
    latest: Micros,
    /// In the ready set (exec has passed, awaiting a GPU)?
    ready: bool,
}

struct MState {
    queue: ModelQueue,
    profile: LatencyProfile,
    cand: Option<Candidate>,
    /// Memoized shedding target: `target_batch` result for `shed_budget`.
    /// One SLO per model makes the head's budget (d − a) constant in
    /// practice, so this is ~always a hit; the seed recomputed the O(b*)
    /// target scan on every arrival and dispatch.
    shed_budget: Option<Micros>,
    shed_target: u32,
}

impl MState {
    /// The memoized drop-head shedding target for the head's SLO budget.
    #[inline]
    fn shed_target_for(&mut self, budget: Micros, n: usize, max_batch: u32) -> u32 {
        if self.shed_budget != Some(budget) {
            self.shed_budget = Some(budget);
            self.shed_target =
                DeferredScheduler::target_batch(&self.profile, budget, n, max_batch);
        }
        self.shed_target
    }
}

/// Configuration for the deferred scheduler.
#[derive(Clone, Copy, Debug)]
pub struct DeferredConfig {
    /// High-percentile network-delay bound budgeted per dispatch (§5.6).
    pub net_bound: Micros,
    /// Batch-size cap (0 = uncapped).
    pub max_batch: u32,
    /// Overload shedding via drop-head batch gathering (§3.2/§3.5).
    /// Disable only for ablations — without it goodput loses the
    /// flat-top under overload.
    pub shed: bool,
}

impl Default for DeferredConfig {
    fn default() -> Self {
        DeferredConfig {
            net_bound: Micros::ZERO,
            max_batch: 0,
            shed: true,
        }
    }
}

pub struct DeferredScheduler {
    models: Vec<MState>,
    free_gpus: GpuSet,
    /// Schedulable candidates ordered by urgency: (latest, model).
    ready: BTreeSet<(Micros, ModelId)>,
    cfg: DeferredConfig,
    num_gpus: usize,
    /// Reusable scratch for dropped ids (§Perf: no per-event allocation).
    drop_scratch: Vec<RequestId>,
}

impl DeferredScheduler {
    pub fn new(profiles: Vec<LatencyProfile>, num_gpus: usize, cfg: DeferredConfig) -> Self {
        let mut free_gpus = GpuSet::with_id_capacity(num_gpus);
        for g in 0..num_gpus as u32 {
            free_gpus.insert(GpuId(g));
        }
        DeferredScheduler {
            models: profiles
                .into_iter()
                .map(|profile| MState {
                    queue: ModelQueue::new(),
                    profile,
                    cand: None,
                    shed_budget: None,
                    shed_target: 0,
                })
                .collect(),
            free_gpus,
            ready: BTreeSet::new(),
            cfg,
            num_gpus,
            drop_scratch: Vec::new(),
        }
    }

    /// Overload-shedding target for the drop-head batch-gathering policy
    /// (§3.2 / §3.5). Start from the staggered-execution optimal batch
    /// b* (largest b with `(1 + 1/N)·ℓ(b) ≤ SLO`, §3.3), then relax to
    /// the smallest batch achieving ≥90% of b*'s throughput — for
    /// weak-batching models (BERT-like) that is b = 1, so no useful work
    /// is ever shed; for strong-batching models the queue head is kept
    /// fresh enough that goodput stays at the flat-top under overload.
    /// (Exposed `pub` for the float/int equivalence property tests; the
    /// hot path reaches it only through the per-model memo.)
    pub fn target_batch(profile: &LatencyProfile, slo: Micros, n: usize, max_batch: u32) -> u32 {
        // lint:allow(float-free-hot-path): cold path — computed once per
        // model and memoized; pinned against the integer reference by the
        // float/int equivalence property tests.
        let budget = Micros((slo.0 as f64 / (1.0 + 1.0 / n.max(1) as f64)) as u64);
        let mut b_star = profile.max_batch_within(budget);
        if max_batch > 0 {
            // Never shed toward a batch the cap forbids — that would
            // drop requests forever chasing an unreachable target.
            b_star = b_star.min(max_batch);
        }
        if b_star <= 1 {
            return b_star;
        }
        // lint:allow(float-free-hot-path): same memoized cold path as above.
        let goal = 0.9 * profile.throughput(b_star);
        for b in 1..b_star {
            if profile.throughput(b) >= goal {
                return b;
            }
        }
        b_star
    }

    fn clear_candidate(&mut self, m: ModelId) {
        if let Some(c) = self.models[m.0 as usize].cand.take() {
            if c.ready {
                self.ready.remove(&(c.latest, m));
            }
        }
    }

    /// `UpdateCandidate(M)` — recompute the candidate batch and its
    /// window; arm timers / try to dispatch as appropriate.
    fn update_candidate(&mut self, m: ModelId, now: Micros, out: &mut Vec<Command>) {
        let max_batch = self.cfg.max_batch;
        let slack = self.cfg.net_bound;
        let shed = self.cfg.shed;
        let n = self.num_gpus;
        let mut dropped = std::mem::take(&mut self.drop_scratch);
        let st = &mut self.models[m.0 as usize];
        let prev = st.cand;
        // `saturating_sub`: the head's SLO (d − a) is non-negative for
        // well-formed requests, but a wrap here would hand the shedding
        // target a ~u64::MAX budget (see `Micros::Sub`).
        let target = match (st.queue.head_deadline(), st.queue.head_arrival()) {
            (Some(d), Some(a)) if shed => {
                st.shed_target_for(d.saturating_sub(a), n, max_batch)
            }
            _ => 0,
        };
        let (b, d) = st
            .queue
            .plan_len(now, &st.profile, slack, max_batch, target, &mut dropped);
        let profile = st.profile;
        if !dropped.is_empty() {
            out.push(Command::Drop(ReqList::from_slice(&dropped)));
            dropped.clear();
        }
        self.drop_scratch = dropped;
        if b == 0 {
            self.clear_candidate(m);
            out.push(Command::CancelTimer { key: TimerKey::Model(m) });
            out.push(Command::CancelTimer { key: TimerKey::ModelAux(m) });
            return;
        }
        let b = b as u32;
        let frontrun = d.saturating_sub(profile.latency(b + 1).saturating_add(slack));
        let latest = d.saturating_sub(profile.latency(b).saturating_add(slack));
        let exec = frontrun.max(now);
        debug_assert!(exec <= latest, "window inverted: exec {exec:?} > latest {latest:?}");

        // Steady-state shortcut: the recomputed candidate is equivalent
        // to the registered one, so every timer and ready-set entry
        // already reflects it — emit nothing.
        // * Pending: the Model timer must fire at exactly `exec`, so all
        //   three fields must match (and the window must still be
        //   closed).
        // * Parked (ready): the candidate is keyed by `(latest, m)` and
        //   its aux timer by `latest + 1`; `exec` is not consulted again
        //   once the window opened, and the recomputed
        //   `exec = max(now, frontrun)` drifts forward with the clock on
        //   every arrival — requiring it to match would defeat the
        //   shortcut in exactly the GPU-starved steady state it targets.
        //   A parked candidate can stay parked only while no GPU is free
        //   (a free GPU empties the ready set, but the bitset check
        //   keeps the shortcut locally sound regardless).
        if let Some(p) = prev {
            if p.size == b && p.latest == latest {
                if !p.ready && p.exec == exec && exec > now {
                    return;
                }
                if p.ready && self.free_gpus.is_empty() {
                    return;
                }
            }
        }

        self.clear_candidate(m);
        let cand = Candidate {
            size: b,
            exec,
            latest,
            ready: false,
        };
        self.models[m.0 as usize].cand = Some(cand);

        if exec > now {
            // Defer: wait for the frontrun moment (§3.1 — "we explicitly
            // disallow dispatching a batch prior to frontrun").
            out.push(Command::SetTimer {
                key: TimerKey::Model(m),
                at: exec,
            });
            out.push(Command::CancelTimer { key: TimerKey::ModelAux(m) });
        } else {
            out.push(Command::CancelTimer { key: TimerKey::Model(m) });
            self.enter_ready(m, now, out);
        }
    }

    /// The candidate's window is open — dispatch if a GPU is free, else
    /// park it in the ready set until a GPU frees or `latest` expires.
    fn enter_ready(&mut self, m: ModelId, now: Micros, out: &mut Vec<Command>) {
        // OnModelTimer: G* = argmin id of free GPUs.
        if let Some(gpu) = self.free_gpus.min() {
            self.dispatch(m, gpu, now, out);
            return;
        }
        let st = &mut self.models[m.0 as usize];
        let c = st.cand.as_mut().expect("enter_ready without candidate");
        c.ready = true;
        let latest = c.latest;
        self.ready.insert((latest, m));
        // Revalidate just past expiry: the batch shrinks and the window
        // moves; repeated shrinking eventually drops hopeless heads.
        // `saturating_add`: a ~u64::MAX `latest` must not wrap the
        // revalidation deadline to 0 in release builds.
        out.push(Command::SetTimer {
            key: TimerKey::ModelAux(m),
            at: latest.saturating_add(Micros(1)),
        });
    }

    /// `Dispatch(M, G)` — re-materialize the batch at dispatch time
    /// ("Update exec"), send it, and immediately prepare the next
    /// candidate.
    fn dispatch(&mut self, m: ModelId, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        self.clear_candidate(m);
        let max_batch = self.cfg.max_batch;
        let slack = self.cfg.net_bound;
        let shed = self.cfg.shed;
        let n = self.num_gpus;
        let mut dropped = std::mem::take(&mut self.drop_scratch);
        let st = &mut self.models[m.0 as usize];
        let target = match (st.queue.head_deadline(), st.queue.head_arrival()) {
            (Some(d), Some(a)) if shed => {
                st.shed_target_for(d.saturating_sub(a), n, max_batch)
            }
            _ => 0,
        };
        // "Update exec": re-plan at dispatch time — count only, then pop
        // the prefix straight into an inline list (the seed materialized
        // the id vector twice per dispatch).
        let (b, _d) = st
            .queue
            .plan_len(now, &st.profile, slack, max_batch, target, &mut dropped);
        if !dropped.is_empty() {
            out.push(Command::Drop(ReqList::from_slice(&dropped)));
            dropped.clear();
        }
        if b == 0 {
            self.drop_scratch = dropped;
            // Everything expired between scheduling and dispatch. Cancel
            // *both* timers: leaving `ModelAux` armed leaks a dead
            // revalidation timer that later fires on an empty queue.
            out.push(Command::CancelTimer { key: TimerKey::Model(m) });
            out.push(Command::CancelTimer { key: TimerKey::ModelAux(m) });
            return;
        }
        let requests = st.queue.take_list(b);
        self.drop_scratch = dropped;
        self.free_gpus.remove(gpu);
        out.push(Command::Dispatch {
            gpu,
            model: m,
            requests,
        });
        // Prepare the next batch from the remaining queue.
        self.update_candidate(m, now, out);
    }

    /// `OnGpuTimer(G)` — find the most urgent schedulable candidate.
    fn match_gpu(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        loop {
            let Some(&(latest, m)) = self.ready.iter().next() else {
                return; // no ready candidates; GPU stays free
            };
            if latest < now {
                // Expired while waiting — recompute (shrinks the batch,
                // possibly drops heads) and retry. The recompute may
                // itself dispatch to `gpu` (its enter_ready sees the
                // free set); stop if the GPU got taken.
                self.update_candidate(m, now, out);
                if !self.free_gpus.contains(gpu) {
                    return;
                }
                continue;
            }
            self.dispatch(m, gpu, now, out);
            return;
        }
    }

    /// Total queued requests (coordination/diagnostics).
    pub fn queued(&self) -> usize {
        self.models.iter().map(|m| m.queue.len()).sum()
    }
}

impl Scheduler for DeferredScheduler {
    fn on_request(&mut self, req: Request, now: Micros, out: &mut Vec<Command>) {
        let m = req.model;
        self.models[m.0 as usize].queue.push(req);
        self.update_candidate(m, now, out);
    }

    fn on_timer(&mut self, key: TimerKey, now: Micros, out: &mut Vec<Command>) {
        match key {
            // The frontrun moment arrived.
            TimerKey::Model(m) => {
                if self.models[m.0 as usize].cand.is_some() {
                    self.enter_ready(m, now, out);
                }
            }
            // Candidate expired un-dispatched; recompute.
            TimerKey::ModelAux(m) => self.update_candidate(m, now, out),
            _ => {}
        }
    }

    fn on_gpu_free(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        self.free_gpus.insert(gpu);
        self.match_gpu(gpu, now, out);
    }

    fn on_gpu_added(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        self.free_gpus.insert(gpu);
        self.match_gpu(gpu, now, out);
    }

    fn on_gpu_removed(&mut self, gpu: GpuId, _now: Micros, _out: &mut Vec<Command>) {
        self.free_gpus.remove(gpu);
    }

    fn name(&self) -> &'static str {
        "symphony"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::profile::ModelSpec;
    use crate::metrics::Metrics;
    use crate::sim::{Engine, SimConfig};
    use crate::workload::Workload;

    /// §3.3 worked example: ℓ(b)=b+5 ms, SLO 12 ms, arrivals every
    /// 0.75 ms, 3 GPUs — first batch must be {R1..R4} dispatched at t=2
    /// (frontrun of b=4: 12 − ℓ(5) = 2).
    fn fig4_engine(n_req: usize) -> (Metrics, Vec<crate::sim::TraceEntry>) {
        let model = ModelSpec::new("m", 1.0, 5.0, 12.0);
        let times: Vec<Micros> = (0..n_req)
            .map(|i| Micros::from_millis_f64(0.75 * i as f64))
            .collect();
        let workload = Workload::explicit(vec![model.clone()], vec![times]);
        let sched =
            DeferredScheduler::new(vec![model.profile], 3, DeferredConfig::default());
        let cfg = SimConfig::new(3, Micros::from_secs_f64(1.0)).trace(true);
        let res = Engine::new(workload, sched, cfg).run();
        (res.metrics, res.trace)
    }

    #[test]
    fn fig4_first_batch_is_four_at_t2() {
        let (_metrics, trace) = fig4_engine(16);
        assert!(!trace.is_empty());
        let first = &trace[0];
        // §3.3: frontrun = 12 − ℓ(5) = 2, latest = 3; R4 arrives at 2.25
        // inside the window, so the batch {R1..R4} dispatches right then
        // ("At t = 2.25, R4 arrives ... the first batch, including the
        // first four requests, is dispatched").
        assert_eq!(first.size, 4, "first batch size");
        assert_eq!(first.start, Micros::from_millis_f64(2.25), "window dispatch");
    }

    #[test]
    fn fig4_staggered_pattern_sustains() {
        let (metrics, trace) = fig4_engine(64);
        // All requests good, no drops.
        assert_eq!(metrics.per_model[0].dropped, 0);
        assert_eq!(metrics.per_model[0].late, 0);
        // After warm-up the batches stabilize at size 4 across 3 GPUs.
        let steady: Vec<u32> = trace.iter().skip(3).map(|t| t.size).collect();
        assert!(steady.iter().all(|&s| s == 4), "steady sizes {steady:?}");
        // Staggered: consecutive batches on different GPUs.
        for w in trace.windows(2) {
            assert_ne!(w[0].gpu, w[1].gpu, "consecutive batches staggered");
        }
    }

    /// Regression (ModelAux leak): an empty-batch dispatch must cancel
    /// the auxiliary revalidation timer along with the model timer —
    /// otherwise a dead timer stays armed and fires on an empty queue.
    #[test]
    fn empty_dispatch_cancels_aux_timer() {
        use crate::core::types::RequestId;
        let profile = LatencyProfile::new(1.0, 5.0);
        let mut s = DeferredScheduler::new(vec![profile], 1, DeferredConfig::default());
        // A request whose deadline has long passed: the dispatch-time
        // re-plan drops it and returns an empty batch.
        s.models[0].queue.push(Request {
            id: RequestId(0),
            model: ModelId(0),
            arrival: Micros::ZERO,
            deadline: Micros::from_millis_f64(10.0),
        });
        let mut out = Vec::new();
        s.dispatch(ModelId(0), GpuId(0), Micros::from_millis_f64(50.0), &mut out);
        let dropped = out
            .iter()
            .any(|c| matches!(c, Command::Drop(ids) if ids.len() == 1 && ids[0] == RequestId(0)));
        assert!(dropped, "expired head must be dropped: {out:?}");
        let cancels_aux = out.iter().any(|c| {
            matches!(
                c,
                Command::CancelTimer {
                    key: TimerKey::ModelAux(ModelId(0))
                }
            )
        });
        assert!(cancels_aux, "ModelAux timer leaked: {out:?}");
        assert!(
            !out.iter().any(|c| matches!(c, Command::Dispatch { .. })),
            "nothing to dispatch: {out:?}"
        );
    }

    /// Regression (release-mode time underflow): a zero-slack request
    /// (deadline == arrival) exercises the shedding target's
    /// `d.saturating_sub(a)` path; it must drop cleanly, not wrap the
    /// SLO budget to ~u64::MAX.
    #[test]
    fn zero_slo_request_drops_cleanly() {
        let profile = LatencyProfile::new(1.0, 5.0);
        let mut s = DeferredScheduler::new(vec![profile], 1, DeferredConfig::default());
        let mut out = Vec::new();
        let now = Micros::from_millis_f64(3.0);
        s.on_request(
            Request {
                id: crate::core::types::RequestId(0),
                model: ModelId(0),
                arrival: now,
                deadline: now,
            },
            now,
            &mut out,
        );
        assert!(
            out.iter()
                .any(|c| matches!(c, Command::Drop(ids) if ids.len() == 1)),
            "hopeless request must be dropped: {out:?}"
        );
        // target_batch itself must treat a zero budget as "no target".
        assert_eq!(
            DeferredScheduler::target_batch(&profile, Micros::ZERO, 4, 0),
            0
        );
    }

    #[test]
    fn window_never_violates_slo() {
        // Deferred scheduling must never complete a request late.
        let model = ModelSpec::new("m", 2.05, 5.378, 27.0);
        let spec = crate::workload::WorkloadSpec::new(vec![model.clone()], 3000.0).seed(5);
        let sched =
            DeferredScheduler::new(vec![model.profile], 8, DeferredConfig::default());
        let cfg = SimConfig::new(8, Micros::from_secs_f64(5.0));
        let res = Engine::new(spec.build(), sched, cfg).run();
        let metrics = res.metrics;
        assert_eq!(metrics.per_model[0].late, 0, "late requests under deferred");
        assert!(metrics.per_model[0].good > 1000);
    }
}
