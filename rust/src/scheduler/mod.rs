//! The scheduler interface shared by Symphony's deferred batch scheduler
//! and all baselines, plus the command vocabulary they use to drive a
//! cluster (simulated or real).
//!
//! A scheduler is a *pure event handler*: the engine (or the real-time
//! coordinator) feeds it `on_request` / `on_timer` / `on_gpu_free`
//! events with the current time, and it emits `Command`s. This is the
//! same shape as the paper's Figure 18 pseudocode, factored so one
//! implementation runs under the discrete-event simulator, the
//! multithreaded coordinator, and the property tests.
//!
//! ## Hot-path architecture (§Perf)
//!
//! Steady-state request handling is integer-only and allocation-free:
//!
//! * [`crate::core::profile::LatencyProfile`] precomputes `alpha_us` /
//!   `beta_us` at construction, so ℓ(b) and the max-batch-within-budget
//!   query — called on every arrival and dispatch — are closed-form
//!   integer arithmetic (the seed did an ms-float round-trip plus two
//!   boundary-correction loops per call);
//! * [`Command::Dispatch`] and [`Command::Drop`] carry their ids in
//!   [`ReqList`], a hand-rolled inline small-vec: batches up to
//!   `REQLIST_INLINE` ids never touch the allocator;
//! * the deferred scheduler memoizes its overload-shedding target per
//!   model (the head's SLO budget is constant per model in practice),
//!   keeps its free-GPU set in an allocation-free bitset
//!   ([`crate::util::bitset::GpuSet`]), and skips all bookkeeping when a
//!   recomputed candidate is unchanged;
//! * the engine skips re-arming timers whose deadline didn't move and
//!   compacts its event heap when dead (superseded/canceled) entries
//!   accumulate.
//!
//! `rust/tests/alloc_free.rs` pins the zero-allocation property with a
//! counting global allocator; `rust/tests/hotpath_equivalence.rs` pins
//! integer/float equivalence against the seed implementations kept in
//! `core::profile::reference`; `rust/benches/bench_hotpath.rs` tracks
//! the throughput trajectory in `BENCH_hotpath.json`.

use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, ReqList, Request};

pub mod analytical;
pub mod batch_policy;
pub mod clockwork;
pub mod deferred;
pub mod nexus;
pub mod shepherd;
pub mod timeout;

/// Keys for scheduler-owned timers. The engine multiplexes them; setting
/// a key that is already pending replaces (cancels) the earlier timer.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum TimerKey {
    /// Fires at a candidate's `exec` moment (Algorithm 1 model timer).
    Model(ModelId),
    /// Auxiliary per-model timer (candidate revalidation / drops).
    ModelAux(ModelId),
    /// Per-GPU timer (used by baselines that poll their own queues).
    Gpu(GpuId),
    /// Periodic/custom timers (Nexus epochs, autoscaler ticks).
    Custom(u64),
}

/// Actions a scheduler can take in response to an event.
#[derive(Clone, Debug)]
pub enum Command {
    /// Start executing `requests` as one batch on `gpu` *now*. The GPU
    /// must be free; execution takes `ℓ(|requests|)` plus network delay.
    Dispatch {
        gpu: GpuId,
        model: ModelId,
        requests: ReqList,
    },
    /// Give up on requests that can no longer meet their deadline.
    Drop(ReqList),
    /// Arm (or re-arm) a timer.
    SetTimer { key: TimerKey, at: Micros },
    /// Disarm a timer if pending.
    CancelTimer { key: TimerKey },
    /// Cancel the batch currently running on `gpu` (Shepherd-style
    /// preemption). The engine frees the GPU immediately and hands the
    /// unfinished requests back via `on_preempted`.
    Preempt { gpu: GpuId },
}

/// Event-driven scheduler interface (Algorithm 1's event procedures).
pub trait Scheduler {
    /// `OnNewRequest` — a request arrived at the cluster.
    fn on_request(&mut self, req: Request, now: Micros, out: &mut Vec<Command>);

    /// A timer previously set via `Command::SetTimer` fired.
    fn on_timer(&mut self, key: TimerKey, now: Micros, out: &mut Vec<Command>);

    /// `OnGpuTimer` — a GPU finished its batch and is free.
    fn on_gpu_free(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>);

    /// A `Preempt` completed; `requests` did not finish and are the
    /// scheduler's responsibility again. Default: schedulers that never
    /// preempt never receive this.
    fn on_preempted(
        &mut self,
        _gpu: GpuId,
        _requests: Vec<Request>,
        _now: Micros,
        _out: &mut Vec<Command>,
    ) {
        unreachable!("scheduler issued no Preempt but got on_preempted");
    }

    /// Cluster grew (autoscaling). The new GPU starts free.
    fn on_gpu_added(&mut self, _gpu: GpuId, _now: Micros, _out: &mut Vec<Command>) {}

    /// Cluster shrank; `gpu` was idle and is gone.
    fn on_gpu_removed(&mut self, _gpu: GpuId, _now: Micros, _out: &mut Vec<Command>) {}

    /// Human-readable name for tables.
    fn name(&self) -> &'static str;
}

impl Scheduler for Box<dyn Scheduler> {
    fn on_request(&mut self, req: Request, now: Micros, out: &mut Vec<Command>) {
        (**self).on_request(req, now, out)
    }
    fn on_timer(&mut self, key: TimerKey, now: Micros, out: &mut Vec<Command>) {
        (**self).on_timer(key, now, out)
    }
    fn on_gpu_free(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        (**self).on_gpu_free(gpu, now, out)
    }
    fn on_preempted(
        &mut self,
        gpu: GpuId,
        requests: Vec<Request>,
        now: Micros,
        out: &mut Vec<Command>,
    ) {
        (**self).on_preempted(gpu, requests, now, out)
    }
    fn on_gpu_added(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        (**self).on_gpu_added(gpu, now, out)
    }
    fn on_gpu_removed(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        (**self).on_gpu_removed(gpu, now, out)
    }
    fn name(&self) -> &'static str {
        (**self).name()
    }
}
