//! Nexus-style baseline (§2.2): distributed scheduling with epoch-level
//! planning.
//!
//! * Every epoch (10 s) a planner assigns models to GPUs with an
//!   expected batch size derived from the SLO (`maxfit(SLO/2)` — without
//!   cluster-wide coordination a request can queue for up to ℓ(b), so
//!   half the SLO budget goes to queueing, §5.3).
//! * Frontends route each request round-robin across the GPUs assigned
//!   to its model — **independently**, with no shared state (running
//!   more frontends loses goodput, Fig 9's Nexus1FE vs Nexus8FE).
//! * Backends are eager: whenever a GPU is idle and has queued work it
//!   runs `min(queued, expected batch)` immediately, round-robin across
//!   the models loaded on it. Excess requests that cannot meet their
//!   deadline are dropped.
//!
//! No coordination means the worst-case queueing delay for a request is
//! a full ℓ(b) (Fig 12) — the analytical "No Coordination" column of
//! Table 2.

use std::collections::BTreeSet;

use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, Request};
use crate::scheduler::batch_policy::ModelQueue;
use crate::scheduler::{Command, Scheduler, TimerKey};

const EPOCH: Micros = Micros(10_000_000); // 10 s
const EPOCH_TIMER: TimerKey = TimerKey::Custom(u64::MAX - 1);
/// EWMA weight of the newest epoch's observed rate.
const EWMA: f64 = 0.5;

struct MState {
    profile: LatencyProfile,
    slo: Micros,
    /// GPUs currently serving this model.
    gpus: Vec<GpuId>,
    /// Scheduler-assigned expected batch size.
    batch_target: u32,
    /// Arrivals this epoch (rate estimation).
    arrivals: u64,
    /// EWMA rate estimate, requests/second.
    rate: f64,
}

/// Per-GPU backend state: one queue per model loaded on it.
#[derive(Default)]
struct GState {
    queues: Vec<(ModelId, ModelQueue)>,
    rr: usize,
}

impl GState {
    fn queue_mut(&mut self, m: ModelId) -> &mut ModelQueue {
        if let Some(i) = self.queues.iter().position(|(id, _)| *id == m) {
            return &mut self.queues[i].1;
        }
        self.queues.push((m, ModelQueue::new()));
        &mut self.queues.last_mut().unwrap().1
    }
}

pub struct NexusScheduler {
    models: Vec<MState>,
    gpus: Vec<GState>,
    free_gpus: BTreeSet<GpuId>,
    /// Independent frontends: per-frontend, per-model round-robin
    /// cursors; requests are spread across frontends round-robin. With a
    /// single frontend the round-robin is perfectly coordinated; with
    /// several, each frontend only sees a sparse sample of the stream —
    /// per-GPU interleaving degrades toward random, creating queue
    /// imbalance (the Fig 9 Nexus1FE-vs-8FE gap).
    frontends: Vec<Vec<usize>>,
    fe_cursor: usize,
    epoch_started: bool,
    route_rng: crate::util::rng::Rng,
}

impl NexusScheduler {
    pub fn new(
        specs: Vec<(LatencyProfile, Micros)>,
        num_gpus: usize,
        num_frontends: usize,
    ) -> Self {
        let n_models = specs.len();
        let mut s = NexusScheduler {
            models: specs
                .into_iter()
                .map(|(profile, slo)| MState {
                    profile,
                    slo,
                    gpus: Vec::new(),
                    batch_target: 1,
                    arrivals: 0,
                    rate: 0.0,
                })
                .collect(),
            gpus: (0..num_gpus).map(|_| GState::default()).collect(),
            free_gpus: (0..num_gpus as u32).map(GpuId).collect(),
            frontends: vec![vec![0; n_models]; num_frontends.max(1)],
            fe_cursor: 0,
            epoch_started: false,
            route_rng: crate::util::rng::Rng::new(0xFE0F ^ num_frontends as u64),
        };
        s.plan_even();
        s
    }

    /// Initial plan: spread GPUs evenly across models (no rates known).
    fn plan_even(&mut self) {
        let g = self.gpus.len();
        let m = self.models.len();
        for st in self.models.iter_mut() {
            st.gpus.clear();
            st.batch_target = st.profile.max_batch_within(Micros(st.slo.0 / 2)).max(1);
        }
        for gi in 0..g {
            let mi = gi % m;
            self.models[mi].gpus.push(GpuId(gi as u32));
        }
        // If fewer GPUs than models, share: model mi uses gpu mi % g.
        for mi in 0..m {
            if self.models[mi].gpus.is_empty() {
                self.models[mi].gpus.push(GpuId((mi % g) as u32));
            }
        }
    }

    /// Epoch planning: proportional GPU shares from EWMA rates
    /// (largest-remainder apportionment), at least one GPU per model.
    fn plan_epoch(&mut self) {
        let g = self.gpus.len();
        let mut demand: Vec<f64> = self
            .models
            .iter()
            .map(|st| {
                let tput = st.profile.throughput(st.batch_target.max(1));
                if tput <= 0.0 {
                    0.0
                } else {
                    st.rate / tput
                }
            })
            .collect();
        let total: f64 = demand.iter().sum();
        if total <= 0.0 {
            self.plan_even();
            return;
        }
        // Scale demand to the cluster size.
        let scale = g as f64 / total.max(g as f64);
        for d in demand.iter_mut() {
            *d *= scale;
        }
        // Integer shares, >= 1, largest remainder.
        let mut shares: Vec<usize> = demand.iter().map(|d| d.floor() as usize).collect();
        for s in shares.iter_mut() {
            *s = (*s).max(1);
        }
        let mut used: usize = shares.iter().sum();
        let mut rema: Vec<(f64, usize)> = demand
            .iter()
            .enumerate()
            .map(|(i, d)| (d - d.floor(), i))
            .collect();
        rema.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut k = 0;
        while used < g && k < rema.len() {
            shares[rema[k].1] += 1;
            used += 1;
            k += 1;
        }
        // Assign GPU ids sequentially; overflow shares wrap (sharing).
        for st in self.models.iter_mut() {
            st.gpus.clear();
        }
        let mut gi = 0usize;
        for (mi, &s) in shares.iter().enumerate() {
            for _ in 0..s {
                self.models[mi].gpus.push(GpuId((gi % g) as u32));
                gi += 1;
            }
        }
    }

    /// Backend loop: run the next batch on an idle GPU, round-robin
    /// across the models loaded on it.
    fn backend_kick(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        let gi = gpu.0 as usize;
        let n = self.gpus[gi].queues.len();
        if n == 0 {
            return;
        }
        for step in 0..n {
            let qi = (self.gpus[gi].rr + step) % n;
            let (m, target, plan) = {
                let (m, _) = self.gpus[gi].queues[qi];
                let st = &self.models[m.0 as usize];
                let profile = st.profile;
                let target = st.batch_target;
                let q = &mut self.gpus[gi].queues[qi].1;
                // Nexus backends drop excess requests to hold the
                // scheduler-assigned batch size (§2.2).
                let plan = q.plan_target(now, &profile, Micros::ZERO, target, target);
                (m, target, plan)
            };
            let _ = target;
            if !plan.dropped.is_empty() {
                out.push(Command::Drop(plan.dropped.clone().into()));
            }
            if plan.batch.is_empty() {
                continue;
            }
            let b = plan.batch.len();
            let requests = self.gpus[gi].queues[qi].1.take_list(b);
            self.gpus[gi].rr = (qi + 1) % n;
            self.free_gpus.remove(&gpu);
            out.push(Command::Dispatch {
                gpu,
                model: m,
                requests,
            });
            return;
        }
    }
}

impl Scheduler for NexusScheduler {
    fn on_request(&mut self, req: Request, now: Micros, out: &mut Vec<Command>) {
        if !self.epoch_started {
            self.epoch_started = true;
            out.push(Command::SetTimer {
                key: EPOCH_TIMER,
                at: now + EPOCH,
            });
        }
        let mi = req.model.0 as usize;
        self.models[mi].arrivals += 1;

        // Frontend routing. One frontend round-robins the full stream —
        // the best a distributed router can do. Several independent
        // frontends each see ~1/k of the stream with no shared cursor;
        // the per-GPU arrival pattern they jointly produce is effectively
        // random, so queues imbalance (Fig 9's distributed-scheduling
        // loss). We model k>1 frontends as uncoordinated random routing.
        let gpus = &self.models[mi].gpus;
        debug_assert!(!gpus.is_empty());
        let gpu = if self.frontends.len() == 1 {
            let cursor = &mut self.frontends[0][mi];
            let g = gpus[*cursor % gpus.len()];
            *cursor = (*cursor + 1) % gpus.len().max(1);
            g
        } else {
            gpus[self.route_rng.below(gpus.len() as u64) as usize]
        };

        self.gpus[gpu.0 as usize].queue_mut(req.model).push(req);
        if self.free_gpus.contains(&gpu) {
            // Eager backend: idle GPU runs immediately.
            self.backend_kick(gpu, now, out);
        }
    }

    fn on_timer(&mut self, key: TimerKey, now: Micros, out: &mut Vec<Command>) {
        if key != EPOCH_TIMER {
            return;
        }
        // Rate estimation + replan.
        let secs = EPOCH.as_secs_f64();
        for st in self.models.iter_mut() {
            let observed = st.arrivals as f64 / secs;
            st.rate = if st.rate == 0.0 {
                observed
            } else {
                EWMA * observed + (1.0 - EWMA) * st.rate
            };
            st.arrivals = 0;
        }
        self.plan_epoch();
        out.push(Command::SetTimer {
            key: EPOCH_TIMER,
            at: now + EPOCH,
        });
    }

    fn on_gpu_free(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        self.free_gpus.insert(gpu);
        self.backend_kick(gpu, now, out);
    }

    fn on_gpu_added(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        let gi = gpu.0 as usize;
        if gi >= self.gpus.len() {
            self.gpus.resize_with(gi + 1, GState::default);
        }
        self.free_gpus.insert(gpu);
        self.plan_epoch();
        self.backend_kick(gpu, now, out);
    }

    fn on_gpu_removed(&mut self, gpu: GpuId, _now: Micros, _out: &mut Vec<Command>) {
        self.free_gpus.remove(&gpu);
    }

    fn name(&self) -> &'static str {
        "nexus"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::profile::ModelSpec;
    use crate::sim::{Engine, SimConfig};
    use crate::workload::WorkloadSpec;

    fn run_nexus(rate: f64, frontends: usize, secs: f64) -> crate::metrics::Metrics {
        let model = ModelSpec::new("r50", 1.053, 5.072, 25.0);
        let spec = WorkloadSpec::new(vec![model.clone()], rate).seed(21);
        let sched = NexusScheduler::new(vec![(model.profile, model.slo)], 8, frontends);
        Engine::new(
            spec.build(),
            sched,
            SimConfig::new(8, Micros::from_secs_f64(secs)),
        )
        .run()
        .metrics
    }

    #[test]
    fn nexus_serves_with_moderate_batches() {
        let m = run_nexus(3500.0, 1, 8.0);
        let median = m.per_model[0].median_batch();
        // Fig 1: Nexus median ~6 on ResNet50 — definitely below
        // Symphony's ~14 and above Clockwork's 1.
        assert!((2..=9).contains(&median), "nexus median {median}");
        assert!(m.bad_fraction() < 0.2, "bad {}", m.bad_fraction());
    }

    #[test]
    fn nexus_queueing_delay_up_to_full_exec() {
        // No coordination: worst queueing ~ ℓ(b) (vs ℓ(b)/N for
        // Symphony) — check p99 queueing is a large fraction of ℓ(b).
        let m = run_nexus(3500.0, 1, 8.0);
        let q = m.queueing_all();
        let p99 = crate::util::stats::percentile(&q, 99.0);
        assert!(p99 > 5.0, "p99 queueing {p99}ms too small for uncoordinated");
    }

    #[test]
    fn more_frontends_do_not_improve() {
        // Fig 9 (Nexus1FE vs Nexus8FE): at a rate one frontend still
        // handles cleanly, independent frontends' uncoordinated routing
        // imbalances queues — higher bad rate, lower goodput.
        let m1 = run_nexus(3500.0, 1, 8.0);
        let m8 = run_nexus(3500.0, 8, 8.0);
        assert!(
            m1.bad_fraction() < m8.bad_fraction(),
            "bad 1FE {} vs 8FE {}",
            m1.bad_fraction(),
            m8.bad_fraction()
        );
        assert!(
            m8.goodput() <= m1.goodput(),
            "1FE {} vs 8FE {}",
            m1.goodput(),
            m8.goodput()
        );
    }

    #[test]
    fn multi_model_sharing_when_fewer_gpus() {
        // 4 models, 2 GPUs: every model must still be routable.
        let models: Vec<ModelSpec> = (0..4)
            .map(|i| ModelSpec::new(&format!("m{i}"), 1.0, 5.0, 50.0))
            .collect();
        let spec = WorkloadSpec::new(models.clone(), 400.0).seed(3);
        let sched = NexusScheduler::new(
            models.iter().map(|m| (m.profile, m.slo)).collect(),
            2,
            1,
        );
        let res = Engine::new(
            spec.build(),
            sched,
            SimConfig::new(2, Micros::from_secs_f64(5.0)),
        )
        .run();
        for (i, pm) in res.metrics.per_model.iter().enumerate() {
            assert!(pm.good > 0, "model {i} starved");
        }
    }
}
