//! Shepherd-style baseline (§2.2): the Flex policy, reimplemented from
//! the published description (Shepherd is closed-source; the authors did
//! the same).
//!
//! * one outstanding candidate per model = the largest feasible batch;
//! * eager: when a GPU frees (or a request arrives at an idle cluster),
//!   dispatch the candidate with the **biggest batch size**;
//! * preemption: a candidate at least `3×` the size of a running batch
//!   may cancel it ("eager batching with preemption"); the canceled
//!   batch's requests are requeued and its work is wasted.

use std::collections::BTreeSet;

use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, Request};
use crate::scheduler::batch_policy::ModelQueue;
use crate::scheduler::{Command, Scheduler, TimerKey};

/// Preemption threshold from §2.2: "at least 3x the size".
const PREEMPT_FACTOR: usize = 3;

struct MState {
    queue: ModelQueue,
    profile: LatencyProfile,
}

#[derive(Clone, Copy, Debug)]
struct Running {
    model: ModelId,
    size: usize,
    /// Execution end (to avoid preempting nearly-done batches wastefully
    /// is Shepherd's concern, not ours — kept for bookkeeping).
    end: Micros,
}

pub struct ShepherdScheduler {
    models: Vec<MState>,
    free_gpus: BTreeSet<GpuId>,
    running: Vec<Option<Running>>,
    /// Allow preemption (the paper's Shepherd default). Disable to get a
    /// pure biggest-batch eager scheduler for ablations.
    pub preemption: bool,
}

impl ShepherdScheduler {
    pub fn new(profiles: Vec<LatencyProfile>, num_gpus: usize) -> Self {
        ShepherdScheduler {
            models: profiles
                .into_iter()
                .map(|profile| MState {
                    queue: ModelQueue::new(),
                    profile,
                })
                .collect(),
            free_gpus: (0..num_gpus as u32).map(GpuId).collect(),
            running: vec![None; num_gpus],
            preemption: true,
        }
    }

    /// Candidate (batch size) for each model; biggest wins.
    fn biggest_candidate(&mut self, now: Micros, out: &mut Vec<Command>) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize)> = None; // (b, model)
        for (mi, st) in self.models.iter_mut().enumerate() {
            let plan = st.queue.plan(now, &st.profile, Micros::ZERO, 0);
            if !plan.dropped.is_empty() {
                out.push(Command::Drop(plan.dropped.clone().into()));
            }
            let b = plan.batch.len();
            if b == 0 {
                continue;
            }
            if best.map_or(true, |(bb, _)| b > bb) {
                best = Some((b, mi));
            }
        }
        best
    }

    fn dispatch_to(&mut self, gpu: GpuId, mi: usize, b: usize, now: Micros, out: &mut Vec<Command>) {
        let requests = self.models[mi].queue.take_list(b);
        self.free_gpus.remove(&gpu);
        let end = now + self.models[mi].profile.latency(b as u32);
        self.running[gpu.0 as usize] = Some(Running {
            model: ModelId(mi as u32),
            size: b,
            end,
        });
        out.push(Command::Dispatch {
            gpu,
            model: ModelId(mi as u32),
            requests,
        });
    }

    fn dispatch_biggest(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        if let Some((b, mi)) = self.biggest_candidate(now, out) {
            self.dispatch_to(gpu, mi, b, now, out);
        }
    }

    /// Try to preempt: find the running batch with the smallest size such
    /// that `candidate >= 3 * size`.
    fn try_preempt(&mut self, cand_size: usize, out: &mut Vec<Command>) -> bool {
        if !self.preemption {
            return false;
        }
        let mut victim: Option<(usize, GpuId)> = None;
        for (gi, r) in self.running.iter().enumerate() {
            if let Some(r) = r {
                if cand_size >= PREEMPT_FACTOR * r.size
                    && victim.map_or(true, |(s, _)| r.size < s)
                {
                    victim = Some((r.size, GpuId(gi as u32)));
                }
            }
        }
        if let Some((_, gpu)) = victim {
            self.running[gpu.0 as usize] = None;
            out.push(Command::Preempt { gpu });
            // The engine will call on_preempted -> requeue -> then the
            // freed GPU is matched below via on_preempted's dispatch.
            true
        } else {
            false
        }
    }
}

impl Scheduler for ShepherdScheduler {
    fn on_request(&mut self, req: Request, now: Micros, out: &mut Vec<Command>) {
        let mi = req.model.0 as usize;
        self.models[mi].queue.push(req);
        if let Some(&gpu) = self.free_gpus.iter().next() {
            // Eager: idle GPU + pending work -> run the biggest batch.
            self.dispatch_biggest(gpu, now, out);
            return;
        }
        // No free GPU: consider preemption for the updated candidate.
        let plan = {
            let st = &mut self.models[mi];
            st.queue.plan(now, &st.profile, Micros::ZERO, 0)
        };
        if !plan.dropped.is_empty() {
            out.push(Command::Drop(plan.dropped.clone().into()));
        }
        let b = plan.batch.len();
        if b > 0 {
            self.try_preempt(b, out);
        }
    }

    fn on_timer(&mut self, _key: TimerKey, _now: Micros, _out: &mut Vec<Command>) {}

    fn on_gpu_free(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        self.running[gpu.0 as usize] = None;
        self.free_gpus.insert(gpu);
        self.dispatch_biggest(gpu, now, out);
    }

    fn on_preempted(
        &mut self,
        gpu: GpuId,
        requests: Vec<Request>,
        now: Micros,
        out: &mut Vec<Command>,
    ) {
        // Requeue the canceled batch's requests (their deadlines stand;
        // most will be droppable — preemption wastes work, §2.2).
        if let Some(first) = requests.first() {
            let mi = first.model.0 as usize;
            self.models[mi].queue.push_front_sorted(requests);
        }
        self.free_gpus.insert(gpu);
        self.dispatch_biggest(gpu, now, out);
    }

    fn on_gpu_added(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        let gi = gpu.0 as usize;
        if gi >= self.running.len() {
            self.running.resize(gi + 1, None);
        }
        self.free_gpus.insert(gpu);
        self.dispatch_biggest(gpu, now, out);
    }

    fn on_gpu_removed(&mut self, gpu: GpuId, _now: Micros, _out: &mut Vec<Command>) {
        self.free_gpus.remove(&gpu);
    }

    fn name(&self) -> &'static str {
        "shepherd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::profile::ModelSpec;
    use crate::sim::{Engine, SimConfig};
    use crate::workload::{Workload, WorkloadSpec};

    #[test]
    fn biggest_batch_wins() {
        let a = ModelSpec::new("a", 1.0, 5.0, 100.0);
        let b = ModelSpec::new("b", 1.0, 5.0, 100.0);
        // Model a has 1 queued, model b has 5 queued; single GPU busy
        // with a long warmup batch... simpler: both queues fill while the
        // only GPU runs the first arrival; on free, b's bigger batch runs.
        let workload = Workload::explicit(
            vec![a.clone(), b.clone()],
            vec![
                vec![Micros(0), Micros(10)],
                (0..5).map(|i| Micros(20 + i)).collect(),
            ],
        );
        let mut sched = ShepherdScheduler::new(vec![a.profile, b.profile], 1);
        sched.preemption = false; // isolate the biggest-batch-wins rule
        let res = Engine::new(
            workload,
            sched,
            SimConfig::new(1, Micros::from_secs_f64(1.0)).trace(true),
        )
        .run();
        // Trace: batch 1 = model a size 1 (eager at t=0); batch 2 should
        // be model b (5 queued > 1 queued of a).
        assert_eq!(res.trace[0].model, ModelId(0));
        assert_eq!(res.trace[1].model, ModelId(1));
        assert_eq!(res.trace[1].size, 5);
    }

    #[test]
    fn preemption_cancels_small_batches() {
        // GPU starts a batch of 1; then 6 requests of another model
        // arrive (6 >= 3*1) -> preempt.
        let a = ModelSpec::new("a", 1.0, 50.0, 200.0);
        let b = ModelSpec::new("b", 1.0, 50.0, 200.0);
        let workload = Workload::explicit(
            vec![a.clone(), b.clone()],
            vec![
                vec![Micros(0)],
                (0..6).map(|i| Micros(1000 + i)).collect(),
            ],
        );
        let sched = ShepherdScheduler::new(vec![a.profile, b.profile], 1);
        let res = Engine::new(
            workload,
            sched,
            SimConfig::new(1, Micros::from_secs_f64(2.0)).trace(true),
        )
        .run();
        assert_eq!(res.metrics.preempted_batches, 1);
        // Preempted model-a batch re-ran later (its deadline was loose).
        let a_good = res.metrics.per_model[0].good;
        assert_eq!(a_good, 1, "preempted request re-ran");
        assert!(res.trace.iter().any(|t| t.preempted));
    }

    #[test]
    fn shepherd_batches_between_eager_and_deferred() {
        let model = ModelSpec::new("r50", 1.053, 5.072, 25.0);
        let spec = WorkloadSpec::new(vec![model.clone()], 4000.0).seed(9);
        let sched = ShepherdScheduler::new(vec![model.profile], 8);
        let res = Engine::new(
            spec.build(),
            sched,
            SimConfig::new(8, Micros::from_secs_f64(4.0)),
        )
        .run();
        let median = res.metrics.per_model[0].median_batch();
        // Fig 1: Shepherd median ~9 on ResNet50 (between Nexus 6 and
        // Symphony 14).
        assert!((4..=13).contains(&median), "shepherd median {median}");
    }
}
