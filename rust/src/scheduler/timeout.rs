//! Timeout-based batch scheduling (TensorFlow-Serving style, §2.2/§3.4)
//! and its `k = 0` special case, **eager scheduling**.
//!
//! Identical to the deferred scheduler except Algorithm 1's line 5:
//!
//! ```text
//! exec ← max(now(), a + k)        (a = earliest arrival in the batch)
//! ```
//!
//! plus the TF-Serving max-batch trigger: when the batch reaches the
//! configured cap it becomes dispatchable immediately. With `k = 0`
//! every candidate is immediately schedulable — eager batching: a batch
//! is dispatched whenever a GPU is idle, with whatever has accumulated.

use std::collections::BTreeSet;

use crate::core::profile::LatencyProfile;
use crate::core::time::Micros;
use crate::core::types::{GpuId, ModelId, Request};
use crate::scheduler::batch_policy::ModelQueue;
use crate::scheduler::{Command, Scheduler, TimerKey};

#[derive(Clone, Copy, Debug)]
struct Candidate {
    exec: Micros,
    latest: Micros,
    ready: bool,
}

struct MState {
    queue: ModelQueue,
    profile: LatencyProfile,
    cand: Option<Candidate>,
}

#[derive(Clone, Copy, Debug)]
pub struct TimeoutConfig {
    /// The timeout `k`; `ZERO` = eager.
    pub timeout: Micros,
    /// Dispatch as soon as the batch reaches this size (0 = use the
    /// SLO-derived max fit).
    pub max_batch: u32,
    pub net_bound: Micros,
}

impl TimeoutConfig {
    pub fn eager() -> Self {
        TimeoutConfig {
            timeout: Micros::ZERO,
            max_batch: 0,
            net_bound: Micros::ZERO,
        }
    }

    pub fn with_timeout(timeout: Micros) -> Self {
        TimeoutConfig {
            timeout,
            max_batch: 0,
            net_bound: Micros::ZERO,
        }
    }
}

pub struct TimeoutScheduler {
    models: Vec<MState>,
    free_gpus: BTreeSet<GpuId>,
    ready: BTreeSet<(Micros, ModelId)>,
    cfg: TimeoutConfig,
    eager: bool,
}

impl TimeoutScheduler {
    pub fn new(profiles: Vec<LatencyProfile>, num_gpus: usize, cfg: TimeoutConfig) -> Self {
        TimeoutScheduler {
            models: profiles
                .into_iter()
                .map(|profile| MState {
                    queue: ModelQueue::new(),
                    profile,
                    cand: None,
                })
                .collect(),
            free_gpus: (0..num_gpus as u32).map(GpuId).collect(),
            ready: BTreeSet::new(),
            eager: cfg.timeout == Micros::ZERO,
            cfg,
        }
    }

    fn clear_candidate(&mut self, m: ModelId) {
        if let Some(c) = self.models[m.0 as usize].cand.take() {
            if c.ready {
                self.ready.remove(&(c.latest, m));
            }
        }
    }

    fn update_candidate(&mut self, m: ModelId, now: Micros, out: &mut Vec<Command>) {
        self.clear_candidate(m);
        let slack = self.cfg.net_bound;
        let st = &mut self.models[m.0 as usize];
        let plan = st.queue.plan(now, &st.profile, slack, self.cfg.max_batch);
        if !plan.dropped.is_empty() {
            out.push(Command::Drop(plan.dropped.clone().into()));
        }
        if plan.batch.is_empty() {
            out.push(Command::CancelTimer { key: TimerKey::Model(m) });
            out.push(Command::CancelTimer { key: TimerKey::ModelAux(m) });
            return;
        }
        let b = plan.batch.len() as u32;
        let d = plan.deadline;
        let latest = d.saturating_sub(st.profile.latency(b).saturating_add(slack));
        let a = st.queue.head_arrival().unwrap();
        // Timeout semantics: wait until `a + k` unless the batch already
        // hit its cap (TF-Serving's second trigger).
        let cap = if self.cfg.max_batch > 0 {
            self.cfg.max_batch
        } else {
            st.profile
                .max_batch_within(d.saturating_sub(now.saturating_add(slack)))
        };
        let exec = if b >= cap {
            now
        } else {
            a.saturating_add(self.cfg.timeout).max(now)
        };
        let cand = Candidate {
            exec,
            latest,
            ready: false,
        };
        self.models[m.0 as usize].cand = Some(cand);

        if exec > now && exec <= latest {
            out.push(Command::SetTimer {
                key: TimerKey::Model(m),
                at: exec,
            });
            out.push(Command::CancelTimer { key: TimerKey::ModelAux(m) });
        } else if exec > latest {
            // Mistuned timeout: the window closed before the timeout
            // expires. The batch is not schedulable; revalidate after
            // `latest` — the shrinking batch raises `latest` until the
            // window reopens (Fig 6b's goodput collapse for large k).
            out.push(Command::CancelTimer { key: TimerKey::Model(m) });
            out.push(Command::SetTimer {
                key: TimerKey::ModelAux(m),
                at: latest.saturating_add(Micros(1)),
            });
        } else {
            out.push(Command::CancelTimer { key: TimerKey::Model(m) });
            self.enter_ready(m, now, out);
        }
    }

    fn enter_ready(&mut self, m: ModelId, now: Micros, out: &mut Vec<Command>) {
        if let Some(&gpu) = self.free_gpus.iter().next() {
            self.dispatch(m, gpu, now, out);
            return;
        }
        let st = &mut self.models[m.0 as usize];
        let c = st.cand.as_mut().expect("enter_ready without candidate");
        c.ready = true;
        let latest = c.latest;
        self.ready.insert((latest, m));
        out.push(Command::SetTimer {
            key: TimerKey::ModelAux(m),
            at: latest.saturating_add(Micros(1)),
        });
    }

    fn dispatch(&mut self, m: ModelId, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        self.clear_candidate(m);
        let slack = self.cfg.net_bound;
        let st = &mut self.models[m.0 as usize];
        let plan = st.queue.plan(now, &st.profile, slack, self.cfg.max_batch);
        if !plan.dropped.is_empty() {
            out.push(Command::Drop(plan.dropped.clone().into()));
        }
        if plan.batch.is_empty() {
            return;
        }
        let n = plan.batch.len();
        let requests = st.queue.take_list(n);
        self.free_gpus.remove(&gpu);
        out.push(Command::Dispatch {
            gpu,
            model: m,
            requests,
        });
        self.update_candidate(m, now, out);
    }

    fn match_gpu(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        loop {
            let Some(&(latest, m)) = self.ready.iter().next() else {
                return;
            };
            if latest < now {
                // Recompute may dispatch to `gpu` itself — stop if taken.
                self.update_candidate(m, now, out);
                if !self.free_gpus.contains(&gpu) {
                    return;
                }
                continue;
            }
            self.dispatch(m, gpu, now, out);
            return;
        }
    }
}

impl Scheduler for TimeoutScheduler {
    fn on_request(&mut self, req: Request, now: Micros, out: &mut Vec<Command>) {
        let m = req.model;
        self.models[m.0 as usize].queue.push(req);
        self.update_candidate(m, now, out);
    }

    fn on_timer(&mut self, key: TimerKey, now: Micros, out: &mut Vec<Command>) {
        match key {
            TimerKey::Model(m) => {
                if self.models[m.0 as usize].cand.is_some() {
                    self.enter_ready(m, now, out);
                }
            }
            TimerKey::ModelAux(m) => self.update_candidate(m, now, out),
            _ => {}
        }
    }

    fn on_gpu_free(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        self.free_gpus.insert(gpu);
        self.match_gpu(gpu, now, out);
    }

    fn on_gpu_added(&mut self, gpu: GpuId, now: Micros, out: &mut Vec<Command>) {
        self.free_gpus.insert(gpu);
        self.match_gpu(gpu, now, out);
    }

    fn on_gpu_removed(&mut self, gpu: GpuId, _now: Micros, _out: &mut Vec<Command>) {
        self.free_gpus.remove(&gpu);
    }

    fn name(&self) -> &'static str {
        if self.eager {
            "eager"
        } else {
            "timeout"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::profile::ModelSpec;
    use crate::sim::{Engine, SimConfig};
    use crate::workload::{Workload, WorkloadSpec};

    #[test]
    fn eager_dispatches_immediately_when_gpu_free() {
        // Single request, free GPUs: eager runs it at t=arrival.
        let model = ModelSpec::new("m", 1.0, 5.0, 12.0);
        let workload = Workload::explicit(
            vec![model.clone()],
            vec![vec![Micros::from_millis_f64(1.0)]],
        );
        let sched =
            TimeoutScheduler::new(vec![model.profile], 2, TimeoutConfig::eager());
        let res = Engine::new(
            workload,
            sched,
            SimConfig::new(2, Micros::from_secs_f64(1.0)).trace(true),
        )
        .run();
        assert_eq!(res.trace.len(), 1);
        assert_eq!(res.trace[0].start, Micros::from_millis_f64(1.0));
        assert_eq!(res.trace[0].size, 1);
    }

    #[test]
    fn timeout_waits_k_after_first_arrival() {
        let model = ModelSpec::new("m", 1.0, 5.0, 20.0);
        let times: Vec<Micros> = (0..4)
            .map(|i| Micros::from_millis_f64(i as f64))
            .collect();
        let workload = Workload::explicit(vec![model.clone()], vec![times]);
        let sched = TimeoutScheduler::new(
            vec![model.profile],
            1,
            TimeoutConfig::with_timeout(Micros::from_millis_f64(5.0)),
        );
        let res = Engine::new(
            workload,
            sched,
            SimConfig::new(1, Micros::from_secs_f64(1.0)).trace(true),
        )
        .run();
        // First batch dispatches at a_0 + k = 5ms with all 4 requests.
        assert_eq!(res.trace[0].start, Micros::from_millis_f64(5.0));
        assert_eq!(res.trace[0].size, 4);
    }

    #[test]
    fn eager_runs_smaller_batches_than_deferred() {
        // ResNet50-like model near saturation: eager median batch must be
        // smaller (§2.2 / Fig 1's ordering).
        let model = ModelSpec::new("r50", 1.053, 5.072, 25.0);
        let mk_spec = || WorkloadSpec::new(vec![model.clone()], 4000.0).seed(3);
        let cfg = || SimConfig::new(8, Micros::from_secs_f64(4.0));

        let eager =
            TimeoutScheduler::new(vec![model.profile], 8, TimeoutConfig::eager());
        let r_eager = Engine::new(mk_spec().build(), eager, cfg()).run();

        let deferred = crate::scheduler::deferred::DeferredScheduler::new(
            vec![model.profile],
            8,
            Default::default(),
        );
        let r_def = Engine::new(mk_spec().build(), deferred, cfg()).run();

        let eb = r_eager.metrics.per_model[0].median_batch();
        let db = r_def.metrics.per_model[0].median_batch();
        assert!(db > eb, "deferred median {db} vs eager {eb}");
    }
}
